from repro.optim.optimizers import adam, sgd, Optimizer, clip_by_global_norm
from repro.optim.grad_compression import int8_compress_decompress, error_feedback_compress
