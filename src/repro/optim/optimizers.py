"""Optimizers from scratch (no optax): Adam (the paper's software baseline)
and plain SGD (the paper's FPGA training rule), as pure pytree transforms.

API mirrors the functional style the rest of the framework uses:

    opt = adam(lr=1e-4)
    state = opt.init(params)
    params, state = opt.update(grads, state, params)

States are pytrees with the same sharding as the params they track, so under
pjit the optimizer shards for free (ZeRO-style partitioned states fall out of
the FSDP param sharding).
"""

from __future__ import annotations

import dataclasses
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable
    update: Callable  # (grads, state, params) -> (new_params, new_state)


class AdamState(NamedTuple):
    step: jnp.ndarray
    mu: object
    nu: object


def adam(lr: float = 1e-4, b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8,
         weight_decay: float = 0.0) -> Optimizer:
    def init(params):
        zeros = lambda p: jnp.zeros_like(p)
        return AdamState(step=jnp.zeros((), jnp.int32),
                         mu=jax.tree.map(zeros, params),
                         nu=jax.tree.map(zeros, params))

    def update(grads, state, params):
        step = state.step + 1
        t = step.astype(jnp.float32)
        c1 = 1.0 - jnp.power(b1, t)
        c2 = 1.0 - jnp.power(b2, t)

        def upd(g, m, v, p):
            m = b1 * m + (1 - b1) * g
            v = b2 * v + (1 - b2) * jnp.square(g)
            mhat = m / c1
            vhat = v / c2
            step_ = lr * (mhat / (jnp.sqrt(vhat) + eps) + weight_decay * p)
            return p - step_, m, v

        flat_g, treedef = jax.tree.flatten(grads)
        flat_m = treedef.flatten_up_to(state.mu)
        flat_v = treedef.flatten_up_to(state.nu)
        flat_p = treedef.flatten_up_to(params)
        out = [upd(g, m, v, p) for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
        new_p = treedef.unflatten([o[0] for o in out])
        new_m = treedef.unflatten([o[1] for o in out])
        new_v = treedef.unflatten([o[2] for o in out])
        return new_p, AdamState(step=step, mu=new_m, nu=new_v)

    return Optimizer(init=init, update=update)


class SGDState(NamedTuple):
    step: jnp.ndarray
    momentum: object | None


def sgd(lr: float = 1e-4, momentum: float = 0.0) -> Optimizer:
    def init(params):
        mom = jax.tree.map(jnp.zeros_like, params) if momentum else None
        return SGDState(step=jnp.zeros((), jnp.int32), momentum=mom)

    def update(grads, state, params):
        if momentum:
            new_mom = jax.tree.map(lambda m, g: momentum * m + g, state.momentum, grads)
            new_p = jax.tree.map(lambda p, m: p - lr * m, params, new_mom)
            return new_p, SGDState(step=state.step + 1, momentum=new_mom)
        new_p = jax.tree.map(lambda p, g: p - lr * g, params, grads)
        return new_p, SGDState(step=state.step + 1, momentum=None)

    return Optimizer(init=init, update=update)


def global_norm(grads):
    return jnp.sqrt(sum(jnp.sum(jnp.square(g)) for g in jax.tree.leaves(grads)))


def clip_by_global_norm(grads, max_norm: float):
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gnorm, 1e-12))
    return jax.tree.map(lambda g: g * scale, grads), gnorm
