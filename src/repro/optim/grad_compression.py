"""Gradient compression for cross-pod (DCN) gradient sync.

At 512+ chips the pod axis crosses data-center network, ~25x slower than ICI.
We ship int8 error-feedback compression (1-bit-Adam-family trick, adapted):
each step the gradient is quantized to int8 with a per-tensor scale before the
pod all-reduce; the quantization residual is fed back into the next step's
gradient so the compression is unbiased in the long run.

Usage inside a pjit'd train step (see train/step.py): compress -> psum over
'pod' -> decompress.  On a single-pod mesh it's the identity.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def int8_compress_decompress(g: jnp.ndarray):
    """Quantize/dequantize one tensor to int8 (symmetric, per-tensor scale).

    Returns (dequantized, residual).  Simulates exactly what the wire sees.
    """
    scale = jnp.max(jnp.abs(g)) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(g / scale), -127, 127)
    deq = q * scale
    return deq, g - deq


def error_feedback_compress(grads, residuals):
    """Apply error feedback + int8 compression to a grad pytree.

    residuals: pytree like grads (carried in the train state).
    Returns (compressed_grads, new_residuals).
    """
    if residuals is None:
        residuals = jax.tree.map(jnp.zeros_like, grads)
    corrected = jax.tree.map(lambda g, r: g + r, grads, residuals)
    out = jax.tree.map(int8_compress_decompress, corrected)
    deq = jax.tree.map(lambda t: t[0], out,
                       is_leaf=lambda t: isinstance(t, tuple) and len(t) == 2)
    res = jax.tree.map(lambda t: t[1], out,
                       is_leaf=lambda t: isinstance(t, tuple) and len(t) == 2)
    return deq, res
