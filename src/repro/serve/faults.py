"""Deterministic fault injection for the recon serving stack.

``ft/runner`` proved the pattern for training: a crash injected at a known
step (``inject_fault_at``) lets CPU tests exercise the checkpoint/restart
path deterministically.  This module is the serving-side equivalent — a
:class:`FaultInjector` threaded through ``ReconEngine``/``WaveExecutor``
that fires scripted faults at exact points in the wave lifecycle, so the
recovery machinery (bounded solo retry, the circuit breaker's fused->lax
degradation, the wave watchdog, shed accounting) is tested against the
same schedule every run instead of hoping a flake reproduces.

Fault kinds (:data:`FAULT_KINDS`), each a :class:`FaultSpec`:

* ``dispatch_raise``   — the wave crashes before staging (engine level).
* ``kernel_fail``      — the jitted/fused forward raises on the wave's
  first tile (executor level): the trigger for the int8 circuit breaker.
* ``tile_timeout``     — the wave's completion wait raises
  :class:`WaveTimeout` (a stuck device / lost tile).
* ``slow_wave``        — the wave completes but reports ``delay_s`` of
  extra compute time: a straggling stall the adaptive controller and the
  watchdog must react to, with no real sleeping in tests.
* ``assembly_corrupt`` — assembling one request's maps raises (scatter of
  a corrupted prediction block).

Triggering is by engine wave index (``wave=``, fires **once** — a
transient infra blip) or by request id (``request_id=``, fires **every**
wave containing that request — a poisoned request that will never
succeed).  The two model exactly the cases the retry policy must split:
transients deserve a retry, poison must fail alone after its bounded
retry, and wave-mates must survive both.

``injector.fired`` logs ``(wave_index, kind)`` tuples in firing order, so
tests and the chaos smoke can assert the schedule actually ran.
"""

from __future__ import annotations

import dataclasses
from typing import Iterable, Sequence

FAULT_KINDS = ("dispatch_raise", "kernel_fail", "tile_timeout", "slow_wave",
               "assembly_corrupt")


class InjectedServeFault(RuntimeError):
    """An injected serving fault (never raised by real failures)."""


class WaveTimeout(InjectedServeFault):
    """A wave exceeded its completion budget (injected ``tile_timeout``)."""


@dataclasses.dataclass(frozen=True)
class FaultSpec:
    """One scripted fault.

    ``wave`` triggers once at that engine dispatch index; ``request_id``
    triggers persistently for every wave containing that request.  Exactly
    one of the two must be set, except ``kernel_fail`` / ``tile_timeout`` /
    ``slow_wave`` which fire at points where no request identity is in
    scope and therefore require ``wave``.
    """

    kind: str
    wave: int | None = None
    request_id: str | None = None
    delay_s: float = 0.05  # slow_wave: synthetic stall added to compute time

    def __post_init__(self):
        if self.kind not in FAULT_KINDS:
            raise ValueError(f"fault kind {self.kind!r} not in {FAULT_KINDS}")
        if (self.wave is None) == (self.request_id is None):
            raise ValueError(f"exactly one of wave / request_id must be set "
                             f"({self!r})")
        if self.kind in ("kernel_fail", "tile_timeout", "slow_wave") \
                and self.wave is None:
            raise ValueError(f"{self.kind} fires where no request identity "
                             f"is in scope; trigger it by wave= ({self!r})")


class FaultInjector:
    """Fires a deterministic fault schedule into the serving hot path.

    Accepts :class:`FaultSpec` instances or plain dicts (the launcher's
    ``--fault-schedule`` JSON).  Thread one injector through
    ``ReconEngine(injector=...)``; the engine hands it to its executor, so
    one schedule covers every injection point.
    """

    def __init__(self, schedule: Sequence):
        self._armed: list[FaultSpec] = [
            s if isinstance(s, FaultSpec) else FaultSpec(**s)
            for s in schedule]
        self.fired: list[tuple[int, str]] = []

    def n_armed(self) -> int:
        """One-shot specs still waiting to fire (persistent request_id
        specs are never disarmed and always count)."""
        return len(self._armed)

    def _take(self, kinds: tuple, wave: int,
              request_ids: Iterable[str] | None = None) -> FaultSpec | None:
        rids = set(request_ids) if request_ids is not None else None
        for i, spec in enumerate(self._armed):
            if spec.kind not in kinds:
                continue
            if spec.request_id is not None:
                # persistent: a poisoned request re-fires on every retry
                if rids is not None and spec.request_id in rids:
                    self.fired.append((wave, spec.kind))
                    return spec
            elif spec.wave == wave:
                self._armed.pop(i)  # one-shot: a transient blip
                self.fired.append((wave, spec.kind))
                return spec
        return None

    # -- injection points (called by engine/executor) ----------------------

    def fire_dispatch(self, wave: int, request_ids: Iterable[str]) -> None:
        """Engine, before staging a wave: raises for ``dispatch_raise``."""
        spec = self._take(("dispatch_raise",), wave, request_ids)
        if spec is not None:
            what = (f"poisoned request {spec.request_id!r}"
                    if spec.request_id else "transient dispatch fault")
            raise InjectedServeFault(f"injected at wave {wave}: {what}")

    def fire_kernel(self, wave: int) -> None:
        """Executor, before the wave's first tile enqueue: raises for
        ``kernel_fail`` (what trips the int8 circuit breaker)."""
        if self._take(("kernel_fail",), wave) is not None:
            raise InjectedServeFault(
                f"injected kernel failure at wave {wave}")

    def fire_wait(self, wave: int) -> FaultSpec | None:
        """Engine, before blocking on a wave: raises :class:`WaveTimeout`
        for ``tile_timeout``; returns the spec for a (non-raising)
        ``slow_wave`` stall so the caller inflates its compute-time
        observation by ``delay_s``."""
        if self._take(("tile_timeout",), wave) is not None:
            raise WaveTimeout(f"injected tile timeout at wave {wave}")
        return self._take(("slow_wave",), wave)

    def fire_assemble(self, wave: int, request_id: str) -> None:
        """Engine, before scattering one request's maps: raises for
        ``assembly_corrupt`` (by wave — first request assembled in that
        wave — or by request id)."""
        if self._take(("assembly_corrupt",), wave,
                      (request_id,)) is not None:
            raise InjectedServeFault(
                f"injected assembly corruption for request {request_id!r} "
                f"at wave {wave}")
