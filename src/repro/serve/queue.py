"""Admission layer of the recon serving stack: a persistent request queue.

The pipelined serving refactor splits ``repro.serve.recon`` into three
layers; this is the first.  A :class:`RequestQueue` outlives any single
wave — requests are *admitted* (validated once, stamped with their enqueue
time) and then *scheduled* into waves by an explicit formation policy,
instead of the engine serving whatever list one ``reconstruct`` call
happened to pass.

Request lifecycle
-----------------
Every admitted request is wrapped in a :class:`QueuedRequest` ticket that
moves through ``pending -> scheduled -> done | failed | shed``:

* ``pending``   — admitted, waiting for a wave.
* ``scheduled`` — handed to the executor as part of a formed wave.
* ``done``      — assembled into a result; ``ticket.result`` is set and
  ``ticket.latency_s`` measures **enqueue-to-assembled** time (the queue
  stamps ``enqueue_t`` at admission, so queue wait is part of the latency —
  not just time-within-wave).
* ``failed``    — rejected at admission (validator) or failed during
  execution/assembly; ``ticket.error`` carries the reason.  Failures are
  lifecycle states, never exceptions thrown out of a wave: one bad request
  cannot leave its wave-mates half-served.
* ``shed``      — rejected by the *load* policy (``serve.admission``), not
  because the request is invalid: the pending-voxel budget is exhausted,
  the estimated queue wait already exceeds the request's deadline, or a
  higher-priority arrival displaced it.  ``ticket.shed_reason`` carries a
  structured :class:`~repro.serve.admission.ShedReason` code so callers can
  tell "invalid, don't retry" (``failed``) from "overloaded, retry later"
  (``shed``) without string-matching ``ticket.error``.

Failed waves can also *requeue* tickets (``scheduled -> pending`` with
``ticket.retries`` incremented and ``ticket.solo`` set): the engine's
bounded-retry path re-admits untouched wave-mates of a crashed dispatch,
and ``solo`` tickets then form single-request waves so a poisoned request
cannot take mates down with it twice.

Wave formation policy
---------------------
``form_wave`` pops the next wave under three knobs:

* ``max_wave_voxels`` — a wave closes when admitting the next request would
  exceed this many voxels (a single oversized request still forms its own
  wave — nothing can starve).
* ``max_wait_ms``     — a deadline from *enqueue*: once the oldest pending
  ticket has waited this long, the wave is due even if small.  ``None``
  disables the deadline trigger (waves form only on the voxel trigger or an
  explicit flush).
* priority          — higher ``priority`` tickets schedule first; ties are
  FIFO in admission order.  Packing never skips over a request that does
  not fit (no starvation by reordering within a priority class), and a
  ticket past its ``max_wait_ms`` deadline is promoted to lead the next
  wave regardless of priority (no starvation by sustained
  higher-priority load).

The queue is time-source-injectable (``clock=``) so deadline behaviour is
deterministically testable.  It holds no jax state at all — staging and
compute live in ``serve.executor``; composition lives in ``serve.recon``.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable


class RequestState:
    """Lifecycle states of a :class:`QueuedRequest` ticket."""

    PENDING = "pending"
    SCHEDULED = "scheduled"
    DONE = "done"
    FAILED = "failed"
    SHED = "shed"

    #: states a ticket can never leave (every admitted ticket must end in
    #: exactly one of these — the chaos-suite property)
    TERMINAL = (DONE, FAILED, SHED)


@dataclasses.dataclass(eq=False)
class QueuedRequest:
    """One admitted request's ticket through the queue lifecycle.

    ``request`` is duck-typed: the queue only reads ``n_voxels`` and
    ``request_id`` (``serve.recon.ReconRequest`` in production).
    """

    request: object
    priority: int
    seq: int              # admission counter: the FIFO tiebreak
    enqueue_t: float
    state: str = RequestState.PENDING
    error: str | None = None
    result: object | None = None
    done_t: float | None = None
    #: structured load-shedding code (None unless state == "shed")
    shed_reason: str | None = None
    #: per-request deadline consulted by the admission policy (ms from
    #: enqueue); None falls back to the policy default
    deadline_ms: float | None = None
    #: times this ticket was requeued after a failed wave (bounded by the
    #: engine's max_retries)
    retries: int = 0
    #: requeued tickets dispatch in single-request waves: a retry must not
    #: share a wave (and its blast radius) with fresh requests
    solo: bool = False

    @property
    def latency_s(self) -> float | None:
        """Enqueue-to-assembled latency; None until the ticket is done."""
        if self.done_t is None:
            return None
        return self.done_t - self.enqueue_t


class RequestQueue:
    """Persistent admission queue with wave-formation policy.

    ``validator`` (optional) maps a request to an error string (or None);
    invalid requests are returned as ``failed`` tickets and never admitted,
    so they cannot poison a wave.

    ``admission`` (optional, a ``serve.admission.AdmissionPolicy``) is the
    *load* gate consulted after validation: it may shed the arriving ticket
    (returned already ``shed`` with a structured ``shed_reason``) or
    displace pending lower-priority tickets to make room.  Validation
    answers "is this request well-formed?"; admission answers "can we
    afford to serve it right now?" — the two rejections stay distinct
    lifecycle outcomes.
    """

    def __init__(self, *, max_wave_voxels: int | None = None,
                 max_wait_ms: float | None = None,
                 validator: Callable[[object], str | None] | None = None,
                 admission=None,
                 clock: Callable[[], float] = time.perf_counter):
        if max_wave_voxels is not None and max_wave_voxels <= 0:
            raise ValueError(f"max_wave_voxels must be positive or None, "
                             f"got {max_wave_voxels}")
        if max_wait_ms is not None and max_wait_ms < 0:
            raise ValueError(f"max_wait_ms must be >= 0 or None, "
                             f"got {max_wait_ms}")
        self.max_wave_voxels = max_wave_voxels
        self.max_wait_ms = max_wait_ms
        self._validator = validator
        self._admission = admission
        self._clock = clock
        self._pending: list[QueuedRequest] = []
        self._sorted = True  # lazily re-sorted on the next form_wave
        # running totals so wave_due is O(1) per poll: the voxel sum, and
        # the oldest pending ticket (enqueue_t is monotonic in seq, so it
        # only needs recomputing when the current oldest is popped)
        self._pending_voxels = 0
        self._oldest: QueuedRequest | None = None
        self._seq = 0
        self.n_rejected = 0
        self.n_shed = 0

    # -- admission ---------------------------------------------------------

    def submit(self, request, *, priority: int = 0, validate: bool = True,
               deadline_ms: float | None = None) -> QueuedRequest:
        """Admit one request; returns its lifecycle ticket.

        Validation happens here, once, at admission: a rejected request
        comes back already ``failed`` (with ``error`` set) and is *not*
        queued — admission of one request never raises and never affects
        requests already pending.  Callers that already validated (the
        engine's all-or-nothing batch path) pass ``validate=False`` to
        avoid paying the mask-sum check twice.

        When an admission policy is installed, a *valid* request can still
        come back ``shed`` (``shed_reason`` set) — the load-shedding
        outcome; ``deadline_ms`` is this request's wait budget for the
        policy's deadline-aware rejection (None: the policy default).
        """
        ticket = QueuedRequest(request=request, priority=int(priority),
                               seq=self._seq, enqueue_t=self._clock(),
                               deadline_ms=deadline_ms)
        self._seq += 1
        if validate and self._validator is not None:
            try:
                err = self._validator(request)
            except Exception as e:
                # a crashing validator must not break admission
                err = f"validator error: {type(e).__name__}: {e}"
            if err is not None:
                ticket.state = RequestState.FAILED
                ticket.error = err
                self.n_rejected += 1
                return ticket
        try:
            nv = int(ticket.request.n_voxels)
        except Exception as e:
            # never-raises holds even for validator-less queues fed
            # malformed duck-typed requests
            ticket.state = RequestState.FAILED
            ticket.error = (f"request has no usable n_voxels: "
                            f"{type(e).__name__}: {e}")
            self.n_rejected += 1
            return ticket
        if self._admission is not None:
            try:
                reason = self._admission.admit(ticket, nv, self)
            except Exception as e:
                # a crashing policy must not break admission either; fail
                # open (admit) would silently disable load shedding, so
                # shed with the error recorded instead
                reason = f"admission policy error: {type(e).__name__}: {e}"
            if reason is not None:
                ticket.state = RequestState.SHED
                ticket.shed_reason = reason
                ticket.error = f"shed at admission: {reason}"
                self.n_shed += 1
                return ticket
        self._pending.append(ticket)
        self._pending_voxels += nv
        if self._oldest is None:  # new tickets are never older
            self._oldest = ticket
        self._sorted = False
        return ticket

    def requeue(self, ticket: QueuedRequest) -> None:
        """Return a previously scheduled ticket to the pending pool.

        The engine's bounded-retry path: wave-mates of a crashed dispatch
        come back here (``retries`` already incremented by the engine) and
        keep their original ``seq``/``enqueue_t``, so FIFO position and
        latency accounting survive the retry.
        """
        if ticket.state != RequestState.SCHEDULED:
            raise ValueError(f"only scheduled tickets can requeue, got "
                             f"{ticket.state!r}")
        ticket.state = RequestState.PENDING
        self._pending.append(ticket)
        self._pending_voxels += int(ticket.request.n_voxels)
        self._sorted = False
        # enqueue_t is monotone in seq, so min-seq is again the oldest
        if self._oldest is None or ticket.seq < self._oldest.seq:
            self._oldest = ticket

    def shed_pending(self, tickets: list, reason: str) -> None:
        """Shed already-pending tickets (the displacement path): each moves
        to the ``shed`` terminal state with ``reason`` recorded."""
        ids = {id(t) for t in tickets}
        if not ids:
            return
        self._pending = [t for t in self._pending if id(t) not in ids]
        for t in tickets:
            self._pending_voxels -= int(t.request.n_voxels)
            t.state = RequestState.SHED
            t.shed_reason = reason
            t.error = f"shed while pending: {reason}"
            self.n_shed += 1
        if self._oldest is not None and id(self._oldest) in ids:
            self._oldest = (min(self._pending, key=lambda t: t.seq)
                            if self._pending else None)

    def pending_tickets(self) -> tuple:
        """Read-only view of the pending pool (admission policies inspect
        priorities/sizes here to pick displacement victims)."""
        return tuple(self._pending)

    # -- introspection -----------------------------------------------------

    @property
    def n_pending(self) -> int:
        return len(self._pending)

    def __len__(self) -> int:
        return len(self._pending)

    def pending_voxels(self) -> int:
        return self._pending_voxels

    def oldest_wait_s(self, now: float | None = None) -> float:
        """Seconds the longest-waiting pending ticket has been queued."""
        if self._oldest is None:
            return 0.0
        now = self._clock() if now is None else now
        return now - self._oldest.enqueue_t

    def wave_due(self, now: float | None = None) -> bool:
        """True when the formation policy says the next wave should go:
        the voxel budget is reached, or the oldest ticket hit its deadline."""
        if not self._pending:
            return False
        if (self.max_wave_voxels is not None
                and self.pending_voxels() >= self.max_wave_voxels):
            return True
        if self.max_wait_ms is not None:
            return self.oldest_wait_s(now) * 1e3 >= self.max_wait_ms
        return False

    # -- wave formation ----------------------------------------------------

    def form_wave(self, *, now: float | None = None,
                  flush: bool = False) -> list[QueuedRequest]:
        """Pop the next wave of tickets (marked ``scheduled``), or ``[]``.

        Without ``flush`` a wave forms only when :meth:`wave_due`; with it
        (the drain path) the policy triggers are bypassed but the voxel cap
        still bounds each wave.  Order is (-priority, admission seq); the
        cap closes the wave at the first request that does not fit — except
        that a wave always takes at least one request, so an oversized
        request is served alone rather than starved.  Deadline promotion
        guards the other starvation mode: once the oldest pending ticket
        exceeds ``max_wait_ms``, it leads the next wave regardless of
        priority, so sustained higher-priority load cannot park it forever.
        """
        if not self._pending:
            return []
        now = self._clock() if now is None else now
        if not flush and not self.wave_due(now):
            return []
        if not self._sorted:
            # one sort per backlog change, not per wave: waves pop a prefix,
            # which keeps the remainder ordered for the next form_wave
            self._pending.sort(key=lambda t: (-t.priority, t.seq))
            self._sorted = True
        cand = self._pending
        promoted = (self.max_wait_ms is not None
                    and self.oldest_wait_s(now) * 1e3 >= self.max_wait_ms
                    and cand[0] is not self._oldest)
        if promoted:
            cand = [self._oldest] + [t for t in cand
                                     if t is not self._oldest]
        wave: list[QueuedRequest] = []
        voxels = 0
        for ticket in cand:
            nv = ticket.request.n_voxels
            # solo (retry) tickets ride alone: a requeued request must not
            # share its blast radius with fresh wave-mates again
            if wave and (ticket.solo or wave[0].solo):
                break
            if (wave and self.max_wave_voxels is not None
                    and voxels + nv > self.max_wave_voxels):
                break
            wave.append(ticket)
            voxels += nv
        if promoted:
            # the wave is no longer a prefix of the sorted pending list;
            # removing a subset of a sorted list keeps it sorted
            ids = {id(t) for t in wave}
            self._pending = [t for t in self._pending if id(t) not in ids]
        else:
            self._pending = self._pending[len(wave):]
        self._pending_voxels -= voxels
        for ticket in wave:
            ticket.state = RequestState.SCHEDULED
        if self._oldest in wave:  # amortized: recompute only when popped
            self._oldest = (min(self._pending, key=lambda t: t.seq)
                            if self._pending else None)
        return wave
