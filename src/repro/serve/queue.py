"""Admission layer of the recon serving stack: a persistent request queue.

The pipelined serving refactor splits ``repro.serve.recon`` into three
layers; this is the first.  A :class:`RequestQueue` outlives any single
wave — requests are *admitted* (validated once, stamped with their enqueue
time) and then *scheduled* into waves by an explicit formation policy,
instead of the engine serving whatever list one ``reconstruct`` call
happened to pass.

Request lifecycle
-----------------
Every admitted request is wrapped in a :class:`QueuedRequest` ticket that
moves through ``pending -> scheduled -> done | failed``:

* ``pending``   — admitted, waiting for a wave.
* ``scheduled`` — handed to the executor as part of a formed wave.
* ``done``      — assembled into a result; ``ticket.result`` is set and
  ``ticket.latency_s`` measures **enqueue-to-assembled** time (the queue
  stamps ``enqueue_t`` at admission, so queue wait is part of the latency —
  not just time-within-wave).
* ``failed``    — rejected at admission (validator) or failed during
  assembly; ``ticket.error`` carries the reason.  Failures are lifecycle
  states, never exceptions thrown out of a wave: one bad request cannot
  leave its wave-mates half-served.

Wave formation policy
---------------------
``form_wave`` pops the next wave under three knobs:

* ``max_wave_voxels`` — a wave closes when admitting the next request would
  exceed this many voxels (a single oversized request still forms its own
  wave — nothing can starve).
* ``max_wait_ms``     — a deadline from *enqueue*: once the oldest pending
  ticket has waited this long, the wave is due even if small.  ``None``
  disables the deadline trigger (waves form only on the voxel trigger or an
  explicit flush).
* priority          — higher ``priority`` tickets schedule first; ties are
  FIFO in admission order.  Packing never skips over a request that does
  not fit (no starvation by reordering within a priority class), and a
  ticket past its ``max_wait_ms`` deadline is promoted to lead the next
  wave regardless of priority (no starvation by sustained
  higher-priority load).

The queue is time-source-injectable (``clock=``) so deadline behaviour is
deterministically testable.  It holds no jax state at all — staging and
compute live in ``serve.executor``; composition lives in ``serve.recon``.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable


class RequestState:
    """Lifecycle states of a :class:`QueuedRequest` ticket."""

    PENDING = "pending"
    SCHEDULED = "scheduled"
    DONE = "done"
    FAILED = "failed"


@dataclasses.dataclass(eq=False)
class QueuedRequest:
    """One admitted request's ticket through the queue lifecycle.

    ``request`` is duck-typed: the queue only reads ``n_voxels`` and
    ``request_id`` (``serve.recon.ReconRequest`` in production).
    """

    request: object
    priority: int
    seq: int              # admission counter: the FIFO tiebreak
    enqueue_t: float
    state: str = RequestState.PENDING
    error: str | None = None
    result: object | None = None
    done_t: float | None = None

    @property
    def latency_s(self) -> float | None:
        """Enqueue-to-assembled latency; None until the ticket is done."""
        if self.done_t is None:
            return None
        return self.done_t - self.enqueue_t


class RequestQueue:
    """Persistent admission queue with wave-formation policy.

    ``validator`` (optional) maps a request to an error string (or None);
    invalid requests are returned as ``failed`` tickets and never admitted,
    so they cannot poison a wave.
    """

    def __init__(self, *, max_wave_voxels: int | None = None,
                 max_wait_ms: float | None = None,
                 validator: Callable[[object], str | None] | None = None,
                 clock: Callable[[], float] = time.perf_counter):
        if max_wave_voxels is not None and max_wave_voxels <= 0:
            raise ValueError(f"max_wave_voxels must be positive or None, "
                             f"got {max_wave_voxels}")
        if max_wait_ms is not None and max_wait_ms < 0:
            raise ValueError(f"max_wait_ms must be >= 0 or None, "
                             f"got {max_wait_ms}")
        self.max_wave_voxels = max_wave_voxels
        self.max_wait_ms = max_wait_ms
        self._validator = validator
        self._clock = clock
        self._pending: list[QueuedRequest] = []
        self._sorted = True  # lazily re-sorted on the next form_wave
        # running totals so wave_due is O(1) per poll: the voxel sum, and
        # the oldest pending ticket (enqueue_t is monotonic in seq, so it
        # only needs recomputing when the current oldest is popped)
        self._pending_voxels = 0
        self._oldest: QueuedRequest | None = None
        self._seq = 0
        self.n_rejected = 0

    # -- admission ---------------------------------------------------------

    def submit(self, request, *, priority: int = 0,
               validate: bool = True) -> QueuedRequest:
        """Admit one request; returns its lifecycle ticket.

        Validation happens here, once, at admission: a rejected request
        comes back already ``failed`` (with ``error`` set) and is *not*
        queued — admission of one request never raises and never affects
        requests already pending.  Callers that already validated (the
        engine's all-or-nothing batch path) pass ``validate=False`` to
        avoid paying the mask-sum check twice.
        """
        ticket = QueuedRequest(request=request, priority=int(priority),
                               seq=self._seq, enqueue_t=self._clock())
        self._seq += 1
        if validate and self._validator is not None:
            try:
                err = self._validator(request)
            except Exception as e:
                # a crashing validator must not break admission
                err = f"validator error: {type(e).__name__}: {e}"
            if err is not None:
                ticket.state = RequestState.FAILED
                ticket.error = err
                self.n_rejected += 1
                return ticket
        try:
            nv = int(ticket.request.n_voxels)
        except Exception as e:
            # never-raises holds even for validator-less queues fed
            # malformed duck-typed requests
            ticket.state = RequestState.FAILED
            ticket.error = (f"request has no usable n_voxels: "
                            f"{type(e).__name__}: {e}")
            self.n_rejected += 1
            return ticket
        self._pending.append(ticket)
        self._pending_voxels += nv
        if self._oldest is None:  # new tickets are never older
            self._oldest = ticket
        self._sorted = False
        return ticket

    # -- introspection -----------------------------------------------------

    @property
    def n_pending(self) -> int:
        return len(self._pending)

    def __len__(self) -> int:
        return len(self._pending)

    def pending_voxels(self) -> int:
        return self._pending_voxels

    def oldest_wait_s(self, now: float | None = None) -> float:
        """Seconds the longest-waiting pending ticket has been queued."""
        if self._oldest is None:
            return 0.0
        now = self._clock() if now is None else now
        return now - self._oldest.enqueue_t

    def wave_due(self, now: float | None = None) -> bool:
        """True when the formation policy says the next wave should go:
        the voxel budget is reached, or the oldest ticket hit its deadline."""
        if not self._pending:
            return False
        if (self.max_wave_voxels is not None
                and self.pending_voxels() >= self.max_wave_voxels):
            return True
        if self.max_wait_ms is not None:
            return self.oldest_wait_s(now) * 1e3 >= self.max_wait_ms
        return False

    # -- wave formation ----------------------------------------------------

    def form_wave(self, *, now: float | None = None,
                  flush: bool = False) -> list[QueuedRequest]:
        """Pop the next wave of tickets (marked ``scheduled``), or ``[]``.

        Without ``flush`` a wave forms only when :meth:`wave_due`; with it
        (the drain path) the policy triggers are bypassed but the voxel cap
        still bounds each wave.  Order is (-priority, admission seq); the
        cap closes the wave at the first request that does not fit — except
        that a wave always takes at least one request, so an oversized
        request is served alone rather than starved.  Deadline promotion
        guards the other starvation mode: once the oldest pending ticket
        exceeds ``max_wait_ms``, it leads the next wave regardless of
        priority, so sustained higher-priority load cannot park it forever.
        """
        if not self._pending:
            return []
        now = self._clock() if now is None else now
        if not flush and not self.wave_due(now):
            return []
        if not self._sorted:
            # one sort per backlog change, not per wave: waves pop a prefix,
            # which keeps the remainder ordered for the next form_wave
            self._pending.sort(key=lambda t: (-t.priority, t.seq))
            self._sorted = True
        cand = self._pending
        promoted = (self.max_wait_ms is not None
                    and self.oldest_wait_s(now) * 1e3 >= self.max_wait_ms
                    and cand[0] is not self._oldest)
        if promoted:
            cand = [self._oldest] + [t for t in cand
                                     if t is not self._oldest]
        wave: list[QueuedRequest] = []
        voxels = 0
        for ticket in cand:
            nv = ticket.request.n_voxels
            if (wave and self.max_wave_voxels is not None
                    and voxels + nv > self.max_wave_voxels):
                break
            wave.append(ticket)
            voxels += nv
        if promoted:
            # the wave is no longer a prefix of the sorted pending list;
            # removing a subset of a sorted list keeps it sorted
            ids = {id(t) for t in wave}
            self._pending = [t for t in self._pending if id(t) not in ids]
        else:
            self._pending = self._pending[len(wave):]
        self._pending_voxels -= voxels
        for ticket in wave:
            ticket.state = RequestState.SCHEDULED
        if self._oldest in wave:  # amortized: recompute only when popped
            self._oldest = (min(self._pending, key=lambda t: t.seq)
                            if self._pending else None)
        return wave
