"""Execution layer of the recon serving stack: the double-buffered
asynchronous wave executor.

The double-buffering contract
-----------------------------
:class:`WaveExecutor` separates *dispatch* from *completion* so the engine
can keep more than one wave in flight:

* :meth:`WaveExecutor.dispatch` stages one wave's voxel pool onto the
  device (a single concatenate that also pads the ragged tail up to its
  bucket — pad-to-bucket is a device op fused into staging, not host-side
  per-tile logic), enqueues every bucket tile on the jitted forward, and
  returns an :class:`InflightWave` **without blocking**.  jax's async
  dispatch means the host comes back as soon as the work is queued, so the
  caller is free to stage + dispatch wave N+1 while the device is still
  computing wave N — that host->device transfer / device compute overlap is
  the entire point of the layer.
* :meth:`InflightWave.wait` performs **one** host sync for the whole wave
  (a single ``jax.block_until_ready`` over the trailing futures list) and
  only then copies results to host memory.  There is deliberately no
  per-tile sync anywhere on this path — tests assert it.
* :meth:`InflightWave.wait_tiles` is the synchronous baseline: it syncs
  tile by tile (the pre-refactor engine behaviour), which gives each
  request its true completion time within the wave at the cost of stalling
  dispatch.  ``ReconEngine(mode="sync")`` uses it; benchmarks compare the
  two on the same trace.

Shape discipline is unchanged from the monolithic engine: tiles come from
:func:`plan_tiles` over a fixed bucket set, every tile the jitted forward
sees has shape ``(bucket, in_dim)``, so the jit cache stays bounded by
``len(buckets)`` (``cache_size`` — via the ``kernels.common.jit_cache_size``
wrapper — must never exceed it).  The bucket batch axis keeps its
``dist.shard`` annotation, so the same executor serves mesh-less or
data-parallel; build it inside ``use_rules(...)`` — ambient rules are
captured at first trace of each bucket shape.  Float and int8 backends run
the exact arithmetic the monolithic engine ran, so pipelined serving is
bit-identical to sync serving.

Graceful degradation
--------------------
The int8 fused whole-network kernel is the TPU deployment path — and the
component most likely to break first on a driver/runtime regression.  The
executor carries a **circuit breaker**: when the fused forward raises (at
tile enqueue here, or asynchronously at the wave wait — the engine reports
those via :meth:`note_kernel_failure`), ``breaker_threshold`` failures trip
the breaker and the executor rebuilds its forward on the pure-lax int8
impl.  PR 7's parity proof makes that fallback **bit-exact**, so degraded
waves serve identical maps at reduced throughput instead of serving
nothing; ``degraded`` / ``degraded_reason`` / ``n_degraded_waves`` record
the event for health reporting.  Fault schedules (``serve.faults``) can
fire a ``kernel_fail`` here deterministically to test the breaker.
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import mrf_net
from repro.data.pipeline import T1_RANGE_MS, T2_RANGE_MS, denormalize_targets
from repro.dist.sharding import shard
from repro.kernels.common import jit_cache_size, resolve_int8_impl
from repro.kernels.qat_dense.ops import (int_forward_fused, int_forward_lax,
                                         int_forward_pallas,
                                         prepad_int_layers)

BACKENDS = ("float", "int8")

# Power-of-two multiples of the 128-lane MXU tile: four shapes cover any
# request mix (full tiles at 1024, tail padded to the smallest fit).  This
# is the *fallback* set — production deployments derive the bucket set from
# the recorded request-size distribution instead (``executor.request_sizes``
# feeds ``benchmarks.serve_autotune``, which measures per-bucket tile cost
# on the rig and picks the set minimizing wall time over the trace).
DEFAULT_BUCKETS = (128, 256, 512, 1024)


def plan_tiles(n: int, buckets: Sequence[int]) -> list:
    """Tile ``n`` voxels into (offset, count, bucket) micro-batches.

    Full tiles use the largest bucket; the remainder uses the smallest
    bucket that fits (padded by the executor).  Covers [0, n) exactly.
    """
    buckets = sorted(int(b) for b in buckets)
    if not buckets or buckets[0] <= 0:
        raise ValueError(f"buckets must be positive: {buckets}")
    bmax = buckets[-1]
    tiles = []
    off = 0
    while n - off >= bmax:
        tiles.append((off, bmax, bmax))
        off += bmax
    rem = n - off
    if rem:
        fit = next(b for b in buckets if b >= rem)
        tiles.append((off, rem, fit))
    return tiles


@dataclasses.dataclass(eq=False)
class InflightWave:
    """Handle to one dispatched wave: device futures + the tile plan.

    ``outputs[i]`` is the (bucket, 2) device array of denormalized
    (T1 ms, T2 ms) predictions for ``tiles[i]``; only the first ``count``
    rows of each are real voxels.
    """

    tiles: list          # (offset, count, bucket) in pool coordinates
    outputs: list        # per-tile device arrays, still in flight
    total: int           # real (unpadded) voxel count of the wave

    @property
    def n_tiles(self) -> int:
        return len(self.tiles)

    def wait(self) -> np.ndarray:
        """Block once for the whole wave; return the (total, 2) predictions.

        Exactly one host sync (``jax.block_until_ready`` over the futures
        list) regardless of tile count — the pipelined path's contract.
        """
        if self.outputs:
            jax.block_until_ready(self.outputs)
        pred = np.empty((self.total, 2), np.float32)
        for (off, count, _), out in zip(self.tiles, self.outputs):
            pred[off:off + count] = np.asarray(out)[:count]
        return pred

    def wait_tiles(self):
        """Per-tile sync generator: yields (offset, count, block) as each
        tile lands.  The synchronous baseline — one host sync per tile."""
        for (off, count, _), out in zip(self.tiles, self.outputs):
            yield off, count, np.asarray(jax.block_until_ready(out))[:count]


class WaveExecutor:
    """Dispatches voxel waves through the jitted per-bucket forward.

    ``backend="float"`` needs ``params`` (the mrf_net pytree);
    ``backend="int8"`` needs ``int_layers`` (a ``qat.export_int8`` /
    ``qat.load_int8_artifact`` list).  ``interpret=None`` auto-detects the
    Pallas mode (compiled on TPU, interpreter elsewhere).

    ``int8_impl`` picks the full-integer implementation (``None`` = fastest
    for the rig, see ``kernels.common.resolve_int8_impl``): ``"fused"`` is
    the whole-network Pallas kernel — weights pre-padded once here at
    artifact load, resident in VMEM across the forward, denormalize fused
    into the kernel epilogue; ``"lax"`` is the vectorized pure-lax forward
    (no Pallas dispatch — the fast path on CPU/GPU where the interpreter
    would be the bottleneck); ``"layered"`` is the original per-layer
    kernel chain.  All three serve bit-identical maps (tested against the
    ``qat.int_forward`` oracle).  ``int8_block_m`` sets the fused kernel's
    voxel-tile granule (default: one tile per bucket, capped at 512).
    """

    def __init__(self, *, backend: str = "float", params=None, int_layers=None,
                 buckets: Sequence[int] = DEFAULT_BUCKETS,
                 interpret: bool | None = None, int8_impl: str | None = None,
                 int8_block_m: int | None = None, injector=None,
                 breaker_threshold: int = 1):
        if backend not in BACKENDS:
            raise ValueError(f"backend {backend!r} not in {BACKENDS}")
        if backend == "float" and params is None:
            raise ValueError("float backend needs params")
        if backend == "int8" and int_layers is None:
            raise ValueError("int8 backend needs int_layers "
                             "(qat.export_int8 or qat.load_int8_artifact)")
        self.backend = backend
        self.params = params
        self.int_layers = int_layers
        self.buckets = tuple(sorted(int(b) for b in buckets))
        self.interpret = interpret
        self.int8_impl = (resolve_int8_impl(int8_impl)
                          if backend == "int8" else None)
        self.int8_block_m = int8_block_m
        # weights are static: pad K/N to the MXU grid exactly once at
        # artifact load (the per-call cost is then M-only padding)
        self._prepadded = (prepad_int_layers(int_layers)
                           if backend == "int8" else None)
        self.in_dim = int(params[0]["w"].shape[0] if backend == "float"
                          else int_layers[0].w_q.shape[0])
        self._fwd = self._make_forward()
        self.bucket_shapes_run: set = set()
        # recorded request-size distribution (voxel counts of every request
        # dispatched) — the input to measured bucket autotuning
        self.request_sizes: list = []
        # fault injection + the fused->lax circuit breaker (see module doc)
        if breaker_threshold < 1:
            raise ValueError(f"breaker_threshold must be >= 1, "
                             f"got {breaker_threshold}")
        self._injector = injector
        self.breaker_threshold = breaker_threshold
        self.degraded = False
        self.degraded_reason: str | None = None
        self.n_kernel_failures = 0
        self.n_degraded_waves = 0
        self._wave_seq = 0  # fallback wave numbering for direct callers

    def _make_forward(self):
        # denormalization stays centralized in data.pipeline
        # .denormalize_targets but runs *inside* the jitted forward (or the
        # fused kernel's epilogue): the elementwise rescale fuses on device,
        # so tile outputs are already (T1, T2) in ms and each wave crosses
        # the host boundary exactly once (no post-sync round-trip to rescale)
        if self.backend == "float":
            params = self.params

            def fwd(x):
                return denormalize_targets(
                    mrf_net.forward(params, shard(x, "batch", None)))
        elif self.int8_impl == "fused":
            pre, interp = self._prepadded, self.interpret
            block_m = self.int8_block_m or 512
            # the same (T1_max, T2_max) row denormalize_targets applies,
            # multiplied after the head scale inside the kernel — bit-exact
            # vs composing denormalize_targets outside (tested)
            dscale = jnp.array([T1_RANGE_MS[1], T2_RANGE_MS[1]], jnp.float32)

            def fwd(x):
                return int_forward_fused(pre, shard(x, "batch", None),
                                         block_m=block_m, interpret=interp,
                                         denorm_scale=dscale)
        elif self.int8_impl == "lax":
            ints = self.int_layers

            def fwd(x):
                return denormalize_targets(
                    int_forward_lax(ints, shard(x, "batch", None)))  # jaxlint: disable=HOSTSYNC -- the exactness probe reads concrete weights once at trace time, not per step
        else:  # "layered": per-layer kernel chain on the prepadded net
            ints, interp, pre = self.int_layers, self.interpret, self._prepadded

            def fwd(x):
                return denormalize_targets(
                    int_forward_pallas(ints, shard(x, "batch", None),
                                       interpret=interp, prepadded=pre))
        return jax.jit(fwd)

    def cache_size(self) -> int:
        """Distinct bucket shapes traced so far; bounded by ``len(buckets)``
        (the no-recompile property).  Tolerant of jit-internals drift."""
        return jit_cache_size(self._fwd,
                              fallback=len(self.bucket_shapes_run))

    # -- staging + dispatch ------------------------------------------------

    def stage(self, features_list: Sequence) -> tuple:  # jaxlint: disable=SHARD -- sharding happens in self._fwd (the _make_forward closures), a stored callable the resolver cannot follow
        """Host->device staging of one wave: returns (pool, tiles, total).

        One device op builds the whole pool: the per-request feature blocks
        *and* the zero rows that pad the ragged tail to its bucket are
        concatenated together, so pad-to-bucket happens on the device as
        part of staging and every tile is then a static-shape slice.
        """
        counts = [int(f.shape[0]) for f in features_list]
        self.request_sizes.extend(counts)
        total = sum(counts)
        tiles = plan_tiles(total, self.buckets)
        padded_total = (tiles[-1][0] + tiles[-1][2]) if tiles else 0
        parts = [jnp.asarray(f, jnp.float32) for f in features_list]
        if padded_total > total:
            parts.append(jnp.zeros((padded_total - total, self.in_dim),
                                   jnp.float32))
        if parts:
            pool = (jnp.concatenate(parts, axis=0) if len(parts) > 1
                    else parts[0])
        else:
            pool = jnp.zeros((0, self.in_dim), jnp.float32)
        return pool, tiles, total

    # -- degradation (the circuit breaker) ---------------------------------

    def can_degrade(self) -> bool:
        """True while a fallback impl exists for this executor's forward
        (the fused int8 kernel degrades to the bit-exact lax impl)."""
        return (self.backend == "int8" and self.int8_impl == "fused"
                and not self.degraded)

    def note_kernel_failure(self) -> bool:
        """Record one forward failure; trips the breaker onto the lax
        fallback once ``breaker_threshold`` failures accumulate and a
        fallback exists.  Returns True iff the executor is (now) degraded.

        Called internally when a tile enqueue raises, and by the engine
        when a wave's *wait* raises (jax dispatch is async, so a kernel
        failure can surface at either point).
        """
        self.n_kernel_failures += 1
        if (self.can_degrade()
                and self.n_kernel_failures >= self.breaker_threshold):
            self.degraded = True
            self.degraded_reason = (
                f"int8 fused kernel failed {self.n_kernel_failures}x; "
                f"circuit breaker tripped to the lax impl (bit-exact by "
                f"the PR 7 parity proof)")
            self.int8_impl = "lax"
            self._fwd = self._make_forward()
        return self.degraded

    def dispatch(self, features_list: Sequence, *,  # jaxlint: disable=SHARD -- sharding happens in self._fwd (the _make_forward closures), a stored callable the resolver cannot follow
                 wave_index: int | None = None) -> InflightWave:
        """Stage one wave and enqueue all its tiles; never blocks.

        The returned handle's outputs are device futures: call ``wait()``
        (pipelined, one sync) or iterate ``wait_tiles()`` (sync baseline).
        ``wave_index`` labels the wave for fault schedules (the engine
        passes its dispatch sequence number; direct callers get an
        internal counter).  A forward that raises at enqueue feeds the
        circuit breaker: if a bit-exact fallback exists the failing tile
        is re-enqueued degraded and the wave still completes.
        """
        pool, tiles, total = self.stage(features_list)
        widx = self._wave_seq if wave_index is None else wave_index
        self._wave_seq = widx + 1
        outputs = []
        for off, _count, bucket in tiles:
            # only the trailing tile is padded, so pool offsets == voxel
            # offsets and every slice is a static (bucket, in_dim) view
            tile = pool[off:off + bucket]
            try:
                if self._injector is not None:
                    self._injector.fire_kernel(widx)
                out = self._fwd(tile)
            except Exception:
                if not self.note_kernel_failure():
                    raise  # no fallback (float / already-lax): engine retries
                out = self._fwd(tile)  # degraded forward, bit-exact maps
            outputs.append(out)
            self.bucket_shapes_run.add(bucket)
        if self.degraded:
            self.n_degraded_waves += 1
        return InflightWave(tiles=tiles, outputs=outputs, total=total)
