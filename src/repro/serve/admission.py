"""Load-management policies for the recon serving stack: admission control
with load shedding, and the adaptive pipelining controller.

Millions-of-users serving dies two ways under overload: the queue grows
without bound (latency collapse — every request eventually violates its
deadline, but only after burning memory and compute on work nobody will
wait for), or a partial failure takes out whole waves.  This module is the
first answer: **reject early, cheaply, and legibly**.

:class:`AdmissionPolicy` is consulted by ``RequestQueue.submit`` after
validation.  It can shed an arriving request for three structured reasons
(:class:`ShedReason`):

* ``QUEUE_FULL`` — admitting it would exceed the pending-voxel budget
  (``max_pending_voxels``), the hard bound on queue memory and backlog.
* ``DEADLINE`` — the *estimated* queue wait (pending voxels over the
  observed service rate, an :class:`~repro.ft.straggler.Ewma` fed by the
  engine at every wave retire) already exceeds the request's deadline.
  Rejection beats queue collapse: the caller learns "retry later" in
  microseconds instead of a guaranteed deadline miss in seconds.
* ``DISPLACED`` — a higher-priority arrival evicted pending lower-priority
  tickets to make room (priority-aware shedding; off via ``displace=False``).

Shedding is a *lifecycle outcome*, never an exception: the ticket comes
back in the ``shed`` terminal state with ``shed_reason`` set, distinct from
``failed`` (invalid request / runtime error), so clients can branch on
"overloaded, retry with backoff" vs "bad request, don't".

:class:`AdaptiveController` closes the ROADMAP's fixed-knob gap: it tracks
per-wave staging-vs-compute overlap with the same EWMA the training
straggler watchdog uses and auto-tunes ``inflight_depth`` (deepen the
pipeline while staging is not hidden under compute, shrink it when the
device starves the host) and the wave voxel cap (sized so one wave costs
``target_wave_ms`` of device time; stalls halve it), both clamped to safe
bounds.  Pure host-side arithmetic — no jax state, deterministic under an
injected clock, unit-testable with synthetic timings.
"""

from __future__ import annotations

import dataclasses

from repro.ft.straggler import Ewma

#: wave caps snap to the 128-lane MXU grid the bucket tiling is built on
LANE = 128


class ShedReason:
    """Structured load-shedding codes recorded on ``ticket.shed_reason``."""

    QUEUE_FULL = "queue_full"          # pending-voxel budget exhausted
    DEADLINE = "deadline_unmeetable"   # est. queue wait > request deadline
    DISPLACED = "displaced_by_priority"  # evicted for a higher-priority job

    ALL = (QUEUE_FULL, DEADLINE, DISPLACED)


@dataclasses.dataclass
class AdmissionPolicy:
    """Admission gate with bounded backlog, deadline-aware rejection, and
    priority displacement.

    ``max_pending_voxels`` bounds the queue's total pending work; a request
    that would exceed it is shed (``QUEUE_FULL``) unless ``displace`` is on
    and enough strictly-lower-priority pending work can be shed
    (``DISPLACED``) to make room.  Note the budget must exceed the largest
    single request, or that request can never be admitted — the bound is
    deliberately hard (bounded memory is the point).

    ``deadline_ms`` is the default per-request wait budget (a ticket's own
    ``deadline_ms`` overrides it): once the observed service rate is known,
    a request whose estimated queue wait exceeds its deadline is shed
    (``DEADLINE``) instead of being queued into a guaranteed miss.  The
    rate estimate is an :class:`Ewma` over ``served_voxels / wave_seconds``
    fed by ``observe_service`` at every wave retire; until the first wave
    retires no estimate exists and the deadline check abstains.
    """

    max_pending_voxels: int | None = None
    deadline_ms: float | None = None
    displace: bool = True
    rate_alpha: float = 0.7
    _rate: Ewma | None = None

    def __post_init__(self):
        if self._rate is None:
            self._rate = Ewma(alpha=self.rate_alpha)

    # -- service-rate feedback (engine calls this at wave retire) ----------

    def observe_service(self, n_voxels: int, seconds: float) -> None:
        """Fold one retired wave's throughput into the rate estimate."""
        if n_voxels > 0 and seconds > 0:
            self._rate.update(n_voxels / seconds)

    @property
    def service_rate(self) -> float | None:
        """Observed voxels/s EWMA; None until the first wave retires."""
        return self._rate.value

    def estimated_wait_s(self, pending_voxels: int) -> float | None:
        """Predicted queue wait for work arriving behind ``pending_voxels``
        of backlog; None while the service rate is unknown."""
        if not self._rate.value:
            return None
        return pending_voxels / self._rate.value

    # -- the gate ----------------------------------------------------------

    def admit(self, ticket, n_voxels: int, queue) -> str | None:
        """Decide one arrival: None admits; a :class:`ShedReason` code sheds.

        May mutate ``queue`` (via ``shed_pending``) when displacement frees
        budget for a higher-priority arrival — in that case the arrival is
        admitted and the displaced tickets are the ones shed.
        """
        deadline = (ticket.deadline_ms if ticket.deadline_ms is not None
                    else self.deadline_ms)
        if deadline is not None:
            est = self.estimated_wait_s(queue.pending_voxels())
            if est is not None and est * 1e3 > deadline:
                return ShedReason.DEADLINE
        if (self.max_pending_voxels is not None
                and queue.pending_voxels() + n_voxels
                > self.max_pending_voxels):
            if self.displace:
                victims = self._displacement_victims(ticket, n_voxels, queue)
                if victims is not None:
                    queue.shed_pending(victims, ShedReason.DISPLACED)
                    return None
            return ShedReason.QUEUE_FULL
        return None

    def _displacement_victims(self, ticket, n_voxels: int, queue):
        """Pick pending tickets of strictly lower priority to shed so
        ``ticket`` fits the budget; None when they can't free enough.
        Victims are lowest-priority-first, newest-first within a class —
        the work least likely to be missed and the cheapest broken promise.
        """
        need = queue.pending_voxels() + n_voxels - self.max_pending_voxels
        victims, freed = [], 0
        cands = sorted((t for t in queue.pending_tickets()
                        if t.priority < ticket.priority),
                       key=lambda t: (t.priority, -t.seq))
        for t in cands:
            if freed >= need:
                break
            victims.append(t)
            freed += int(t.request.n_voxels)
        return victims if freed >= need else None


def _lane_floor(n: float, lo: int, hi: int) -> int:
    """Clamp to [lo, hi] and snap down onto the 128-lane grid."""
    n = max(lo, min(hi, int(n)))
    return max(lo, (n // LANE) * LANE)


@dataclasses.dataclass
class AdaptiveController:
    """Auto-tunes ``inflight_depth`` and the wave voxel cap from observed
    per-wave staging/compute overlap, clamped to safe bounds.

    Fed once per retired wave by the engine (``observe``), it keeps three
    EWMAs — host staging seconds, device compute seconds, and compute
    voxels/s — and applies two deterministic rules:

    * **depth** — pipelining exists to hide host staging under device
      compute.  While staging costs more than ``grow_ratio`` of compute,
      one extra in-flight wave buys real overlap: deepen (up to
      ``max_depth``).  Once staging is under ``shrink_ratio`` of compute
      the extra depth only adds queue latency ahead of the device: shrink
      (down to ``min_depth``).
    * **wave cap** — sized so one wave costs ``target_wave_ms`` of device
      time at the observed rate (big enough to amortize dispatch, small
      enough that a wave is a latency quantum, not a convoy), snapped to
      the 128-lane grid and clamped to [min_wave_voxels, max_wave_voxels].
      A stalled wave (watchdog timeout / injected slow-wave fault) halves
      the cap instead — smaller waves bound the damage a stall does while
      the EWMA recovers.

    ``target_wave_ms=None`` disables cap tuning (stalls still shrink).
    """

    min_depth: int = 1
    max_depth: int = 4
    min_wave_voxels: int = LANE
    max_wave_voxels: int = 1 << 16
    target_wave_ms: float | None = 50.0
    grow_ratio: float = 0.5
    shrink_ratio: float = 0.1
    alpha: float = 0.7
    depth: int = 2
    wave_voxels: int | None = None

    _staging: Ewma | None = None
    _compute: Ewma | None = None
    _rate: Ewma | None = None

    def __post_init__(self):
        if self.min_depth < 1 or self.max_depth < self.min_depth:
            raise ValueError(f"need 1 <= min_depth <= max_depth, got "
                             f"[{self.min_depth}, {self.max_depth}]")
        if self.min_wave_voxels < 1 or \
                self.max_wave_voxels < self.min_wave_voxels:
            raise ValueError(
                f"need 1 <= min_wave_voxels <= max_wave_voxels, got "
                f"[{self.min_wave_voxels}, {self.max_wave_voxels}]")
        self.depth = max(self.min_depth, min(self.max_depth, self.depth))
        if self.wave_voxels is not None:
            self.wave_voxels = _lane_floor(
                self.wave_voxels, self.min_wave_voxels, self.max_wave_voxels)
        for name in ("_staging", "_compute", "_rate"):
            if getattr(self, name) is None:
                setattr(self, name, Ewma(alpha=self.alpha))

    def observe(self, *, staging_s: float, compute_s: float, n_voxels: int,
                stalled: bool = False) -> tuple:
        """Fold one retired wave in; returns the tuned ``(depth,
        wave_voxels)`` (wave_voxels None while cap tuning is inactive)."""
        self._staging.update(max(staging_s, 0.0))
        self._compute.update(max(compute_s, 1e-9))
        if n_voxels > 0 and compute_s > 0:
            self._rate.update(n_voxels / compute_s)
        ratio = self._staging.value / max(self._compute.value, 1e-12)
        if ratio > self.grow_ratio and self.depth < self.max_depth:
            self.depth += 1
        elif ratio < self.shrink_ratio and self.depth > self.min_depth:
            self.depth -= 1
        if stalled:
            base = (self.wave_voxels if self.wave_voxels is not None
                    else self.max_wave_voxels)
            self.wave_voxels = _lane_floor(base // 2, self.min_wave_voxels,
                                           self.max_wave_voxels)
        elif self.target_wave_ms is not None and self._rate.value:
            want = self._rate.value * self.target_wave_ms * 1e-3
            self.wave_voxels = _lane_floor(want, self.min_wave_voxels,
                                           self.max_wave_voxels)
        return self.depth, self.wave_voxels
