"""Serving steps: prefill (prompt -> KV cache + first logits) and decode
(one token against a sequence-sharded KV cache), plus a greedy/temperature
sampler.  These are the functions the decode_*/long_* dry-run cells lower.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.dist.sharding import shard


def make_prefill_step(fns):
    def prefill_step(params, batch):
        batch = shard(batch, "batch", None)  # (B, S) prompts, data-parallel
        cache, logits = fns.prefill(params, batch)
        next_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return cache, next_tok, logits

    return prefill_step


def make_serve_step(fns, *, temperature: float = 0.0):
    """serve_step(params, cache, tokens, cache_len[, key]) -> (next, cache).

    One new token with a KV cache of seq_len — the assigned decode cells."""

    def serve_step(params, cache, tokens, cache_len, key=None):
        tokens = shard(tokens, "batch")  # (B,) current tokens, data-parallel
        logits, cache = fns.decode(params, cache, tokens, cache_len)
        if temperature > 0.0 and key is not None:
            next_tok = jax.random.categorical(key, logits / temperature, -1)
        else:
            next_tok = jnp.argmax(logits, axis=-1)
        return next_tok.astype(jnp.int32), cache

    return serve_step
