from repro.serve.decode import make_serve_step, make_prefill_step
from repro.serve.executor import InflightWave, WaveExecutor
from repro.serve.queue import QueuedRequest, RequestQueue, RequestState
from repro.serve.recon import (ReconEngine, ReconRequest, ReconResult,
                               latency_percentiles, plan_tiles)
