from repro.serve.admission import (AdaptiveController, AdmissionPolicy,
                                   ShedReason)
from repro.serve.decode import make_serve_step, make_prefill_step
from repro.serve.executor import InflightWave, WaveExecutor
from repro.serve.faults import (FAULT_KINDS, FaultInjector, FaultSpec,
                                InjectedServeFault, WaveTimeout)
from repro.serve.queue import QueuedRequest, RequestQueue, RequestState
from repro.serve.recon import (ReconEngine, ReconRequest, ReconResult,
                               latency_percentiles, plan_tiles)
