"""Batched MRF map-reconstruction serving engine.

The paper's clinical payoff is real-time parameter-map reconstruction inside
the scanner: a trained MLP replaces dictionary matching for per-voxel
(T1, T2) inference at volume scale (DRONE / Barbieri et al.).  This module is
that deployment path — the third leg of the train/dist/serve triad:

* **Request pool** — each :class:`ReconRequest` is one slice/volume of
  fingerprint features plus the voxel mask it was acquired under; a wave of
  requests is pooled into one flat voxel stream.
* **Bucketed micro-batching** — the stream is tiled into fixed MXU-aligned
  buckets (:func:`plan_tiles`): full tiles at the largest bucket, the ragged
  tail padded up to the smallest bucket that fits.  Shapes therefore come
  from a small closed set and the jitted per-bucket forward never recompiles
  after warmup, however ragged the requests.
* **Two backends** — ``float`` runs ``core.mrf_net.forward`` on the trained
  fp32 params; ``int8`` runs the full-integer export through the Pallas
  int8 kernel (``kernels.qat_dense.int_forward_pallas``), bit-identical to
  the ``core.qat.int_forward`` oracle.
* **Batch-axis sharding** — the bucket batch axis is annotated with the
  ``batch`` logical axis via ``dist.sharding.shard``, so the same engine
  code serves mesh-less on one device and data-parallel under
  ``use_rules(...)`` on a mesh.  Build the engine *inside* the rules scope:
  ambient rules are captured at first trace of each bucket shape.
* **Masked re-assembly** — per-voxel predictions are denormalised in exactly
  one place (``data.pipeline.denormalize_targets``) and scattered back into
  map-shaped arrays through the request's mask.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import mrf_net
from repro.data.pipeline import denormalize_targets
from repro.dist.sharding import shard
from repro.kernels.qat_dense.ops import int_forward_pallas

BACKENDS = ("float", "int8")

# Power-of-two multiples of the 128-lane MXU tile: four shapes cover any
# request mix (full tiles at 1024, tail padded to the smallest fit).
DEFAULT_BUCKETS = (128, 256, 512, 1024)


@dataclasses.dataclass(frozen=True, eq=False)  # eq=False: jnp array fields
class ReconRequest:
    """One slice/volume of fingerprints to reconstruct.

    ``features``: (n_voxels, 2F) float32 — the masked voxels' [Re | Im]
    fingerprint features in row-major order (see ``data.phantom``).
    ``mask``: optional bool array of any map shape with ``mask.sum() ==
    n_voxels``; when given, results are scattered back into ``mask.shape``
    maps (background voxels stay 0).  Without it, results stay flat.
    """

    features: jnp.ndarray
    mask: np.ndarray | None = None
    request_id: str = ""

    @property
    def n_voxels(self) -> int:
        return int(self.features.shape[0])


@dataclasses.dataclass
class ReconResult:
    request_id: str
    t1_ms: np.ndarray  # mask.shape maps, or (n_voxels,) when mask is None
    t2_ms: np.ndarray
    n_voxels: int
    latency_s: float   # submit-to-assembled, within the wave


def plan_tiles(n: int, buckets: Sequence[int]) -> list:
    """Tile ``n`` voxels into (offset, count, bucket) micro-batches.

    Full tiles use the largest bucket; the remainder uses the smallest
    bucket that fits (padded by the caller).  Covers [0, n) exactly.
    """
    buckets = sorted(int(b) for b in buckets)
    if not buckets or buckets[0] <= 0:
        raise ValueError(f"buckets must be positive: {buckets}")
    bmax = buckets[-1]
    tiles = []
    off = 0
    while n - off >= bmax:
        tiles.append((off, bmax, bmax))
        off += bmax
    rem = n - off
    if rem:
        fit = next(b for b in buckets if b >= rem)
        tiles.append((off, rem, fit))
    return tiles


def latency_percentiles(results: Sequence[ReconResult]) -> dict:
    """p50/p90/p99 request latency (ms) over a batch of results.

    Empty input yields NaNs rather than raising, so callers can report a
    zero-request wave without special-casing."""
    if not results:
        return {f"p{p}_ms": float("nan") for p in (50, 90, 99)}
    lats = np.array([r.latency_s for r in results], np.float64) * 1e3
    return {f"p{p}_ms": float(np.percentile(lats, p)) for p in (50, 90, 99)}


class ReconEngine:
    """Batched (T1, T2) map reconstruction over a request pool.

    ``backend="float"`` needs ``params`` (the mrf_net pytree);
    ``backend="int8"`` needs ``int_layers`` (a ``qat.export_int8`` /
    ``qat.load_int8_artifact`` list).  ``interpret=None`` auto-detects the
    Pallas mode (compiled on TPU, interpreter elsewhere).
    """

    def __init__(self, *, backend: str = "float", params=None, int_layers=None,
                 buckets: Sequence[int] = DEFAULT_BUCKETS,
                 interpret: bool | None = None):
        if backend not in BACKENDS:
            raise ValueError(f"backend {backend!r} not in {BACKENDS}")
        if backend == "float" and params is None:
            raise ValueError("float backend needs params")
        if backend == "int8" and int_layers is None:
            raise ValueError("int8 backend needs int_layers "
                             "(qat.export_int8 or qat.load_int8_artifact)")
        self.backend = backend
        self.params = params
        self.int_layers = int_layers
        self.buckets = tuple(sorted(int(b) for b in buckets))
        self.interpret = interpret
        self.in_dim = int(params[0]["w"].shape[0] if backend == "float"
                          else int_layers[0].w_q.shape[0])
        self._fwd = self._make_forward()
        self.bucket_shapes_run: set = set()
        self.last_wave: dict = {}

    # -- forward ----------------------------------------------------------

    def _make_forward(self):
        if self.backend == "float":
            params = self.params

            def fwd(x):
                return mrf_net.forward(params, shard(x, "batch", None))
        else:
            ints, interp = self.int_layers, self.interpret

            def fwd(x):
                return int_forward_pallas(ints, shard(x, "batch", None),
                                          interpret=interp)
        return jax.jit(fwd)

    def compile_cache_size(self) -> int:
        """Number of distinct bucket shapes traced so far (must stay bounded
        by ``len(self.buckets)`` — the no-recompile property)."""
        return int(self._fwd._cache_size())

    # -- serving ----------------------------------------------------------

    def reconstruct(self, requests: Sequence[ReconRequest]) -> list:
        """Serve one wave: pool, tile into buckets, predict, re-assemble.

        Returns one :class:`ReconResult` per request, in request order.
        Requests complete as the tiles covering them finish, so
        ``latency_s`` is each request's true completion time within the
        wave.  Wave-level stats land in ``self.last_wave``.
        """
        if not requests:
            self.last_wave = {"n_requests": 0, "total_voxels": 0,
                              "wall_s": 0.0, "voxels_per_s": 0.0}
            return []
        for r in requests:
            if int(r.features.shape[-1]) != self.in_dim:
                raise ValueError(
                    f"request {r.request_id!r} has feature dim "
                    f"{r.features.shape[-1]}, engine expects {self.in_dim}")
            if r.mask is not None and int(np.asarray(r.mask).sum()) != r.n_voxels:
                raise ValueError(
                    f"request {r.request_id!r}: mask selects "
                    f"{int(np.asarray(r.mask).sum())} voxels, features carry "
                    f"{r.n_voxels}")

        t_wave = time.perf_counter()
        counts = [r.n_voxels for r in requests]
        total = sum(counts)
        ends = np.cumsum(counts)
        pool = (jnp.concatenate([jnp.asarray(r.features, jnp.float32)
                                 for r in requests], axis=0)
                if len(requests) > 1
                else jnp.asarray(requests[0].features, jnp.float32))

        pred_norm = np.empty((total, 2), np.float32)
        results: list = [None] * len(requests)
        done = covered = 0

        def drain():  # assemble every request whose voxels are all computed
            nonlocal done
            now = time.perf_counter()
            while done < len(requests) and ends[done] <= covered:
                start = ends[done] - counts[done]
                results[done] = self._assemble(
                    requests[done], pred_norm[start:ends[done]], now - t_wave)
                done += 1

        for off, count, bucket in plan_tiles(total, self.buckets):
            chunk = pool[off:off + count]
            if count < bucket:  # pad-to-bucket: shapes never leave the set
                chunk = jnp.pad(chunk, ((0, bucket - count), (0, 0)))
            out = self._fwd(chunk)
            self.bucket_shapes_run.add(bucket)
            # per-tile sync: completed requests get their true latency
            pred_norm[off:off + count] = np.asarray(
                jax.block_until_ready(out))[:count]
            covered += count
            drain()
        drain()  # a wave of only zero-voxel requests produces no tiles
        wall = time.perf_counter() - t_wave
        self.last_wave = {"n_requests": len(requests), "total_voxels": total,
                          "wall_s": wall,
                          "voxels_per_s": total / max(wall, 1e-12)}
        return results

    def _assemble(self, req: ReconRequest, pred_norm_slice: np.ndarray,
                  latency_s: float) -> ReconResult:
        pred_ms = np.asarray(denormalize_targets(pred_norm_slice))
        if req.mask is not None:
            mask = np.asarray(req.mask, bool)
            t1 = np.zeros(mask.shape, np.float32)
            t2 = np.zeros(mask.shape, np.float32)
            t1[mask] = pred_ms[:, 0]
            t2[mask] = pred_ms[:, 1]
        else:
            t1, t2 = pred_ms[:, 0].copy(), pred_ms[:, 1].copy()
        return ReconResult(request_id=req.request_id, t1_ms=t1, t2_ms=t2,
                           n_voxels=int(pred_ms.shape[0]),
                           latency_s=latency_s)
