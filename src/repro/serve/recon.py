"""Pipelined MRF map-reconstruction serving engine (composition layer).

The paper's clinical payoff is real-time parameter-map reconstruction inside
the scanner: a trained MLP replaces dictionary matching for per-voxel
(T1, T2) inference at volume scale (DRONE / Barbieri et al.).  Serving is a
three-layer stack; this module is the top:

* **Admission** (``serve.queue``) — a persistent :class:`RequestQueue`.
  Each :class:`ReconRequest` (one slice/volume of fingerprint features plus
  its voxel mask) is admitted as a lifecycle ticket
  (``pending -> scheduled -> done | failed``) stamped with its enqueue time;
  waves form under ``max_wave_voxels`` / ``max_wait_ms`` / priority policy.
* **Execution** (``serve.executor``) — the double-buffered
  :class:`WaveExecutor`: MXU-aligned pad-to-bucket tiling (fixed shape set,
  jit cache bounded by the bucket count), device-side staging, asynchronous
  tile dispatch with one host sync per wave, float (``mrf_net.forward``) or
  full-integer int8 backends (``int8_impl`` picks the fused whole-network
  kernel, the pure-lax fallback, or the layered kernel chain — all
  bit-exact vs ``qat.int_forward``), batch axis ``dist.shard``-annotated so
  the same stack serves mesh-less or data-parallel (build the engine inside
  ``use_rules``; ambient rules are captured at first trace).
* **Engine** (here) — :class:`ReconEngine` composes the two.
  ``mode="pipelined"`` keeps up to ``inflight_depth`` waves in flight, so
  staging of wave N+1 overlaps device compute of wave N and each wave costs
  one host sync; ``mode="sync"`` retires each wave tile-by-tile before
  dispatching the next (the pre-queue engine, kept as the measured
  baseline).  Both modes run the identical jitted per-bucket forward, so
  their maps are bit-identical.  ``reconstruct(requests)`` is the
  compatibility wrapper: validate everything, enqueue everything, drain.

Robustness layer
----------------
The engine is overload- and fault-hardened end to end:

* **Admission control** — pass ``admission=AdmissionPolicy(...)`` and the
  queue sheds (never queues-to-collapse) under load: bounded pending-voxel
  budget, deadline-aware rejection against the observed service rate (the
  engine feeds ``observe_service`` at every wave retire), priority
  displacement.  Shed tickets end in the distinct ``shed`` terminal state
  with a structured ``ShedReason``.
* **Bounded retry, solo blast radius** — a wave that crashes at dispatch
  or execution no longer fails every wave-mate: tickets with retry budget
  left (``max_retries``, default 1) are requeued as *solo* waves (each
  retries alone, optionally after ``retry_backoff_s * 2**(retries-1)`` of
  backoff), so a transient blip costs a retry and only a genuinely
  poisoned request exhausts its budget and fails — alone.
* **Degradation** — execution failures feed the executor's circuit
  breaker; once it trips, retried and subsequent waves serve through the
  bit-exact lax int8 fallback (``engine.health()["degraded"]``).
* **Watchdog + adaptive pipelining** — each wave's staging and compute
  times are measured; ``wave_timeout_s`` flags stalls, and with
  ``adaptive=True`` an ``AdaptiveController`` (EWMA-driven, clamped)
  auto-tunes ``inflight_depth`` and the wave voxel cap live.
* **Fault injection** — ``injector=FaultInjector(schedule)`` fires
  deterministic faults (``serve.faults``) at every lifecycle point, the
  serving twin of ``ft/runner``'s ``inject_fault_at``.

Per-voxel predictions are denormalised in exactly one place
(``data.pipeline.denormalize_targets``, fused on-device inside the
executor's jitted forward) and scattered back into map-shaped arrays
through each request's mask.  ``ReconResult.latency_s`` measures
enqueue-to-assembled time — queue wait included, not just time-in-wave.
"""

from __future__ import annotations

import collections
import dataclasses
import time
from typing import Sequence

import jax.numpy as jnp
import numpy as np

from repro.serve.admission import AdaptiveController
from repro.serve.executor import (BACKENDS, DEFAULT_BUCKETS, WaveExecutor,
                                  plan_tiles)
from repro.serve.faults import WaveTimeout
from repro.serve.queue import QueuedRequest, RequestQueue, RequestState

__all__ = ["BACKENDS", "DEFAULT_BUCKETS", "MODES", "ReconEngine",
           "ReconRequest", "ReconResult", "latency_percentiles", "plan_tiles"]

MODES = ("sync", "pipelined")


@dataclasses.dataclass(frozen=True, eq=False)  # eq=False: jnp array fields
class ReconRequest:
    """One slice/volume of fingerprints to reconstruct.

    ``features``: (n_voxels, 2F) float32 — the masked voxels' [Re | Im]
    fingerprint features in row-major order (see ``data.phantom``).
    ``mask``: optional bool array of any map shape with ``mask.sum() ==
    n_voxels``; when given, results are scattered back into ``mask.shape``
    maps (background voxels stay 0).  Without it, results stay flat.
    """

    features: jnp.ndarray
    mask: np.ndarray | None = None
    request_id: str = ""

    @property
    def n_voxels(self) -> int:
        return int(self.features.shape[0])


@dataclasses.dataclass
class ReconResult:
    request_id: str
    t1_ms: np.ndarray  # mask.shape maps, or (n_voxels,) when mask is None
    t2_ms: np.ndarray
    n_voxels: int
    latency_s: float   # enqueue-to-assembled (queue wait included)


def latency_percentiles(results: Sequence[ReconResult]) -> dict:
    """p50/p90/p99 request latency (ms) over a batch of results.

    Empty input yields NaNs rather than raising, so callers can report a
    zero-request wave without special-casing."""
    if not results:
        return {f"p{p}_ms": float("nan") for p in (50, 90, 99)}
    lats = np.array([r.latency_s for r in results], np.float64) * 1e3
    return {f"p{p}_ms": float(np.percentile(lats, p)) for p in (50, 90, 99)}


class ReconEngine:
    """Queued, batched (T1, T2) map reconstruction.

    ``backend="float"`` needs ``params`` (the mrf_net pytree);
    ``backend="int8"`` needs ``int_layers`` (a ``qat.export_int8`` /
    ``qat.load_int8_artifact`` list).  ``interpret=None`` auto-detects the
    Pallas mode (compiled on TPU, interpreter elsewhere).

    Serving knobs: ``mode`` picks the executor discipline ("sync" = per-tile
    retirement, the baseline; "pipelined" = up to ``inflight_depth`` waves
    in flight, one host sync per wave); ``max_wave_voxels`` caps a wave,
    ``max_wait_ms`` is the admission deadline from enqueue (see
    ``serve.queue``).  ``int8_impl`` / ``int8_block_m`` select the int8
    implementation and the fused kernel's voxel tile (``None`` = fastest
    for the rig; see :class:`WaveExecutor`).  Defaults (no cap, no
    deadline, sync) make :meth:`reconstruct` behave exactly like the
    pre-queue engine.

    Robustness knobs: ``admission`` installs a load-shedding policy
    (``serve.admission.AdmissionPolicy``); ``max_retries`` bounds the solo
    requeues a ticket gets after a failed wave (0 restores fail-the-wave);
    ``retry_backoff_s`` sleeps ``retry_backoff_s * 2**(retries-1)`` before
    a retry wave dispatches (0 = immediate); ``wave_timeout_s`` flags waves
    whose completion wait exceeds it as stalls (health accounting + the
    adaptive controller's shrink signal); ``adaptive=True`` (or a
    configured ``AdaptiveController``) auto-tunes ``inflight_depth`` and
    ``max_wave_voxels`` live — pipelined mode only; ``injector`` threads a
    deterministic ``serve.faults.FaultInjector`` through every lifecycle
    point.
    """

    def __init__(self, *, backend: str = "float", params=None, int_layers=None,
                 buckets: Sequence[int] = DEFAULT_BUCKETS,
                 interpret: bool | None = None, mode: str = "sync",
                 max_wave_voxels: int | None = None,
                 max_wait_ms: float | None = None, inflight_depth: int = 2,
                 int8_impl: str | None = None, int8_block_m: int | None = None,
                 admission=None, injector=None, max_retries: int = 1,
                 retry_backoff_s: float = 0.0,
                 wave_timeout_s: float | None = None,
                 adaptive=False, clock=time.perf_counter):
        if mode not in MODES:
            raise ValueError(f"mode {mode!r} not in {MODES}")
        if inflight_depth < 1:
            raise ValueError(f"inflight_depth must be >= 1: {inflight_depth}")
        if max_retries < 0:
            raise ValueError(f"max_retries must be >= 0: {max_retries}")
        if retry_backoff_s < 0:
            raise ValueError(f"retry_backoff_s must be >= 0: "
                             f"{retry_backoff_s}")
        self.mode = mode
        self.executor = WaveExecutor(backend=backend, params=params,
                                     int_layers=int_layers, buckets=buckets,
                                     interpret=interpret, int8_impl=int8_impl,
                                     int8_block_m=int8_block_m,
                                     injector=injector)
        # one time source for enqueue stamps AND completion stamps, so an
        # injected test clock yields coherent latencies
        self._clock = clock
        self.admission = admission
        self.queue = RequestQueue(max_wave_voxels=max_wave_voxels,
                                  max_wait_ms=max_wait_ms,
                                  validator=self._validate,
                                  admission=admission, clock=clock)
        self._injector = injector
        self.max_retries = int(max_retries)
        self.retry_backoff_s = float(retry_backoff_s)
        self.wave_timeout_s = wave_timeout_s
        if adaptive and mode != "pipelined":
            raise ValueError("adaptive pipelining tunes inflight_depth — "
                             "it requires mode='pipelined'")
        if isinstance(adaptive, AdaptiveController):
            self.controller = adaptive
        elif adaptive:
            self.controller = AdaptiveController(
                depth=inflight_depth,
                max_depth=max(AdaptiveController.max_depth, inflight_depth),
                wave_voxels=max_wave_voxels,
                max_wave_voxels=(max_wave_voxels * 4 if max_wave_voxels
                                 else AdaptiveController.max_wave_voxels))
        else:
            self.controller = None
        self._depth = 1 if mode == "sync" else int(inflight_depth)
        self._inflight: collections.deque = collections.deque()
        self._wave_seq = 0  # engine dispatch counter = fault-schedule index
        # aggregate stats of waves poll() retired (or that died at
        # dispatch) since the last drain — folded into the next drain's
        # last_wave.  Stats only, never ticket references: a long-lived
        # enqueue/poll streaming server must not accumulate served
        # features/maps in the engine (the caller holds the tickets).
        self._early_stats = self._zero_stats()
        self._shed_mark = 0    # queue.n_shed watermark at the last drain
        self._t_epoch: float | None = None  # first dispatch since last drain
        self.last_wave: dict = {}
        # lifetime health counters (never reset by drain)
        self.n_retries_total = 0
        self.n_slow_waves = 0

    @staticmethod
    def _zero_stats() -> dict:
        return {"n_done": 0, "voxels": 0, "n_failed": 0, "n_waves": 0,
                "n_retries": 0}

    def _fold_early(self, wave: list) -> None:
        """Account a wave finalized outside drain() into the early stats.
        Requeued (pending-again) tickets are in flight, not finalized —
        they are counted when their retry wave lands."""
        if not wave:
            return
        self._early_stats["n_waves"] += 1
        for t in wave:
            if t.state == RequestState.DONE:
                self._early_stats["n_done"] += 1
                self._early_stats["voxels"] += t.request.n_voxels
            elif t.state == RequestState.FAILED:
                self._early_stats["n_failed"] += 1

    # -- thin views over the layers (the executor owns the network state) --

    @property
    def backend(self) -> str:
        return self.executor.backend

    @property
    def params(self):
        return self.executor.params

    @property
    def int_layers(self):
        return self.executor.int_layers

    @property
    def buckets(self) -> tuple:
        return self.executor.buckets

    @property
    def in_dim(self) -> int:
        return self.executor.in_dim

    @property
    def int8_impl(self) -> str | None:
        return self.executor.int8_impl

    @property
    def request_sizes(self) -> list:
        """Voxel counts of every request dispatched — the recorded size
        distribution that feeds measured bucket autotuning."""
        return self.executor.request_sizes

    @property
    def bucket_shapes_run(self) -> set:
        return self.executor.bucket_shapes_run

    def compile_cache_size(self) -> int:
        """Number of distinct bucket shapes traced so far (must stay bounded
        by ``len(self.buckets)`` — the no-recompile property)."""
        return self.executor.cache_size()

    # -- validation (admission-time, once per request) ---------------------

    def _validate(self, r: ReconRequest) -> str | None:
        if not hasattr(r.features, "shape"):
            return (f"request {r.request_id!r} features must be an array "
                    f"with .shape: got {type(r.features).__name__}")
        if len(r.features.shape) != 2:
            return (f"request {r.request_id!r} features must be rank-2 "
                    f"(n_voxels, features): got shape "
                    f"{tuple(r.features.shape)}")
        if int(r.features.shape[-1]) != self.in_dim:
            return (f"request {r.request_id!r} has feature dim "
                    f"{r.features.shape[-1]}, engine expects {self.in_dim}")
        # count the bool cast, exactly what _assemble scatters through —
        # e.g. an int mask [2, 1, 0] sums to 3 but selects 2 cells
        if r.mask is not None and int(np.asarray(r.mask, bool).sum()) != r.n_voxels:
            return (f"request {r.request_id!r}: mask selects "
                    f"{int(np.asarray(r.mask, bool).sum())} voxels, features "
                    f"carry {r.n_voxels}")
        return None

    # -- streaming API -----------------------------------------------------

    def enqueue(self, request: ReconRequest, *, priority: int = 0,
                deadline_ms: float | None = None) -> QueuedRequest:
        """Admit one request; returns its lifecycle ticket.

        Invalid requests come back already ``failed`` (``ticket.error``
        set) — admission never raises and never disturbs pending requests.
        With an admission policy installed, a valid request can instead
        come back ``shed`` (``ticket.shed_reason`` set): overloaded, retry
        later.  ``deadline_ms`` is this request's wait budget for
        deadline-aware shedding (None: the policy default).
        """
        return self.queue.submit(request, priority=priority,
                                 deadline_ms=deadline_ms)

    def poll(self) -> int:
        """Dispatch every wave the formation policy says is due; no blocking
        beyond pipeline-full backpressure.  Returns waves dispatched.

        Waves retired here under backpressure finalize their tickets (the
        caller holds those) and fold into the next :meth:`drain`'s stats —
        nothing served is dropped, and nothing is retained by the engine.
        """
        n = 0
        while self.queue.n_pending and self.queue.wave_due():
            if len(self._inflight) >= self._depth:
                self._fold_early(self._retire_oldest())
            if self._dispatch(self.queue.form_wave()):
                n += 1  # waves that died at dispatch don't count as work
        return n

    def drain(self) -> list:
        """Serve everything: flush the queue through the executor, keeping
        up to ``inflight_depth`` waves in flight (pipelined) or exactly one
        retired tile-by-tile (sync).  Returns results in completion order;
        each ticket's ``result``/``state`` is updated in place.

        Returns the results of waves retired by this call; waves already
        retired by :meth:`poll` live on their tickets (the streaming caller
        holds those) and are folded into the stats only.  ``self.last_wave``
        covers the whole serving session since the previous drain, with
        ``wall_s`` spanning from the session's first dispatch, so streamed
        and batch serving report comparable throughput.
        """
        t0 = self._t_epoch if self._t_epoch is not None else self._clock()
        retired: list[QueuedRequest] = []
        n_waves = 0
        while self.queue.n_pending or self._inflight:
            # keep the pipeline full: stage + dispatch wave N+1 while the
            # device still computes wave N (async dispatch returns at once)
            while self.queue.n_pending and len(self._inflight) < self._depth:
                self._dispatch(self.queue.form_wave(flush=True))
            wave_tickets = self._retire_oldest()
            if wave_tickets:  # don't count a phantom wave when every
                retired.extend(wave_tickets)  # dispatch this round failed
                n_waves += 1
        early = self._early_stats  # poll retirements + dispatch failures
        self._early_stats = self._zero_stats()
        wall = self._clock() - t0
        self._t_epoch = None
        n_shed = self.queue.n_shed - self._shed_mark
        self._shed_mark = self.queue.n_shed
        served = [t for t in retired if t.state == RequestState.DONE]
        total = sum(t.request.n_voxels for t in served) + early["voxels"]
        n_req = len(served) + early["n_done"]
        self.last_wave = {"n_requests": n_req, "total_voxels": total,
                          "wall_s": wall,
                          "voxels_per_s": total / max(wall, 1e-12),
                          "n_waves": n_waves + early["n_waves"],
                          "mode": self.mode,
                          "n_failed": (len(retired) - len(served)
                                       + early["n_failed"]),
                          "n_shed": n_shed,
                          "n_retries": early["n_retries"],
                          "degraded": self.executor.degraded}
        return [t.result for t in served]

    def health(self) -> dict:
        """Live robustness snapshot: degradation, failures, retries,
        shedding, stalls, and the current (possibly adaptive) knobs."""
        ex = self.executor
        return {"degraded": ex.degraded,
                "degraded_reason": ex.degraded_reason,
                "int8_impl": ex.int8_impl,
                "n_kernel_failures": ex.n_kernel_failures,
                "n_degraded_waves": ex.n_degraded_waves,
                "n_retries_total": self.n_retries_total,
                "n_slow_waves": self.n_slow_waves,
                "n_shed_total": self.queue.n_shed,
                "n_rejected_total": self.queue.n_rejected,
                "inflight_depth": self._depth,
                "max_wave_voxels": self.queue.max_wave_voxels,
                "service_rate_voxels_per_s": (
                    self.admission.service_rate
                    if self.admission is not None else None)}

    # -- compatibility wrapper --------------------------------------------

    def reconstruct(self, requests: Sequence[ReconRequest]) -> list:
        """Serve one batch: validate all, enqueue all, drain.

        All-or-nothing admission: *every* request is validated before any
        is admitted, so a bad request raises here without half-serving the
        others (the streaming path instead marks it ``failed`` — see
        :meth:`enqueue`).  Returns one :class:`ReconResult` per request, in
        request order; if serving any request failed mid-wave (dispatch,
        execution, or assembly), the wave still completes for everyone
        else and *then* this raises (never a silent ``None`` in the batch
        API).
        """
        if not requests:
            self.last_wave = {"n_requests": 0, "total_voxels": 0,
                              "wall_s": 0.0, "voxels_per_s": 0.0,
                              "n_waves": 0, "mode": self.mode, "n_failed": 0}
            return []
        for r in requests:
            err = self._validate(r)
            if err is not None:
                raise ValueError(err)
        # validated above, all-or-nothing: skip submit's re-validation
        tickets = [self.queue.submit(r, validate=False) for r in requests]
        self.drain()
        failed = [t for t in tickets if t.state in (RequestState.FAILED,
                                                    RequestState.SHED)]
        if failed:
            # each ticket's error names the failing stage (admission shed /
            # dispatch / execution / assembly); don't relabel it here
            raise ValueError(
                f"{len(failed)} request(s) failed while serving the wave: "
                + "; ".join(t.error for t in failed[:3]))
        return [t.result for t in tickets]

    # -- wave mechanics ----------------------------------------------------

    def _wave_failed(self, wave: list, stage: str, exc: Exception) -> int:
        """Bounded-retry failure policy for a crashed wave; returns how
        many tickets it marked failed (the caller owns the accounting —
        execution failures return their tickets to drain, dispatch
        failures never enter flight and count into the early stats).

        Every still-scheduled ticket with retry budget left goes back to
        the queue as a *solo* ticket (its retry wave carries no mates, so
        a poisoned request can only take itself down on the next attempt);
        tickets out of budget fail with the error recorded.  This is the
        fix for the whole-wave blast radius: one crashing dispatch used to
        fail every wave-mate outright.
        """
        retried = failed = 0
        for t in wave:
            if t.state != RequestState.SCHEDULED:
                continue  # sync mode may have assembled some already
            if t.retries < self.max_retries:
                t.retries += 1
                t.solo = True
                self.queue.requeue(t)
                retried += 1
            else:
                t.state = RequestState.FAILED
                t.error = (f"wave {stage} failed"
                           f"{' after retry' if t.retries else ''}: "
                           f"{type(exc).__name__}: {exc}")
                failed += 1
        if retried:
            self._early_stats["n_retries"] += retried
            self.n_retries_total += retried
            if self.retry_backoff_s > 0:
                # exponential backoff before the retry waves can dispatch:
                # a crashing backend gets breathing room, bounded by
                # max_retries doublings
                worst = max(t.retries for t in wave
                            if t.state == RequestState.PENDING)
                time.sleep(self.retry_backoff_s * 2 ** (worst - 1))
        return failed

    def _dispatch(self, wave: list) -> bool:
        """Stage + enqueue one wave; True iff it actually entered flight."""
        if not wave:
            return False
        widx = self._wave_seq
        self._wave_seq += 1
        t_start = self._clock()
        try:
            if self._injector is not None:
                self._injector.fire_dispatch(
                    widx, [t.request.request_id for t in wave])
            handle = self.executor.dispatch(
                [t.request.features for t in wave], wave_index=widx)
        except Exception as e:
            # an executor failure must stay a lifecycle state too: a wave
            # that cannot stage requeues/fails its tickets instead of
            # raising out of poll()/drain() and stranding them as
            # "scheduled".  Not counted in n_waves — it never entered
            # flight — so its failures count here (they belong to no
            # retired wave that drain could account).
            self._early_stats["n_failed"] += self._wave_failed(
                wave, "dispatch", e)
            return False
        if self._t_epoch is None:
            # session clock starts at the first wave that actually entered
            # flight; a wave dying at dispatch must not skew wall_s
            self._t_epoch = t_start
        staging_s = self._clock() - t_start
        self._inflight.append((wave, handle, widx, staging_s))
        return True

    def _retire_oldest(self) -> list:
        """Complete the oldest in-flight wave and assemble its requests;
        returns the wave's *finalized* tickets (requeued ones are pending
        again and excluded).

        Sync mode syncs tile-by-tile so each request is assembled the
        moment its last tile lands; pipelined mode blocks once for the
        whole wave (``InflightWave.wait``) and assembles everything.  The
        wait is watchdogged (``wave_timeout_s``) and its measured staging/
        compute split feeds the admission service-rate estimate and the
        adaptive controller.
        """
        if not self._inflight:
            return []
        wave, handle, widx, staging_s = self._inflight.popleft()
        counts = [t.request.n_voxels for t in wave]
        ends = np.cumsum(counts) if counts else np.zeros(0, np.int64)
        pred_ms = None
        done = 0

        def assemble_upto(covered):
            nonlocal done
            now = self._clock()
            while done < len(wave) and ends[done] <= covered:
                end = int(ends[done])
                self._finish(wave[done], pred_ms[end - counts[done]:end],
                             now, widx)
                done += 1

        # tiles come back already denormalized (ms): the rescale lives
        # inside the executor's jitted forward, so retirement adds no
        # device round-trip after the executor's single sync
        t_wait = self._clock()
        stall_s = 0.0
        try:
            if self._injector is not None:
                spec = self._injector.fire_wait(widx)  # raises WaveTimeout
                if spec is not None:  # slow_wave: a synthetic stall
                    stall_s = spec.delay_s
            if self.mode == "sync":
                pred_ms = np.empty((handle.total, 2), np.float32)
                covered = 0
                for off, count, block in handle.wait_tiles():
                    pred_ms[off:off + count] = block
                    covered += count
                    assemble_upto(covered)
            else:
                pred_ms = handle.wait()
            assemble_upto(handle.total)  # remainder incl. zero-voxel requests
        except Exception as e:
            # device-side execution failures are lifecycle states too: the
            # wave was already popped, so strand nothing in "scheduled" —
            # retry-budgeted tickets requeue solo, the rest fail
            if not isinstance(e, WaveTimeout):
                # async kernel failures surface here; feed the circuit
                # breaker so retries (and later waves) serve degraded
                self.executor.note_kernel_failure()
            self._wave_failed(wave, "execution", e)
            return [t for t in wave
                    if t.state in (RequestState.DONE, RequestState.FAILED)]
        compute_s = self._clock() - t_wait + stall_s
        stalled = stall_s > 0 or (self.wave_timeout_s is not None
                                  and compute_s > self.wave_timeout_s)
        if stalled:
            self.n_slow_waves += 1
        if self.admission is not None:
            self.admission.observe_service(handle.total, compute_s)
        if self.controller is not None:
            depth, cap = self.controller.observe(
                staging_s=staging_s, compute_s=compute_s,
                n_voxels=handle.total, stalled=stalled)
            self._depth = depth
            if cap is not None:
                self.queue.max_wave_voxels = cap
        return wave

    def _finish(self, ticket: QueuedRequest, pred_ms_slice: np.ndarray,
                now: float, wave_index: int = -1) -> None:
        try:
            if self._injector is not None:
                self._injector.fire_assemble(wave_index,
                                             ticket.request.request_id)
            ticket.result = self._assemble(ticket.request, pred_ms_slice,
                                           now - ticket.enqueue_t)
        except Exception as e:  # surface as lifecycle state, not out of wave
            ticket.state = RequestState.FAILED
            ticket.error = f"{type(e).__name__}: {e}"
            return
        ticket.state = RequestState.DONE
        ticket.done_t = now

    def _assemble(self, req: ReconRequest, pred_ms: np.ndarray,
                  latency_s: float) -> ReconResult:
        """Scatter one request's already-denormalized (ms) predictions."""
        if req.mask is not None:
            mask = np.asarray(req.mask, bool)
            t1 = np.zeros(mask.shape, np.float32)
            t2 = np.zeros(mask.shape, np.float32)
            t1[mask] = pred_ms[:, 0]
            t2[mask] = pred_ms[:, 1]
        else:
            t1, t2 = pred_ms[:, 0].copy(), pred_ms[:, 1].copy()
        return ReconResult(request_id=req.request_id, t1_ms=t1, t2_ms=t2,
                           n_voxels=int(pred_ms.shape[0]),
                           latency_s=latency_s)
