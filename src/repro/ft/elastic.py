"""Elastic scaling: re-shard a training state onto a different mesh.

Checkpoints are global-indexed (ft/checkpoint.py), so scaling down/up between
job restarts is just restore-with-new-shardings.  For *in-job* elasticity
(donating a live state to a new mesh after evicting a straggler host),
``reshard_tree`` re-places every leaf with ``jax.device_put`` under the new
rules — GSPMD moves the bytes.
"""

from __future__ import annotations

import jax

from repro.dist.sharding import AxisRules, param_shardings


def reshard_tree(tree, axes_tree, new_rules: AxisRules):
    """Re-place a pytree of arrays onto the mesh/rules in ``new_rules``.

    axes_tree: logical-axes pytree matching ``tree`` (same one used to build
    the original shardings) — the mapping is mesh-independent, which is what
    makes the state portable across mesh shapes.
    """
    shardings = param_shardings(axes_tree, new_rules)
    return jax.tree.map(jax.device_put, tree, shardings)


def downsize_batch_rules(rules: AxisRules, lost_hosts: int,
                         hosts_per_data_shard: int = 1) -> AxisRules:
    """Policy helper: after evicting hosts, shrink the data axis (keep model
    axis intact — TP degree is baked into padded head counts)."""
    # The new mesh must be constructed by the caller from surviving devices;
    # this helper only documents/validates the policy choice.
    del lost_hosts, hosts_per_data_shard
    return rules
