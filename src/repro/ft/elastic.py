"""Elastic scaling: re-shard a training state onto a different mesh.

Checkpoints are global-indexed (ft/checkpoint.py), so scaling down/up between
job restarts is just restore-with-new-shardings.  For *in-job* elasticity
(donating a live state to a new mesh after evicting a straggler host),
``reshard_tree`` re-places every leaf with ``jax.device_put`` under the new
rules — GSPMD moves the bytes.
"""

from __future__ import annotations

import jax

from repro.dist.sharding import AxisRules, param_shardings


def reshard_tree(tree, axes_tree, new_rules: AxisRules):
    """Re-place a pytree of arrays onto the mesh/rules in ``new_rules``.

    axes_tree: logical-axes pytree matching ``tree`` (same one used to build
    the original shardings) — the mapping is mesh-independent, which is what
    makes the state portable across mesh shapes.
    """
    shardings = param_shardings(axes_tree, new_rules)
    return jax.tree.map(jax.device_put, tree, shardings)


def downsize_batch_rules(rules: AxisRules, lost_hosts: int,
                         hosts_per_data_shard: int = 1) -> AxisRules:
    """Policy helper: after evicting hosts, shrink the data axis (keep model
    axis intact — TP degree is baked into padded head counts).

    Validates that the eviction removes whole batch shards and leaves the
    batch-shard pool non-empty, then returns the logical mapping detached
    from the dead mesh.  The pool is the product of the mesh axes the
    ``batch`` rule names (``data`` single-pod, ``pod*data`` multi-pod), so
    losing a whole pod's hosts is a valid downsize.  The caller rebuilds the
    survivor mesh with ``pool - lost_hosts // hosts_per_data_shard`` batch
    shards (choosing which axis to shrink) and re-binds via
    ``launch.mesh.rules_for`` — the mapping itself is mesh-shape-independent,
    which is what makes the state portable.
    """
    if rules.mesh is None:
        raise ValueError("rules must be bound to the pre-eviction mesh")
    if lost_hosts <= 0:
        raise ValueError(f"lost_hosts must be positive, got {lost_hosts}")
    if lost_hosts % hosts_per_data_shard != 0:
        raise ValueError(
            f"evicting {lost_hosts} hosts is not shard-aligned "
            f"({hosts_per_data_shard} hosts per data shard): a surviving "
            f"data shard would straddle a dead host")
    lost_shards = lost_hosts // hosts_per_data_shard
    batch_axes = rules.rules.get("batch") or ("data",)
    if isinstance(batch_axes, str):
        batch_axes = (batch_axes,)
    pool = 1
    for a in batch_axes:
        pool *= rules.mesh.shape.get(a, 1)
    if lost_shards >= pool:
        raise ValueError(
            f"evicting {lost_shards} batch shards empties the batch-shard "
            f"pool ({'x'.join(batch_axes)} had {pool})")
    return AxisRules(rules=dict(rules.rules), mesh=None)
