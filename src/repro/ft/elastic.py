"""Elastic scaling: re-shard a training state onto a different mesh.

Checkpoints are global-indexed (ft/checkpoint.py), so scaling down/up between
job restarts is just restore-with-new-shardings.  For *in-job* elasticity
(donating a live state to a new mesh after evicting a straggler host),
``reshard_tree`` re-places every leaf with ``jax.device_put`` under the new
rules — GSPMD moves the bytes.
"""

from __future__ import annotations

import math

import jax

from repro.dist.sharding import AxisRules, make_compat_mesh, param_shardings


def reshard_tree(tree, axes_tree, new_rules: AxisRules):
    """Re-place a pytree of arrays onto the mesh/rules in ``new_rules``.

    axes_tree: logical-axes pytree matching ``tree`` (same one used to build
    the original shardings) — the mapping is mesh-independent, which is what
    makes the state portable across mesh shapes.
    """
    shardings = param_shardings(axes_tree, new_rules)
    return jax.tree.map(jax.device_put, tree, shardings)


def downsize_batch_rules(rules: AxisRules, lost_hosts: int,
                         hosts_per_data_shard: int = 1) -> AxisRules:
    """Policy helper: after evicting hosts, shrink the data axis (keep model
    axis intact — TP degree is baked into padded head counts).

    Validates that the eviction removes whole batch shards and leaves the
    batch-shard pool non-empty, then returns the logical mapping detached
    from the dead mesh.  The pool is the product of the mesh axes the
    ``batch`` rule names (``data`` single-pod, ``pod*data`` multi-pod), so
    losing a whole pod's hosts is a valid downsize.  The caller rebuilds the
    survivor mesh with ``pool - lost_hosts // hosts_per_data_shard`` batch
    shards (choosing which axis to shrink) and re-binds via
    ``launch.mesh.rules_for`` — the mapping itself is mesh-shape-independent,
    which is what makes the state portable.
    """
    if rules.mesh is None:
        raise ValueError("rules must be bound to the pre-eviction mesh")
    if lost_hosts <= 0:
        raise ValueError(f"lost_hosts must be positive, got {lost_hosts}")
    if lost_hosts % hosts_per_data_shard != 0:
        raise ValueError(
            f"evicting {lost_hosts} hosts is not shard-aligned "
            f"({hosts_per_data_shard} hosts per data shard): a surviving "
            f"data shard would straddle a dead host")
    lost_shards = lost_hosts // hosts_per_data_shard
    batch_axes = _batch_axes(rules)
    pool = 1
    for a in batch_axes:
        pool *= rules.mesh.shape.get(a, 1)
    if lost_shards >= pool:
        raise ValueError(
            f"evicting {lost_shards} batch shards empties the batch-shard "
            f"pool ({'x'.join(batch_axes)} had {pool})")
    return AxisRules(rules=dict(rules.rules), mesh=None)


def _batch_axes(rules: AxisRules) -> tuple:
    axes = rules.rules.get("batch") or ("data",)
    return (axes,) if isinstance(axes, str) else tuple(axes)


def survivor_mesh(live_devices, rules: AxisRules) -> AxisRules:
    """Build the post-eviction mesh from the live device set and re-bind.

    The automatic half of an eviction (``downsize_batch_rules`` validates it;
    this constructs the result): every non-batch mesh axis keeps its original
    extent — TP degree is baked into padded head counts, so the model axis
    must survive intact — and the batch axes (``data``, or ``pod x data``
    multi-pod) collapse into a single ``data`` axis sized by whatever the
    survivors support.  Logical axes that mapped to any batch mesh axis
    (``batch``, ``fsdp``) are remapped to the new ``data`` axis; everything
    else keeps its mapping.  Collapsing ``pod`` is deliberate: after losing
    part of a pod the survivor set has no meaningful DCN structure, and the
    flat mapping is mesh-shape-independent, so the state reshards onto it via
    ``reshard_tree`` without caring where the survivors physically live.
    """
    if rules.mesh is None:
        raise ValueError("rules must be bound to the pre-eviction mesh")
    live = list(live_devices)
    if not live:
        raise ValueError("no live devices to build a survivor mesh from")
    if len(set(live)) != len(live):
        raise ValueError("live_devices contains duplicates")
    batch_axes = _batch_axes(rules)
    keep_axes = [a for a in rules.mesh.axis_names if a not in batch_axes]
    if "data" in keep_axes:
        raise ValueError(
            f"batch rule {batch_axes} does not cover the 'data' mesh axis; "
            "survivor_mesh reserves 'data' for the collapsed batch axis")
    keep_extent = math.prod(rules.mesh.shape[a] for a in keep_axes)
    if len(live) % keep_extent != 0:
        raise ValueError(
            f"{len(live)} survivors do not tile the intact "
            f"{'x'.join(keep_axes) or '(none)'} extent {keep_extent}: the "
            f"eviction must remove whole batch shards "
            f"(use downsize_batch_rules to validate the plan first)")
    new_data = len(live) // keep_extent
    mesh = make_compat_mesh((new_data, *(rules.mesh.shape[a] for a in keep_axes)),
                            ("data", *keep_axes), devices=live)
    remapped = {}
    for name, phys in rules.rules.items():
        phys_tuple = (phys,) if isinstance(phys, str) else (phys or ())
        if any(a in batch_axes for a in phys_tuple):
            remapped[name] = "data"
        else:
            remapped[name] = phys
    return AxisRules(rules=remapped, mesh=mesh)
