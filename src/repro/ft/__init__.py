from repro.ft.checkpoint import CheckpointManager, restore_state, save_state
from repro.ft.straggler import StragglerMonitor
from repro.ft.elastic import reshard_tree
