"""Fault-tolerant training loop: periodic async checkpoints, resume-from-
latest, straggler watchdog, and crash-retry — the loop a real multi-pod job
runs under a cluster scheduler.

Fault injection (``inject_fault_at``) lets tests exercise the recovery path
deterministically on CPU: the loop "crashes" at a chosen step, then the
restart resumes from the latest checkpoint and must reach the same final
state as an uninterrupted run (tests/test_fault_tolerance.py).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable

import jax

from repro.ft.checkpoint import CheckpointManager
from repro.ft.straggler import StragglerMonitor


class InjectedFault(RuntimeError):
    pass


@dataclasses.dataclass
class RunnerConfig:
    total_steps: int
    ckpt_dir: str
    ckpt_every: int = 50
    keep: int = 3
    max_restarts: int = 3
    inject_fault_at: int | None = None


def run(train_step: Callable, init_state, batches: Callable[[int], Any],
        cfg: RunnerConfig, *, shardings=None, on_metrics=None):
    """Run to cfg.total_steps with checkpoint/restart.

    Returns ``(state, step)``: the final state and the step count reached.

    ``batches`` is a *seekable* factory — ``batches(step) -> batch`` must
    return the same batch for the same step on every call, so a restart
    replays the data stream deterministically from the resume step.
    """
    mgr = CheckpointManager(cfg.ckpt_dir, keep=cfg.keep, every=cfg.ckpt_every)
    monitor = StragglerMonitor()
    restarts = 0
    faults_remaining = 1 if cfg.inject_fault_at is not None else 0

    # step-0 checkpoint: the train step donates its state buffers, so a crash
    # before the first periodic checkpoint must restore from step 0 rather
    # than reuse (already-donated) init_state.
    from repro.ft.checkpoint import latest_step, save_state
    if latest_step(cfg.ckpt_dir) is None:
        save_state(init_state, cfg.ckpt_dir, 0, async_io=False)

    while True:
        restored, start = mgr.restore_latest(init_state, shardings)
        state = restored if restored is not None else init_state
        step = start
        try:
            while step < cfg.total_steps:
                batch = batches(step)
                t0 = time.perf_counter()
                if faults_remaining and step == cfg.inject_fault_at:
                    faults_remaining -= 1
                    raise InjectedFault(f"injected at step {step}")
                state, metrics = train_step(state, batch)
                jax.block_until_ready(metrics["loss"])
                dt = time.perf_counter() - t0
                action = monitor.update(dt)
                if action == "checkpoint_and_evict":
                    mgr.maybe_save(state, step + 1)  # snapshot before evict
                step += 1
                mgr.maybe_save(state, step)
                if on_metrics:
                    on_metrics(step, metrics, dt)
            mgr.wait()
            return state, step
        except InjectedFault:
            restarts += 1
            if restarts > cfg.max_restarts:
                raise
            mgr.wait()  # flush any pending async save, then "restart"
            continue
