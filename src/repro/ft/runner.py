"""Fault-tolerant training loop: periodic async checkpoints, resume-from-
latest, straggler watchdog, and crash-retry — the loop a real multi-pod job
runs under a cluster scheduler.

Dispatch modes
--------------
Stepwise (``chunk_steps=1``): one ``train_step(state, batches(step))`` call
per step.  The loop hard-syncs on the step's metrics only when an
``on_metrics`` callback is registered (the callback's ``dt`` is then true
per-step wall time); without one, steps are dispatched asynchronously and
the host syncs only at checkpoint boundaries and loop exit — the straggler
monitor then sees *dispatch* time, not compute time.

Chunked (``chunk_steps>1`` + a ``chunk_fn``): ``chunk_fn(state, start, n)``
runs ``n`` steps in one jitted ``lax.scan`` dispatch (batches synthesized
on-device — see train/engine.build_chunked) and returns per-step metrics
stacked ``(n, ...)``.  The loop dispatches chunk N+1 *before* syncing chunk
N's metrics, so the device never idles on the host fetch; metrics cross to
the host once per chunk.  Chunk ends are clipped to checkpoint boundaries,
``total_steps`` (the final ragged chunk runs at its own static length), and
the fault-injection step, so checkpoints land exactly where the stepwise
loop would put them and a resume starts from any chunk boundary.  The
straggler monitor is fed once per chunk with the chunk's wall time
(dispatch-to-metrics-retired, clamped against overlap) divided by the
chunk length — per-step units, so mixed chunk lengths and stepwise runs
share one EWMA scale.

Fault injection (``inject_fault_at``) lets tests exercise the recovery path
deterministically on CPU: the loop "crashes" at a chosen step, then the
restart resumes from the latest checkpoint and must reach the same final
state as an uninterrupted run (tests/test_fault_tolerance.py,
tests/test_chunked_training.py).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable

import jax

from repro.ft.checkpoint import CheckpointManager
from repro.ft.straggler import StragglerMonitor


class InjectedFault(RuntimeError):
    pass


@dataclasses.dataclass
class RunnerConfig:
    total_steps: int
    ckpt_dir: str
    ckpt_every: int = 50
    keep: int = 3
    max_restarts: int = 3
    inject_fault_at: int | None = None


def _next_boundary(step: int, every: int) -> int:
    return (step // every + 1) * every


def run(train_step: Callable, init_state, batches: Callable[[int], Any],
        cfg: RunnerConfig, *, shardings=None, on_metrics=None,
        chunk_fn: Callable | None = None, chunk_steps: int = 1):
    """Run to cfg.total_steps with checkpoint/restart.

    Returns ``(state, step)``: the final state and the step count reached.

    ``batches`` is a *seekable* factory — ``batches(step) -> batch`` must
    return the same batch for the same step on every call, so a restart
    replays the data stream deterministically from the resume step.  With
    ``chunk_steps > 1`` a ``chunk_fn(state, start, n)`` is required and
    ``batches`` is not consulted (the chunk synthesizes its own batches from
    the step index); the two modes are bit-identical by construction.
    """
    if chunk_steps > 1 and chunk_fn is None:
        raise ValueError("chunk_steps > 1 requires a chunk_fn "
                         "(see train/engine.build_chunked)")
    mgr = CheckpointManager(cfg.ckpt_dir, keep=cfg.keep, every=cfg.ckpt_every)
    monitor = StragglerMonitor()
    restarts = 0
    faults_remaining = 1 if cfg.inject_fault_at is not None else 0

    # step-0 checkpoint: the train step donates its state buffers, so a crash
    # before the first periodic checkpoint must restore from step 0 rather
    # than reuse (already-donated) init_state.
    from repro.ft.checkpoint import latest_step, save_state
    if latest_step(cfg.ckpt_dir) is None:
        save_state(init_state, cfg.ckpt_dir, 0,  # jaxlint: disable=HOSTSYNC -- step-0 checkpoint runs before the loop starts; syncing here is the point
                   async_io=False)

    while True:
        restored, start = mgr.restore_latest(init_state, shardings)
        state = restored if restored is not None else init_state
        step = start
        try:
            if chunk_steps > 1:
                state, step = _chunked_loop(
                    chunk_fn, state, step, cfg, mgr, monitor,
                    on_metrics=on_metrics, chunk_steps=chunk_steps,
                    fault_live=faults_remaining > 0)
            else:
                state, step = _stepwise_loop(
                    train_step, state, step, batches, cfg, mgr, monitor,
                    on_metrics=on_metrics, fault_live=faults_remaining > 0)
            if step is None:  # fault fired inside the loop
                faults_remaining -= 1
                raise InjectedFault(f"injected at step {cfg.inject_fault_at}")
            mgr.wait()
            return state, step
        except InjectedFault:
            restarts += 1
            if restarts > cfg.max_restarts:
                raise
            mgr.wait()  # flush any pending async save, then "restart"
            continue


def _stepwise_loop(train_step, state, step, batches, cfg, mgr, monitor, *,
                   on_metrics, fault_live):
    """One step per dispatch.  Returns (state, step), or (state, None) when
    the injected fault fires (the caller raises — keeping the raise outside
    lets both loops share the restart bookkeeping)."""
    sync_each_step = on_metrics is not None
    while step < cfg.total_steps:
        batch = batches(step)
        t0 = time.perf_counter()
        if fault_live and step == cfg.inject_fault_at:
            return state, None
        state, metrics = train_step(state, batch)
        if sync_each_step:
            jax.block_until_ready(metrics["loss"])  # jaxlint: disable=HOSTSYNC -- opt-in sync_each_step mode exists to measure true per-step latency
        # without a callback, dt is dispatch time only (async steps); the
        # straggler EWMA then watches dispatch latency, documented above
        dt = time.perf_counter() - t0
        action = monitor.update(dt)
        if action == "checkpoint_and_evict":
            mgr.maybe_save(state, step + 1, force=True)  # snapshot pre-evict
        step += 1
        mgr.maybe_save(state, step)  # device->host snapshot = a sync point
        if on_metrics:
            on_metrics(step, metrics, dt)
    jax.block_until_ready(state)  # jaxlint: disable=HOSTSYNC -- loop exit: the promised final sync, once per run
    return state, step


def _chunked_loop(chunk_fn, state, step, cfg, mgr, monitor, *, on_metrics,
                  chunk_steps, fault_live):
    """Whole chunks per dispatch, metrics retired one chunk behind.
    Returns (state, step) or (state, None) when the injected fault fires."""
    inflight = None  # (chunk start step, n, stacked metrics, dispatch t0)
    retired_at = float("-inf")  # when the device last went idle (host clock)

    def retire(chunk):
        """Block on a chunk's stacked metrics, fan them out per step."""
        nonlocal retired_at
        c_start, n, metrics, t0 = chunk
        host = jax.device_get(metrics)  # ONE host fetch for n steps
        now = time.perf_counter()
        # a chunk dispatched while its predecessor was still computing only
        # *started* when the predecessor retired — clamp so overlapped wall
        # time isn't double-counted in dt / the straggler EWMA
        dt = now - max(t0, retired_at)
        retired_at = now
        # per-step normalized: boundary-clipped chunks vary in length, and
        # the EWMA must compare like with like (and with stepwise runs)
        action = monitor.update(dt / n)
        if on_metrics:
            for i in range(n):
                on_metrics(c_start + i + 1,
                           jax.tree.map(lambda m: m[i], host), dt / n)
        return action

    while step < cfg.total_steps:
        if fault_live and step == cfg.inject_fault_at:
            if inflight is not None:  # deliver completed steps' metrics
                retire(inflight)
            return state, None
        n = min(chunk_steps, cfg.total_steps - step,
                _next_boundary(step, cfg.ckpt_every) - step)
        if fault_live and step < cfg.inject_fault_at:
            n = min(n, cfg.inject_fault_at - step)
        t0 = time.perf_counter()
        new_state, metrics = chunk_fn(state, step, n)  # async dispatch
        prev, inflight = inflight, (step, n, metrics, t0)
        state, step = new_state, step + n
        if prev is not None:  # overlap: chunk N computes while N-1 retires
            if retire(prev) == "checkpoint_and_evict":
                mgr.maybe_save(state, step, force=True)  # snapshot pre-evict
        if step % cfg.ckpt_every == 0 and step < cfg.total_steps:
            # retire before saving: the snapshot is a sync point anyway, and
            # the next dispatch donates these state buffers
            retire(inflight)
            inflight = None
            mgr.maybe_save(state, step)
    if inflight is not None:
        retire(inflight)
    jax.block_until_ready(state)  # jaxlint: disable=HOSTSYNC -- chunked-loop exit: one final sync after the last chunk retires
    mgr.maybe_save(state, step)
    return state, step
