"""Sharded, mesh-shape-agnostic checkpointing (no tensorstore dependency).

Layout:
    <dir>/step_<N>/
        manifest.json            tree structure + leaf shapes/dtypes
        leaf_<i>/shard_<j>.npy   one file per addressable shard
        leaf_<i>.npy             (small leaves: single global array)
    <dir>/LATEST                 atomic pointer (tmp+rename)

Each shard file records its *global index* (slices into the global array), so
restore can reassemble onto ANY mesh/sharding — the elastic-scaling property:
a checkpoint from a 256-chip run restores onto 512 chips or 8 (DESIGN.md §5).

Async mode: device->host transfer happens synchronously (cheap), file IO on a
background thread so the train loop isn't blocked (the standard async-ckpt
split).  ``CheckpointManager`` keeps the last K checkpoints and handles
resume-from-latest.
"""

from __future__ import annotations

import json
import os
import pathlib
import shutil
import threading
from concurrent.futures import ThreadPoolExecutor

import jax
import numpy as np

_SMALL = 1 << 20  # leaves below 1 MiB are stored as single global arrays


def _leaf_paths(tree):
    leaves, treedef = jax.tree.flatten(tree)
    return leaves, treedef


def _index_to_json(idx, shape):
    out = []
    for sl, dim in zip(idx, shape):
        start = 0 if sl.start is None else int(sl.start)
        stop = dim if sl.stop is None else int(sl.stop)
        out.append([start, stop])
    return out


def save_state(state, directory, step: int, *, async_io: bool = True,
               _executor=ThreadPoolExecutor(max_workers=2)):
    """Save a pytree of (possibly sharded) jax arrays. Returns a wait() fn."""
    directory = pathlib.Path(directory)
    tmp = directory / f".tmp_step_{step}"
    final = directory / f"step_{step}"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir(parents=True)

    leaves, treedef = _leaf_paths(state)
    # tree structure is carried by the restore-side `like` tree (restore_state
    # asserts leaf counts); record the repr for human debugging only.
    manifest = {"step": step, "treedef_repr": str(treedef)[:2000],
                "n_leaves": len(leaves), "leaves": []}

    # synchronous device->host snapshot; file IO deferred to the worker
    work = []
    for i, leaf in enumerate(leaves):
        arr = leaf
        info = {"shape": list(np.shape(arr)),
                "dtype": str(np.asarray(jax.tree.leaves(arr)[0]).dtype)
                if not hasattr(arr, "dtype") else str(arr.dtype),
                "shards": []}
        if hasattr(arr, "addressable_shards") and arr.nbytes > _SMALL:
            for j, shard in enumerate(arr.addressable_shards):
                host = np.asarray(shard.data)
                idx = _index_to_json(shard.index, arr.shape)
                # skip duplicate replicas: only save the first owner
                if any(s["index"] == idx for s in info["shards"]):
                    continue
                fn = f"leaf_{i}/shard_{len(info['shards'])}.npy"
                info["shards"].append({"file": fn, "index": idx})
                work.append((tmp / fn, host))
        else:
            host = np.asarray(jax.device_get(arr))
            fn = f"leaf_{i}.npy"
            info["file"] = fn
            work.append((tmp / fn, host))
        manifest["leaves"].append(info)

    def flush():
        for path, host in work:
            path.parent.mkdir(parents=True, exist_ok=True)
            np.save(path, host)
        (tmp / "manifest.json").write_text(json.dumps(manifest))
        if final.exists():
            shutil.rmtree(final)
        os.rename(tmp, final)
        latest_tmp = directory / ".LATEST.tmp"
        latest_tmp.write_text(str(step))
        os.replace(latest_tmp, directory / "LATEST")

    if async_io:
        fut = _executor.submit(flush)
        return fut.result  # wait() function
    flush()
    return lambda: None


def latest_step(directory) -> int | None:
    p = pathlib.Path(directory) / "LATEST"
    if not p.exists():
        return None
    return int(p.read_text().strip())


def restore_state(like, directory, step: int | None = None, *,
                  shardings=None):
    """Restore into the structure of ``like`` (a pytree of arrays or
    ShapeDtypeStructs).  ``shardings``: optional matching pytree of
    NamedSharding to place leaves onto (elastic restore onto a new mesh)."""
    directory = pathlib.Path(directory)
    if step is None:
        step = latest_step(directory)
        assert step is not None, f"no checkpoint under {directory}"
    d = directory / f"step_{step}"
    manifest = json.loads((d / "manifest.json").read_text())
    leaves, treedef = _leaf_paths(like)
    assert len(leaves) == manifest["n_leaves"], "tree structure mismatch"
    shard_leaves = (jax.tree.leaves(shardings) if shardings is not None
                    else [None] * len(leaves))

    out = []
    for i, (leaf, info) in enumerate(zip(leaves, manifest["leaves"])):
        if "file" in info:
            host = np.load(d / info["file"])
        else:
            host = np.zeros(info["shape"], dtype=info["dtype"])
            for s in info["shards"]:
                idx = tuple(slice(a, b) for a, b in s["index"])
                host[idx] = np.load(d / s["file"])
        if shard_leaves[i] is not None:
            out.append(jax.device_put(host, shard_leaves[i]))
        else:
            out.append(jax.device_put(host))
    return jax.tree.unflatten(jax.tree.structure(like), out)


class CheckpointManager:
    """Keep-last-K manager with async save and resume."""

    def __init__(self, directory, *, keep: int = 3, every: int = 100):
        self.dir = pathlib.Path(directory)
        self.keep = keep
        self.every = every
        self._pending = None
        self._lock = threading.Lock()

    def maybe_save(self, state, step: int, *, force: bool = False) -> bool:
        """Save if ``step`` is on the period — or unconditionally with
        ``force`` (eviction snapshots land wherever the straggler fired)."""
        if not force and step % self.every:
            return False
        self.wait()
        inner = save_state(state, self.dir, step, async_io=True)

        def finish():  # GC only after the rename landed
            inner()
            self._gc()

        self._pending = finish
        return True

    def wait(self):
        with self._lock:
            if self._pending is not None:
                self._pending()
                self._pending = None

    def _gc(self):
        steps = sorted(int(p.name.split("_")[1])
                       for p in self.dir.glob("step_*"))
        for s in steps[:-self.keep]:
            shutil.rmtree(self.dir / f"step_{s}", ignore_errors=True)

    def restore_latest(self, like, shardings=None):
        step = latest_step(self.dir)
        if step is None:
            return None, 0
        return restore_state(like, self.dir, step, shardings=shardings), step
