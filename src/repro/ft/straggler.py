"""Straggler detection & mitigation policy.

At 1000+ nodes, slow hosts (thermal throttling, failing HBM, network
degradation) stretch every synchronous step.  The monitor keeps an EWMA of
step times; a step slower than ``threshold x`` the EWMA increments a strike
counter, and ``strikes`` consecutive slow steps trigger a mitigation action:

    "checkpoint_and_evict" — snapshot via CheckpointManager, remove the slow
    host from the next job restart (elastic re-mesh handles the smaller
    device count — see ft/elastic.py).

On this CPU container the monitor is exercised by tests with synthetic
timings; on a real cluster the per-host step times come from the
coordination service heartbeats.
"""

from __future__ import annotations

import dataclasses


@dataclasses.dataclass
class StragglerMonitor:
    threshold: float = 1.8     # step slower than 1.8x EWMA is "slow"
    strikes: int = 3           # consecutive slow steps before mitigation
    ema: float = 0.9
    warmup: int = 5            # ignore the first steps (compile, cache warm)

    _mean: float = 0.0
    _count: int = 0
    _strikes: int = 0

    def update(self, step_seconds: float, host: int = 0) -> str | None:
        """Feed one step time. Returns a mitigation action or None."""
        self._count += 1
        if self._count <= self.warmup:
            self._mean = step_seconds if self._mean == 0.0 else (
                0.5 * self._mean + 0.5 * step_seconds)
            return None
        slow = step_seconds > self.threshold * self._mean
        if slow:
            self._strikes += 1
        else:
            self._strikes = 0
            self._mean = self.ema * self._mean + (1 - self.ema) * step_seconds
        if self._strikes >= self.strikes:
            self._strikes = 0
            return "checkpoint_and_evict"
        return None

    @property
    def mean_step_seconds(self) -> float:
        return self._mean
