"""Straggler detection & mitigation policy.

At 1000+ nodes, slow hosts (thermal throttling, failing HBM, network
degradation) stretch every synchronous step.  The monitor keeps an EWMA of
step times; a step slower than ``threshold x`` the EWMA increments a strike
counter, and ``strikes`` consecutive slow steps trigger a mitigation action:

    "checkpoint_and_evict" — snapshot via CheckpointManager, remove the slow
    host from the next job restart (elastic re-mesh handles the smaller
    device count — see ft/elastic.py).

On this CPU container the monitor is exercised by tests with synthetic
timings; on a real cluster the per-host step times come from the
coordination service heartbeats.
"""

from __future__ import annotations

import dataclasses


@dataclasses.dataclass
class Ewma:
    """Exponentially-weighted moving average over a stream of observations.

    ``value = alpha * value + (1 - alpha) * x`` — the first observation
    seeds the average.  The same smoother tracks step times here and the
    serving stack's per-wave staging/compute overlap and service rate
    (``serve.admission``), so every adaptive loop in the repo shares one
    well-tested primitive.
    """

    alpha: float = 0.9
    value: float | None = None

    def update(self, x: float, alpha: float | None = None) -> float:
        """Fold one observation in; ``alpha`` overrides the blend for this
        sample only (the monitor's warmup uses a faster 0.5 blend)."""
        a = self.alpha if alpha is None else alpha
        self.value = x if self.value is None else a * self.value + (1 - a) * x
        return self.value


@dataclasses.dataclass
class StragglerMonitor:
    threshold: float = 1.8     # step slower than 1.8x EWMA is "slow"
    strikes: int = 3           # consecutive slow steps before mitigation
    ema: float = 0.9
    warmup: int = 5            # ignore the first steps (compile, cache warm)

    _count: int = 0
    _strikes: int = 0
    _mean_ewma: Ewma | None = None

    def __post_init__(self):
        if self._mean_ewma is None:
            self._mean_ewma = Ewma(alpha=self.ema)

    def update(self, step_seconds: float, host: int = 0) -> str | None:
        """Feed one step time. Returns a mitigation action or None."""
        self._count += 1
        if self._count <= self.warmup:
            self._mean_ewma.update(step_seconds, alpha=0.5)
            return None
        slow = step_seconds > self.threshold * self.mean_step_seconds
        if slow:
            self._strikes += 1
        else:
            self._strikes = 0
            self._mean_ewma.update(step_seconds)
        if self._strikes >= self.strikes:
            self._strikes = 0
            return "checkpoint_and_evict"
        return None

    @property
    def mean_step_seconds(self) -> float:
        return self._mean_ewma.value if self._mean_ewma.value is not None \
            else 0.0
