"""Pure-jnp oracle for the fused training kernel.

Semantics being checked: sequential SGD over batch tiles — for each tile,
compute the MSE loss/grads of the *current* weights via ``jax.value_and_grad``
(autodiff is the gradient oracle; the kernel hand-derives Eq. 2), then apply
one SGD update.  ``tile_batch=1`` is the paper-faithful per-sample stream.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import mrf_net


def _tile_loss(params, x, y, qat: bool):
    if qat:
        def fq(w):
            s = jnp.max(jnp.abs(w), axis=0, keepdims=True) / 127.0 + 1e-12
            q = jnp.clip(jnp.round(w / s), -127, 127) * s
            return w + jax.lax.stop_gradient(q - w)  # STE, matches kernel fwd math
        qparams = [{"w": fq(p["w"]), "b": p["b"]} for p in params]
    else:
        qparams = params
    pred = mrf_net.forward(qparams, x)
    return jnp.mean(jnp.square(pred - y))


def ref_train(params, x, y, *, lr: float, tile_batch: int, qat: bool = False):
    """Returns (new_params, per-tile losses). x: (B, D_in), y: (B, out)."""
    batch = x.shape[0]
    assert batch % tile_batch == 0
    n_tiles = batch // tile_batch
    xt = x.reshape(n_tiles, tile_batch, -1)
    yt = y.reshape(n_tiles, tile_batch, -1)

    def step(params, xy):
        xi, yi = xy
        loss, grads = jax.value_and_grad(_tile_loss)(params, xi, yi, qat)
        params = jax.tree.map(lambda p, g: p - lr * g, params, grads)
        return params, loss

    return jax.lax.scan(step, params, (xt, yt))
