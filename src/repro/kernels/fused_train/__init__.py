"""Fused train-step kernel (pallas) + reference implementation."""
