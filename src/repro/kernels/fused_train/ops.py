"""Public wrapper for the fused training kernel: pads the MRF net's ragged
layer list to the kernel's uniform (L, 128, 128) layout, runs the kernel, and
unpads back to the param pytree.

The zero padding is *self-preserving*: padded weight rows/cols and biases are
zero, padded activations stay exactly 0 through ReLU, and every padded
gradient entry is a product with one of those zeros — so the unpadded result
equals the unpadded math (asserted against ref.py in the tests).
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.fused_train.kernel import PAD, fused_train_call


def pad_params(params):
    """Ragged [{'w','b'}] -> ((L,PAD,PAD), (L,PAD)) zero-padded stacks."""
    n_layers = len(params)
    w = jnp.zeros((n_layers, PAD, PAD), jnp.float32)
    b = jnp.zeros((n_layers, PAD), jnp.float32)
    for l, layer in enumerate(params):
        i, o = layer["w"].shape
        assert i <= PAD and o <= PAD, f"layer {l} ({i}x{o}) exceeds PAD={PAD}"
        w = w.at[l, :i, :o].set(layer["w"].astype(jnp.float32))
        b = b.at[l, :o].set(layer["b"].astype(jnp.float32))
    return w, b


def unpad_params(w_pad, b_pad, like):
    out = []
    for l, layer in enumerate(like):
        i, o = layer["w"].shape
        out.append({"w": w_pad[l, :i, :o], "b": b_pad[l, :o]})
    return out


def fused_train_step(params, x, y, *, lr: float, tile_batch: int = 128,
                     qat: bool = False, interpret: bool | None = None):
    """One fused pass over batch (B, D_in)/(B, out): streams tiles through the
    VMEM-resident net.  Returns (new_params, per-tile losses)."""
    batch, d_in = x.shape
    out_dim = y.shape[-1]
    assert d_in <= PAD, f"feature dim {d_in} > PAD={PAD}"
    assert batch % tile_batch == 0, (batch, tile_batch)
    x_pad = jnp.zeros((batch, PAD), jnp.float32).at[:, :d_in].set(x)
    y_pad = jnp.zeros((batch, PAD), jnp.float32).at[:, :out_dim].set(y)
    w_pad, b_pad = pad_params(params)
    w_new, b_new, losses = fused_train_call(
        x_pad, y_pad, w_pad, b_pad, n_layers=len(params), out_dim=out_dim,
        lr=lr, tile_batch=tile_batch, qat=qat, interpret=interpret)
    return unpad_params(w_new, b_new, params), losses


def effective_tile(batch: int, tile_batch: int) -> int:
    """Largest tile <= tile_batch that divides ``batch`` (kernel grid
    constraint); degrades toward per-sample streaming rather than crashing
    on awkward batch sizes."""
    t = min(tile_batch, batch)
    while batch % t:
        t -= 1
    return t


def make_engine_step(*, lr: float, tile_batch: int = 128, qat: bool = False,
                     interpret: bool | None = None):
    """The ``fused_step`` backend for ``repro.train.step.make_train_step``.

    Conforms the kernel to the engine contract
    ``(params, aux, batch) -> (new_params, new_aux, metrics)``: the whole
    grads+SGD-update pipeline runs inside the kernel, so there is no grad
    pytree and no optimizer state to touch — aux passes through untouched and
    the metrics carry the mean over per-tile losses (each tile sees params
    already updated by its predecessors, the paper's sequential-SGD regime).

    ``tile_batch`` is a ceiling: the actual tile is the largest divisor of
    the (static) batch size not exceeding it.
    """
    def fused(params, aux, batch):
        new_params, losses = fused_train_step(
            params, batch["x"], batch["y"], lr=lr,
            tile_batch=effective_tile(batch["x"].shape[0], tile_batch),
            qat=qat, interpret=interpret)
        return new_params, aux, {"loss": jnp.mean(losses)}
    return fused
