"""Public wrapper for the fused training kernel: pads the MRF net's ragged
layer list to the kernel's uniform (L, 128, 128) layout, runs the kernel, and
unpads back to the param pytree.

The zero padding is *self-preserving*: padded weight rows/cols and biases are
zero, padded activations stay exactly 0 through ReLU, and every padded
gradient entry is a product with one of those zeros — so the unpadded result
equals the unpadded math (asserted against ref.py in the tests).
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.fused_train.kernel import PAD, fused_train_call
from repro.kernels.fused_train.multistep import (fused_train_adam_call,
                                                fused_train_multistep_call)
from repro.optim.optimizers import AdamState

# Optimizer rules the fused kernels implement in-VMEM.  Anything else must
# use a stepwise backend (the kernel would silently train with the wrong
# rule otherwise).
FUSED_OPTIMIZERS = ("sgd", "adam")


def pad_params(params):
    """Ragged [{'w','b'}] -> ((L,PAD,PAD), (L,PAD)) zero-padded stacks."""
    n_layers = len(params)
    w = jnp.zeros((n_layers, PAD, PAD), jnp.float32)
    b = jnp.zeros((n_layers, PAD), jnp.float32)
    for l, layer in enumerate(params):
        i, o = layer["w"].shape
        assert i <= PAD and o <= PAD, f"layer {l} ({i}x{o}) exceeds PAD={PAD}"
        w = w.at[l, :i, :o].set(layer["w"].astype(jnp.float32))
        b = b.at[l, :o].set(layer["b"].astype(jnp.float32))
    return w, b


def unpad_params(w_pad, b_pad, like):
    out = []
    for l, layer in enumerate(like):
        i, o = layer["w"].shape
        out.append({"w": w_pad[l, :i, :o], "b": b_pad[l, :o]})
    return out


def fused_train_step(params, x, y, *, lr: float, tile_batch: int = 128,
                     qat: bool = False, interpret: bool | None = None):
    """One fused pass over batch (B, D_in)/(B, out): streams tiles through the
    VMEM-resident net.  Returns (new_params, per-tile losses)."""
    batch, d_in = x.shape
    out_dim = y.shape[-1]
    assert d_in <= PAD, f"feature dim {d_in} > PAD={PAD}"
    assert batch % tile_batch == 0, (batch, tile_batch)
    x_pad = jnp.zeros((batch, PAD), jnp.float32).at[:, :d_in].set(x)
    y_pad = jnp.zeros((batch, PAD), jnp.float32).at[:, :out_dim].set(y)
    w_pad, b_pad = pad_params(params)
    w_new, b_new, losses = fused_train_call(
        x_pad, y_pad, w_pad, b_pad, n_layers=len(params), out_dim=out_dim,
        lr=lr, tile_batch=tile_batch, qat=qat, interpret=interpret)
    return unpad_params(w_new, b_new, params), losses


def effective_tile(batch: int, tile_batch: int) -> int:
    """Largest tile <= tile_batch that divides ``batch`` (kernel grid
    constraint); degrades toward per-sample streaming rather than crashing
    on awkward batch sizes."""
    t = min(tile_batch, batch)
    while batch % t:
        t -= 1
    return t


def fused_train_multistep(params, opt_state, x, y, *, n_steps: int, lr: float,
                          optimizer: str = "sgd", tile_batch: int = 128,
                          qat: bool = False, interpret: bool | None = None):
    """K training steps in **one** kernel launch, weights (and Adam moments)
    VMEM-resident across all of them.

    ``x``/``y``: ``(K*B, d_in)`` / ``(K*B, out_dim)`` — K steps' batches
    pre-staged back to back (step k = rows ``[k*B, (k+1)*B)``).  The tile is
    the largest divisor of the *per-step* batch B not exceeding
    ``tile_batch``, so no tile ever straddles a step boundary and the grid
    flattens cleanly to ``(K * n_tiles,)``.

    ``opt_state``: for ``optimizer="adam"`` an ``optim.optimizers.AdamState``
    (moments padded into kernel stacks, ``step`` advanced by one per tile —
    the kernel performs one Adam update per tile); for ``"sgd"`` any state
    with a ``step`` field (advanced by ``n_steps``) or ``None``.

    Returns ``(new_params, new_opt_state, losses (K, n_tiles))`` — row k is
    step k's per-tile losses, bit-identical to what K sequential
    single-step fused calls would have produced.
    """
    total, d_in = x.shape
    out_dim = y.shape[-1]
    if total % n_steps:
        raise ValueError(f"staged stream of {total} rows is not divisible "
                         f"into n_steps={n_steps} equal batches")
    per_step = total // n_steps
    tile = effective_tile(per_step, tile_batch)
    n_tiles = per_step // tile
    assert d_in <= PAD, f"feature dim {d_in} > PAD={PAD}"
    x_pad = jnp.zeros((total, PAD), jnp.float32).at[:, :d_in].set(x)
    y_pad = jnp.zeros((total, PAD), jnp.float32).at[:, :out_dim].set(y)
    w_pad, b_pad = pad_params(params)
    if optimizer == "sgd":
        w_new, b_new, tile_losses = fused_train_multistep_call(
            x_pad, y_pad, w_pad, b_pad, n_layers=len(params), out_dim=out_dim,
            lr=lr, tile_batch=tile, qat=qat, interpret=interpret)
        if opt_state is not None and hasattr(opt_state, "step"):
            new_opt = opt_state._replace(step=opt_state.step + n_steps)
        else:
            new_opt = opt_state
    elif optimizer == "adam":
        if not isinstance(opt_state, AdamState):
            raise ValueError(
                f"optimizer='adam' needs an AdamState, got {type(opt_state)!r}"
                " — build it with optim.optimizers.adam(lr).init(params)")
        mw_pad, mb_pad = pad_params(opt_state.mu)
        vw_pad, vb_pad = pad_params(opt_state.nu)
        step0 = opt_state.step.astype(jnp.int32).reshape(1, 1)
        (w_new, b_new, mw_new, mb_new, vw_new, vb_new,
         tile_losses) = fused_train_adam_call(
            step0, x_pad, y_pad, w_pad, b_pad, mw_pad, mb_pad, vw_pad, vb_pad,
            n_layers=len(params), out_dim=out_dim, lr=lr, tile_batch=tile,
            qat=qat, interpret=interpret)
        new_opt = AdamState(step=opt_state.step + n_steps * n_tiles,
                            mu=unpad_params(mw_new, mb_new, params),
                            nu=unpad_params(vw_new, vb_new, params))
    else:
        raise ValueError(
            f"fused backend implements optimizers {FUSED_OPTIMIZERS}, got "
            f"{optimizer!r}; use a stepwise backend for anything else")
    return (unpad_params(w_new, b_new, params), new_opt,
            tile_losses.reshape(n_steps, n_tiles))


def make_engine_step(*, lr: float, optimizer: str = "sgd",
                     tile_batch: int = 128, qat: bool = False,
                     interpret: bool | None = None):
    """The ``fused_step`` backend for ``repro.train.step.make_train_step``.

    Conforms the kernel to the engine contract
    ``(params, opt_state, aux, batch) -> (new_params, new_opt_state,
    new_aux, metrics)``: the whole grads+update pipeline runs inside the
    kernel with the engine's configured rule — in-kernel SGD (the paper's
    FPGA algorithm) or in-kernel Adam (moment stacks resident next to the
    weights).  aux passes through untouched and the metrics carry the mean
    over per-tile losses (each tile sees params already updated by its
    predecessors, the paper's sequential-update regime).

    ``tile_batch`` is a ceiling: the actual tile is the largest divisor of
    the (static) batch size not exceeding it.  Raises ``ValueError`` for an
    optimizer the kernel does not implement — silently training with the
    wrong rule is the one thing this backend must never do.
    """
    if optimizer not in FUSED_OPTIMIZERS:
        raise ValueError(
            f"fused-pallas trains in-kernel and implements only "
            f"{FUSED_OPTIMIZERS}; got optimizer={optimizer!r}. Use "
            f"backend='float' (or another stepwise backend) for it.")

    def fused(params, opt_state, aux, batch):
        new_params, new_opt, losses = fused_train_multistep(
            params, opt_state, batch["x"], batch["y"], n_steps=1, lr=lr,
            optimizer=optimizer, tile_batch=tile_batch, qat=qat,
            interpret=interpret)
        return new_params, new_opt, aux, {"loss": jnp.mean(losses, axis=1)[0]}
    return fused
