"""The paper's contribution as a TPU kernel: whole-network fused training
(forward + backprop + optimizer update) inside ``pl.pallas_call``.

FPGA -> TPU mapping (DESIGN.md §2), multi-step regime:

* ALVEO: weights live in BRAM/FF for the **entire training run** — the
  bitstream is configured once, then samples stream past the resident
  network until training ends.  Weight state never crosses the board's
  memory boundary mid-run.
* Here: all layer weights (and, for the Adam variant, both moment stacks)
  live in **VMEM scratch across every step of a launch** — loaded from HBM
  once at grid step 0, updated in place over all K steps x all batch tiles,
  and written back to HBM once at the final grid step (see ``multistep.py``,
  which flattens ``grid=(K * n_tiles,)`` over a pre-staged ``(K*B, PAD)``
  sample stream).  Per-launch weight HBM traffic is 2 transfers regardless
  of K — the single-step kernel in this file is the K=1 special case, where
  chunked dispatch had to re-enter the kernel (and re-stream the weight
  stack through HBM) every step.
* The "16-node semi-parallel block" becomes a 128-lane MXU tile: every layer
  is zero-padded to PAD=128 so each layer's matmul is one aligned MXU op.
  Zero padding is self-preserving through fwd+bwd (zero rows/cols stay zero;
  see tests), so no masking is needed except at the loss.

Grid semantics: TPU grids execute sequentially on a core, which makes the
read-modify-write of the scratch weights across grid steps sound (the same
property the classic Pallas matmul accumulator uses).  That sequencing is
exactly what makes the multi-step flattening legal: tile ``k*n_tiles + j``
always sees the weights as updated by every earlier tile of every earlier
step.

Two update modes:
* ``tile_batch = 1``  -> per-sample streaming SGD, the *faithful* FPGA
  algorithm (one update per training signal);
* ``tile_batch = T``  -> minibatch update per tile, the MXU-native
  reformulation (beyond-paper optimization; see EXPERIMENTS.md §Perf).

``train_tile`` is the shared per-tile body (forward, masked MSE loss,
hand-derived backward, optimizer callback): the single-step kernel here and
the multi-step kernels in ``multistep.py`` both inline it, which is what
makes a K-step launch bit-identical to K single-step launches.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

from repro.kernels.common import resolve_interpret

PAD = 128  # MXU lane width; every layer is padded to this many nodes.


def train_tile(x, y, w_s, b_s, h_s, update, *, n_layers: int, out_dim: int,
               qat: bool):
    """One batch tile through the VMEM-resident net: forward, masked MSE
    loss, backward (Eq. 2 of the paper), with the optimizer rule injected as
    ``update(l, dw, db)`` — called once per layer, in backward order, with
    the layer's raw gradients.  Returns the tile loss (f32 scalar).

    Every fused kernel (single-step SGD, multi-step SGD, multi-step Adam)
    runs this exact op sequence per tile, so their per-tile arithmetic is
    bit-identical by construction — only the update rule and the grid
    schedule differ.
    """
    tb = x.shape[0]

    def maybe_fq(w):
        if not qat:
            return w
        # symmetric per-channel int8 fake-quant of the live weights (QAT fwd)
        s = jnp.max(jnp.abs(w), axis=0, keepdims=True) / 127.0 + 1e-12
        return jnp.clip(jnp.round(w / s), -127, 127) * s

    # --- forward ------------------------------------------------------------
    h = x
    for l in range(n_layers):
        w_l = maybe_fq(w_s[l])
        z = jnp.dot(h, w_l, preferred_element_type=jnp.float32) + b_s[l][None, :]
        h = z if l == n_layers - 1 else jnp.maximum(z, 0.0)
        if l < n_layers - 1:
            h_s[l] = h  # post-activation, reused as both input and relu-mask in bwd

    # --- loss (MSE over the first out_dim lanes) -----------------------------
    lane = jax.lax.broadcasted_iota(jnp.int32, (tb, PAD), 1)
    mask = (lane < out_dim).astype(jnp.float32)
    diff = (h - y) * mask
    denom = jnp.float32(tb * out_dim)
    loss = jnp.sum(diff * diff) / denom

    # --- backward + in-scratch optimizer update ------------------------------
    dz = 2.0 * diff / denom
    for l in range(n_layers - 1, -1, -1):
        h_prev = x if l == 0 else h_s[l - 1]
        # propagate delta *before* updating this layer's weights
        if l > 0:
            w_l = maybe_fq(w_s[l])
            dh = jnp.dot(dz, w_l.T, preferred_element_type=jnp.float32)
            relu_mask = (h_prev > 0.0).astype(jnp.float32)
        dw = jnp.dot(h_prev.T, dz, preferred_element_type=jnp.float32)
        db = jnp.sum(dz, axis=0)
        update(l, dw, db)
        if l > 0:
            dz = dh * relu_mask
    return loss


def _sgd_update(w_s, b_s, lr: float):
    """The in-scratch SGD rule for ``train_tile`` (the paper's Eq. 2)."""
    def update(l, dw, db):
        w_s[l] = w_s[l] - lr * dw
        b_s[l] = b_s[l] - lr * db
    return update


def _kernel(x_ref, y_ref, w_in_ref, b_in_ref,            # inputs
            w_out_ref, b_out_ref, loss_ref,               # outputs
            w_s, b_s, h_s,                                # scratch
            *, n_layers: int, out_dim: int, lr: float, n_tiles: int,
            qat: bool):
    i = pl.program_id(0)

    # --- load weights into VMEM scratch once -------------------------------
    @pl.when(i == 0)
    def _init():
        w_s[...] = w_in_ref[...]
        b_s[...] = b_in_ref[...]

    loss_ref[0, 0] = train_tile(
        x_ref[...], y_ref[...], w_s, b_s, h_s, _sgd_update(w_s, b_s, lr),
        n_layers=n_layers, out_dim=out_dim, qat=qat)

    # --- flush updated weights to HBM once ----------------------------------
    @pl.when(i == n_tiles - 1)
    def _flush():
        w_out_ref[...] = w_s[...]
        b_out_ref[...] = b_s[...]


@functools.partial(jax.jit, static_argnames=("n_layers", "out_dim", "lr",
                                             "tile_batch", "qat", "interpret"))
def fused_train_call(x_pad, y_pad, w_pad, b_pad, *, n_layers: int, out_dim: int,
                     lr: float, tile_batch: int, qat: bool = False,
                     interpret: bool | None = None):
    """Run one fused pass over the whole (padded) batch.

    x_pad: (B, PAD) fp32; y_pad: (B, PAD) fp32; w_pad: (L, PAD, PAD);
    b_pad: (L, PAD).  B must be a multiple of tile_batch.
    Returns (w_new, b_new, per_tile_losses (B//tile_batch,)).
    ``interpret=None`` auto-detects: compiled on TPU, interpreter elsewhere.

    This is the single-step (K=1) kernel; multi-step launches with weights
    resident across steps — and the in-kernel Adam variant — live in
    ``multistep.py`` (``fused_train_multistep_call``).
    """
    interpret = resolve_interpret(interpret)
    batch, _ = x_pad.shape
    assert batch % tile_batch == 0, (batch, tile_batch)
    n_tiles = batch // tile_batch
    kern = functools.partial(_kernel, n_layers=n_layers, out_dim=out_dim,
                             lr=lr, n_tiles=n_tiles, qat=qat)
    w_new, b_new, losses = pl.pallas_call(
        kern,
        grid=(n_tiles,),
        in_specs=[
            pl.BlockSpec((tile_batch, PAD), lambda i: (i, 0)),   # x tile
            pl.BlockSpec((tile_batch, PAD), lambda i: (i, 0)),   # y tile
            pl.BlockSpec((n_layers, PAD, PAD), lambda i: (0, 0, 0)),
            pl.BlockSpec((n_layers, PAD), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((n_layers, PAD, PAD), lambda i: (0, 0, 0)),
            pl.BlockSpec((n_layers, PAD), lambda i: (0, 0)),
            pl.BlockSpec((1, 1), lambda i: (i, 0)),  # jaxlint: disable=PALLASTILE -- one scalar loss per grid step; pads one tile, negligible next to the weights
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n_layers, PAD, PAD), jnp.float32),
            jax.ShapeDtypeStruct((n_layers, PAD), jnp.float32),
            jax.ShapeDtypeStruct((n_tiles, 1), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((n_layers, PAD, PAD), jnp.float32),       # weights
            pltpu.VMEM((n_layers, PAD), jnp.float32),            # biases
            pltpu.VMEM((max(n_layers - 1, 1), tile_batch, PAD), jnp.float32),
        ],
        interpret=interpret,
    )(x_pad, y_pad, w_pad, b_pad)
    return w_new, b_new, losses[:, 0]
