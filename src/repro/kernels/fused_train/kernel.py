"""The paper's contribution as a TPU kernel: a whole-network fused training
step (forward + backprop + SGD update) in a single ``pl.pallas_call``.

FPGA -> TPU mapping (DESIGN.md §2):

* ALVEO: weights live in BRAM/FF for the entire run; samples stream through a
  16-node block time-multiplexed over layers.
* Here: all layer weights live in **VMEM scratch for the entire grid** —
  loaded from HBM once (grid step 0), updated in-place every batch tile, and
  written back to HBM once (last grid step).  The grid streams batch tiles,
  so per-step HBM traffic is *samples only*, exactly the paper's regime.
* The "16-node semi-parallel block" becomes a 128-lane MXU tile: every layer
  is zero-padded to PAD=128 so each layer's matmul is one aligned MXU op.
  Zero padding is self-preserving through fwd+bwd (zero rows/cols stay zero;
  see tests), so no masking is needed except at the loss.

Grid semantics: TPU grids execute sequentially on a core, which makes the
read-modify-write of the scratch weights across grid steps sound (the same
property the classic Pallas matmul accumulator uses).

Two update modes:
* ``tile_batch = 1``  -> per-sample streaming SGD, the *faithful* FPGA
  algorithm (one update per training signal);
* ``tile_batch = T``  -> minibatch-SGD per tile, the MXU-native reformulation
  (beyond-paper optimization; see EXPERIMENTS.md §Perf).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

from repro.kernels.common import resolve_interpret

PAD = 128  # MXU lane width; every layer is padded to this many nodes.


def _kernel(x_ref, y_ref, w_in_ref, b_in_ref,            # inputs
            w_out_ref, b_out_ref, loss_ref,               # outputs
            w_s, b_s, h_s,                                # scratch
            *, n_layers: int, out_dim: int, lr: float, n_tiles: int,
            qat: bool):
    i = pl.program_id(0)

    # --- load weights into VMEM scratch once -------------------------------
    @pl.when(i == 0)
    def _init():
        w_s[...] = w_in_ref[...]
        b_s[...] = b_in_ref[...]

    x = x_ref[...]           # (T, PAD) fp32, feature-padded with zeros
    y = y_ref[...]           # (T, PAD) fp32, target-padded with zeros
    tb = x.shape[0]

    def maybe_fq(w):
        if not qat:
            return w
        # symmetric per-channel int8 fake-quant of the live weights (QAT fwd)
        s = jnp.max(jnp.abs(w), axis=0, keepdims=True) / 127.0 + 1e-12
        return jnp.clip(jnp.round(w / s), -127, 127) * s

    # --- forward ------------------------------------------------------------
    h = x
    for l in range(n_layers):
        w_l = maybe_fq(w_s[l])
        z = jnp.dot(h, w_l, preferred_element_type=jnp.float32) + b_s[l][None, :]
        h = z if l == n_layers - 1 else jnp.maximum(z, 0.0)
        if l < n_layers - 1:
            h_s[l] = h  # post-activation, reused as both input and relu-mask in bwd

    # --- loss (MSE over the first out_dim lanes) -----------------------------
    lane = jax.lax.broadcasted_iota(jnp.int32, (tb, PAD), 1)
    mask = (lane < out_dim).astype(jnp.float32)
    diff = (h - y) * mask
    denom = jnp.float32(tb * out_dim)
    loss_ref[0, 0] = jnp.sum(diff * diff) / denom

    # --- backward + in-scratch SGD update (Eq. 2 of the paper) ---------------
    dz = 2.0 * diff / denom
    for l in range(n_layers - 1, -1, -1):
        h_prev = x if l == 0 else h_s[l - 1]
        # propagate delta *before* updating this layer's weights
        if l > 0:
            w_l = maybe_fq(w_s[l])
            dh = jnp.dot(dz, w_l.T, preferred_element_type=jnp.float32)
            relu_mask = (h_prev > 0.0).astype(jnp.float32)
        dw = jnp.dot(h_prev.T, dz, preferred_element_type=jnp.float32)
        db = jnp.sum(dz, axis=0)
        w_s[l] = w_s[l] - lr * dw
        b_s[l] = b_s[l] - lr * db
        if l > 0:
            dz = dh * relu_mask

    # --- flush updated weights to HBM once ----------------------------------
    @pl.when(i == n_tiles - 1)
    def _flush():
        w_out_ref[...] = w_s[...]
        b_out_ref[...] = b_s[...]


@functools.partial(jax.jit, static_argnames=("n_layers", "out_dim", "lr",
                                             "tile_batch", "qat", "interpret"))
def fused_train_call(x_pad, y_pad, w_pad, b_pad, *, n_layers: int, out_dim: int,
                     lr: float, tile_batch: int, qat: bool = False,
                     interpret: bool | None = None):
    """Run one fused pass over the whole (padded) batch.

    x_pad: (B, PAD) fp32; y_pad: (B, PAD) fp32; w_pad: (L, PAD, PAD);
    b_pad: (L, PAD).  B must be a multiple of tile_batch.
    Returns (w_new, b_new, per_tile_losses (B//tile_batch,)).
    ``interpret=None`` auto-detects: compiled on TPU, interpreter elsewhere.
    """
    interpret = resolve_interpret(interpret)
    batch, _ = x_pad.shape
    assert batch % tile_batch == 0, (batch, tile_batch)
    n_tiles = batch // tile_batch
    kern = functools.partial(_kernel, n_layers=n_layers, out_dim=out_dim,
                             lr=lr, n_tiles=n_tiles, qat=qat)
    w_new, b_new, losses = pl.pallas_call(
        kern,
        grid=(n_tiles,),
        in_specs=[
            pl.BlockSpec((tile_batch, PAD), lambda i: (i, 0)),   # x tile
            pl.BlockSpec((tile_batch, PAD), lambda i: (i, 0)),   # y tile
            pl.BlockSpec((n_layers, PAD, PAD), lambda i: (0, 0, 0)),
            pl.BlockSpec((n_layers, PAD), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((n_layers, PAD, PAD), lambda i: (0, 0, 0)),
            pl.BlockSpec((n_layers, PAD), lambda i: (0, 0)),
            pl.BlockSpec((1, 1), lambda i: (i, 0)),  # jaxlint: disable=PALLASTILE -- one scalar loss per grid step; pads one tile, negligible next to the weights
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n_layers, PAD, PAD), jnp.float32),
            jax.ShapeDtypeStruct((n_layers, PAD), jnp.float32),
            jax.ShapeDtypeStruct((n_tiles, 1), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((n_layers, PAD, PAD), jnp.float32),       # weights
            pltpu.VMEM((n_layers, PAD), jnp.float32),            # biases
            pltpu.VMEM((max(n_layers - 1, 1), tile_batch, PAD), jnp.float32),
        ],
        interpret=interpret,
    )(x_pad, y_pad, w_pad, b_pad)
    return w_new, b_new, losses[:, 0]
