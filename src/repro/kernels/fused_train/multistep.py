"""Multi-step fused training: K train steps per kernel launch, weights (and
Adam moments) resident in VMEM across **all steps** — the true analogue of
the paper's on-FPGA training loop, where the network lives in BRAM for the
whole run and only samples stream past.

The single-step kernel (``kernel.py``) already keeps weights in VMEM across
the batch tiles *of one step*, but chunked dispatch re-entered the
``pallas_call`` every scan iteration: each of the K steps re-loaded and
re-flushed the full ``(L, PAD, PAD)`` weight stack through HBM (2K stack
transfers per chunk) and re-paid the padding/unpadding of the param pytree.
Here the grid flattens to ``(K * n_tiles,)`` over a pre-staged ``(K*B, PAD)``
sample stream: weights load at grid step 0, update in place across every
tile of every step, and flush once at the end — 2 stack transfers per chunk,
one Python dispatch, no scan re-entry.  TPU grids execute sequentially on a
core, so tile ``k*n_tiles + j`` sees the weights exactly as K single-step
launches would have left them: a K-step launch is **bit-identical** to K
sequential ``fused_train_call`` invocations (both inline
``kernel.train_tile``, so the per-tile arithmetic is the same ops in the
same order).

Two optimizer rules, selected statically:

* **SGD** (``fused_train_multistep_call``) — the paper's FPGA training rule,
  reusing the single-step kernel body over the longer flattened grid.
* **Adam** (``fused_train_adam_call``) — the paper's *software* baseline,
  now in-kernel: first/second moment stacks ride as extra input/output refs
  plus VMEM scratch (same residency as the weights), and the bias
  correction is driven by the traced global Adam step ``step0`` (an SMEM
  scalar), with ``t = step0 + tile_index + 1`` — each batch tile is one
  Adam update, the sequential-update regime the SGD kernel already uses.
  The update formula mirrors ``optim.optimizers.adam`` op for op, so given
  the same gradients it produces the same bits as the engine's software
  Adam on the padded math (zero-padded lanes have g = m = v = 0 and stay
  exactly zero through the update).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

from repro.kernels.common import resolve_interpret
from repro.kernels.fused_train.kernel import PAD, _kernel, train_tile

# Adam defaults — must match optim.optimizers.adam for the engine's
# fused path to be interchangeable with the software optimizer.
_ADAM_B1 = 0.9
_ADAM_B2 = 0.999
_ADAM_EPS = 1e-8


@functools.partial(jax.jit, static_argnames=("n_layers", "out_dim", "lr",
                                             "tile_batch", "qat", "interpret"))
def fused_train_multistep_call(x_pad, y_pad, w_pad, b_pad, *, n_layers: int,
                               out_dim: int, lr: float, tile_batch: int,
                               qat: bool = False,
                               interpret: bool | None = None):
    """K steps of in-kernel SGD in one launch, weights VMEM-resident
    throughout.

    x_pad/y_pad: ``(K*B, PAD)`` fp32 — K steps' batches pre-staged back to
    back (step k = rows ``[k*B, (k+1)*B)``); ``K*B`` must be a multiple of
    ``tile_batch``, and ``tile_batch`` must divide the per-step batch ``B``
    so no tile straddles a step boundary (``ops.effective_tile`` guarantees
    this).  Returns ``(w_new, b_new, per_tile_losses (K*B//tile_batch,))``
    — the caller regroups tiles into the ``(K,)`` per-step loss trace.

    The SGD rule needs no extra state, so this is literally the single-step
    kernel body run over the flattened ``(K * n_tiles,)`` grid: the
    single-step call is the K=1 special case.
    """
    interpret = resolve_interpret(interpret)
    total, _ = x_pad.shape
    assert total % tile_batch == 0, (total, tile_batch)
    n_tiles = total // tile_batch
    kern = functools.partial(_kernel, n_layers=n_layers, out_dim=out_dim,
                             lr=lr, n_tiles=n_tiles, qat=qat)
    w_new, b_new, losses = pl.pallas_call(
        kern,
        grid=(n_tiles,),
        in_specs=[
            pl.BlockSpec((tile_batch, PAD), lambda i: (i, 0)),   # x tile
            pl.BlockSpec((tile_batch, PAD), lambda i: (i, 0)),   # y tile
            pl.BlockSpec((n_layers, PAD, PAD), lambda i: (0, 0, 0)),
            pl.BlockSpec((n_layers, PAD), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((n_layers, PAD, PAD), lambda i: (0, 0, 0)),
            pl.BlockSpec((n_layers, PAD), lambda i: (0, 0)),
            pl.BlockSpec((1, 1), lambda i: (i, 0)),  # jaxlint: disable=PALLASTILE -- one scalar loss per grid step; pads one tile, negligible next to the weights
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n_layers, PAD, PAD), jnp.float32),
            jax.ShapeDtypeStruct((n_layers, PAD), jnp.float32),
            jax.ShapeDtypeStruct((n_tiles, 1), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((n_layers, PAD, PAD), jnp.float32),       # weights
            pltpu.VMEM((n_layers, PAD), jnp.float32),            # biases
            pltpu.VMEM((max(n_layers - 1, 1), tile_batch, PAD), jnp.float32),
        ],
        interpret=interpret,
    )(x_pad, y_pad, w_pad, b_pad)
    return w_new, b_new, losses[:, 0]


def _adam_kernel(step0_ref,                               # SMEM scalar
                 x_ref, y_ref, w_in_ref, b_in_ref,        # inputs
                 mw_in_ref, mb_in_ref, vw_in_ref, vb_in_ref,
                 w_out_ref, b_out_ref,                    # outputs
                 mw_out_ref, mb_out_ref, vw_out_ref, vb_out_ref, loss_ref,
                 w_s, b_s, mw_s, mb_s, vw_s, vb_s, h_s,   # scratch
                 *, n_layers: int, out_dim: int, lr: float, b1: float,
                 b2: float, eps: float, weight_decay: float, n_tiles: int,
                 qat: bool):
    i = pl.program_id(0)

    # --- load weights AND both moment stacks into VMEM scratch once ---------
    @pl.when(i == 0)
    def _init():
        w_s[...] = w_in_ref[...]
        b_s[...] = b_in_ref[...]
        mw_s[...] = mw_in_ref[...]
        mb_s[...] = mb_in_ref[...]
        vw_s[...] = vw_in_ref[...]
        vb_s[...] = vb_in_ref[...]

    # bias correction from the traced global Adam step: each tile is one
    # update, so update t of this launch is step0 + i + 1 — exactly the
    # counter optim.optimizers.adam would have reached.
    t = (step0_ref[0, 0] + i + 1).astype(jnp.float32)
    c1 = 1.0 - jnp.power(b1, t)
    c2 = 1.0 - jnp.power(b2, t)

    def update(l, dw, db):
        # mirrors optim.optimizers.adam.upd op for op — including the
        # weight_decay term at its default 0.0, because dropping the
        # `+ 0.0 * p` changes XLA's fusion choices and costs a ulp of
        # bit-parity with the software optimizer
        for p_s, m_s, v_s, g in ((w_s, mw_s, vw_s, dw), (b_s, mb_s, vb_s, db)):
            m = b1 * m_s[l] + (1 - b1) * g
            v = b2 * v_s[l] + (1 - b2) * jnp.square(g)
            mhat = m / c1
            vhat = v / c2
            step_ = lr * (mhat / (jnp.sqrt(vhat) + eps) + weight_decay * p_s[l])
            p_s[l] = p_s[l] - step_
            m_s[l] = m
            v_s[l] = v

    loss_ref[0, 0] = train_tile(
        x_ref[...], y_ref[...], w_s, b_s, h_s, update,
        n_layers=n_layers, out_dim=out_dim, qat=qat)

    # --- flush weights + moments to HBM once ---------------------------------
    @pl.when(i == n_tiles - 1)
    def _flush():
        w_out_ref[...] = w_s[...]
        b_out_ref[...] = b_s[...]
        mw_out_ref[...] = mw_s[...]
        mb_out_ref[...] = mb_s[...]
        vw_out_ref[...] = vw_s[...]
        vb_out_ref[...] = vb_s[...]


@functools.partial(jax.jit, static_argnames=("n_layers", "out_dim", "lr",
                                             "b1", "b2", "eps", "weight_decay",
                                             "tile_batch", "qat", "interpret"))
def fused_train_adam_call(step0, x_pad, y_pad, w_pad, b_pad, mw_pad, mb_pad,
                          vw_pad, vb_pad, *, n_layers: int, out_dim: int,
                          lr: float, b1: float = _ADAM_B1, b2: float = _ADAM_B2,
                          eps: float = _ADAM_EPS, weight_decay: float = 0.0,
                          tile_batch: int, qat: bool = False,
                          interpret: bool | None = None):
    """K steps of in-kernel Adam in one launch: weights and both moment
    stacks VMEM-resident throughout.

    ``step0``: ``(1, 1)`` int32 — the Adam step counter *before* this launch
    (traced, so chunk dispatches never recompile as the run advances).
    ``mw/mb/vw/vb``: first/second-moment stacks, padded exactly like the
    weights.  Returns ``(w, b, mw, mb, vw, vb, per_tile_losses)``.
    """
    interpret = resolve_interpret(interpret)
    total, _ = x_pad.shape
    assert total % tile_batch == 0, (total, tile_batch)
    n_tiles = total // tile_batch
    kern = functools.partial(_adam_kernel, n_layers=n_layers, out_dim=out_dim,
                             lr=lr, b1=b1, b2=b2, eps=eps,
                             weight_decay=weight_decay, n_tiles=n_tiles,
                             qat=qat)
    stack3 = pl.BlockSpec((n_layers, PAD, PAD), lambda i: (0, 0, 0))
    stack2 = pl.BlockSpec((n_layers, PAD), lambda i: (0, 0))
    outs = pl.pallas_call(
        kern,
        grid=(n_tiles,),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),               # step0 scalar
            pl.BlockSpec((tile_batch, PAD), lambda i: (i, 0)),   # x tile
            pl.BlockSpec((tile_batch, PAD), lambda i: (i, 0)),   # y tile
            stack3, stack2,                                       # w, b
            stack3, stack2,                                       # mu
            stack3, stack2,                                       # nu
        ],
        out_specs=[
            stack3, stack2,                                       # w, b
            stack3, stack2,                                       # mu
            stack3, stack2,                                       # nu
            pl.BlockSpec((1, 1), lambda i: (i, 0)),  # jaxlint: disable=PALLASTILE -- one scalar loss per grid step; pads one tile, negligible next to the weights
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n_layers, PAD, PAD), jnp.float32),
            jax.ShapeDtypeStruct((n_layers, PAD), jnp.float32),
            jax.ShapeDtypeStruct((n_layers, PAD, PAD), jnp.float32),
            jax.ShapeDtypeStruct((n_layers, PAD), jnp.float32),
            jax.ShapeDtypeStruct((n_layers, PAD, PAD), jnp.float32),
            jax.ShapeDtypeStruct((n_layers, PAD), jnp.float32),
            jax.ShapeDtypeStruct((n_tiles, 1), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((n_layers, PAD, PAD), jnp.float32),       # weights
            pltpu.VMEM((n_layers, PAD), jnp.float32),            # biases
            pltpu.VMEM((n_layers, PAD, PAD), jnp.float32),       # mu (w)
            pltpu.VMEM((n_layers, PAD), jnp.float32),            # mu (b)
            pltpu.VMEM((n_layers, PAD, PAD), jnp.float32),       # nu (w)
            pltpu.VMEM((n_layers, PAD), jnp.float32),            # nu (b)
            pltpu.VMEM((max(n_layers - 1, 1), tile_batch, PAD), jnp.float32),
        ],
        interpret=interpret,
    )(step0, x_pad, y_pad, w_pad, b_pad, mw_pad, mb_pad, vw_pad, vb_pad)
    *stacks, losses = outs
    return (*stacks, losses[:, 0])
