# Pallas TPU kernels for the paper's compute hot-spots:
#   fused_train  — whole-net fused training step (the paper's contribution)
#   qat_dense    — int8 quantized dense layer (full-integer inference path)
# Each package: kernel.py (pallas_call + BlockSpec), ops.py (public jit'd
# wrapper), ref.py (pure-jnp oracle used by the allclose/bit-exact tests).
