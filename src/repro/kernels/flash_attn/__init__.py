"""Flash-attention kernel (pallas) + reference implementation."""
