"""Public wrapper: (B, S, H, dh) GQA layout -> kernel layout, with seq
padding to block multiples and head grouping handled here."""

from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.flash_attn.kernel import flash_attention_call


def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    block_q: int = 128, block_k: int = 128,
                    interpret: bool | None = None):
    """q: (B, Sq, Hq, dh); k/v: (B, Sk, Hkv, dh) -> (B, Sq, Hq, dh).
    ``interpret=None`` auto-detects (compiled on TPU, interpreter elsewhere)."""
    b, sq, hq, dh = q.shape
    sk, hkv = k.shape[1], k.shape[2]
    g = hq // hkv
    block_q = min(block_q, max(sq, 8))
    block_k = min(block_k, max(sk, 8))
    pq = (-sq) % block_q
    pk = (-sk) % block_k
    qp = jnp.pad(q, ((0, 0), (0, pq), (0, 0), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, pk), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, pk), (0, 0), (0, 0)))
    # (B, S, H, dh) -> (B*H, S, dh), kv heads shared per group via index_map
    qf = qp.transpose(0, 2, 1, 3).reshape(b * hq, sq + pq, dh)
    kf = kp.transpose(0, 2, 1, 3).reshape(b * hkv, sk + pk, dh)
    vf = vp.transpose(0, 2, 1, 3).reshape(b * hkv, sk + pk, dh)
    out = flash_attention_call(qf, kf, vf, causal=causal, window=window,
                               block_q=block_q, block_k=block_k, group=g,
                               kv_len=sk, interpret=interpret)
    out = out.reshape(b, hq, sq + pq, dh).transpose(0, 2, 1, 3)
    return out[:, :sq]
