"""Pure-jnp oracle for the flash attention kernel: naive full-softmax GQA
attention with causal / sliding-window masks."""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def ref_attention(q, k, v, *, causal: bool = True, window: int = 0):
    """q: (B, Sq, Hq, dh); k/v: (B, Sk, Hkv, dh) -> (B, Sq, Hq, dh)."""
    b, sq, hq, dh = q.shape
    sk, hkv = k.shape[1], k.shape[2]
    g = hq // hkv
    kk = jnp.repeat(k, g, axis=2)
    vv = jnp.repeat(v, g, axis=2)
    s = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                   kk.astype(jnp.float32)) / math.sqrt(dh)
    qpos, kpos = jnp.arange(sq), jnp.arange(sk)
    keep = jnp.ones((sq, sk), bool)
    if causal:
        keep &= kpos[None] <= qpos[:, None]
    if window:
        keep &= kpos[None] > qpos[:, None] - window
    s = jnp.where(keep[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", p, vv.astype(jnp.float32))
    return out.astype(q.dtype)
