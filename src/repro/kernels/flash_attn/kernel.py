"""Flash attention (fwd) in Pallas with causal block skip + SWA band skip.

Why this kernel exists (EXPERIMENTS §Roofline): the XLA attention path
materialises (q_chunk x S) f32 score slabs in HBM — the dominant memory-term
producer for every prefill_32k cell — and computes the full causal rectangle
(2x FLOP waste, visible as MODEL_FLOPS/HLO ~ 0.5).  The fused kernel keeps
the online-softmax state (m, l, acc) in VMEM across the kv-block grid axis
and *skips* kv blocks that are fully masked:

    causal:  kv_block > q_block           -> skipped (halves causal FLOPs)
    window:  kv_block band outside W      -> skipped (SWA cost ~ S*(W+Bq))

Grid: (batch*q_heads, n_q_blocks, n_kv_blocks), kv innermost — the standard
TPU revisiting-accumulator pattern.  GQA: k/v BlockSpecs index kv heads via
``bh // group`` so no head replication is materialised.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.common import resolve_interpret
import jax.experimental.pallas.tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
            scale: float, causal: bool, window: int, block_q: int,
            block_k: int, n_k: int, seq_len: int):
    iq = pl.program_id(1)
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_lo = iq * block_q
    k_lo = ik * block_k
    run = True
    if causal:
        run = k_lo <= q_lo + block_q - 1          # any unmasked pair
    if window:
        run = jnp.logical_and(run, k_lo + block_k - 1 > q_lo - window)

    @pl.when(run)
    def _block():
        q = q_ref[0]                              # (Bq, dh)
        k = k_ref[0]                              # (Bk, dh)
        v = v_ref[0]
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        q_pos = q_lo + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
        k_pos = k_lo + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        keep = k_pos < seq_len
        if causal:
            keep &= k_pos <= q_pos
        if window:
            keep &= k_pos > q_pos - window
        s = jnp.where(keep, s, NEG_INF)
        m_prev = m_scr[...]                       # (Bq, 1)
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        p = jnp.exp(s - m_new)                    # (Bq, Bk)
        corr = jnp.exp(m_prev - m_new)            # (Bq, 1)
        l_scr[...] = l_scr[...] * corr + jnp.sum(p, axis=1, keepdims=True)
        acc_scr[...] = acc_scr[...] * corr + jax.lax.dot(
            p.astype(v.dtype), v, preferred_element_type=jnp.float32)
        m_scr[...] = m_new

    @pl.when(ik == n_k - 1)
    def _out():
        o_ref[0] = (acc_scr[...] / jnp.maximum(l_scr[...], 1e-30)).astype(
            o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "window", "block_q",
                                             "block_k", "group", "kv_len",
                                             "interpret"))
def flash_attention_call(q, k, v, *, causal: bool = True, window: int = 0,
                         block_q: int = 128, block_k: int = 128,
                         group: int = 1, kv_len: int | None = None,
                         interpret: bool | None = None):
    """q: (BH, Sq, dh); k/v: (BH//group, Sk, dh), seqs padded to block
    multiples; kv_len = true (unpadded) kv length.  Returns (BH, Sq, dh).
    ``interpret=None`` auto-detects: compiled on TPU, interpreter elsewhere."""
    interpret = resolve_interpret(interpret)
    bh, sq, dh = q.shape
    sk = k.shape[1]
    n_q, n_k = sq // block_q, sk // block_k
    kern = functools.partial(_kernel, scale=1.0 / math.sqrt(dh),
                             causal=causal, window=window, block_q=block_q,
                             block_k=block_k, n_k=n_k,
                             seq_len=kv_len if kv_len is not None else sk)
    return pl.pallas_call(
        kern,
        grid=(bh, n_q, n_k),
        in_specs=[
            pl.BlockSpec((1, block_q, dh), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, block_k, dh), lambda b, i, j, g=group: (b // g, j, 0)),
            pl.BlockSpec((1, block_k, dh), lambda b, i, j, g=group: (b // g, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, dh), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, sq, dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),  # jaxlint: disable=PALLASTILE -- online-softmax running max is one column per query row by construction
            pltpu.VMEM((block_q, 1), jnp.float32),  # jaxlint: disable=PALLASTILE -- online-softmax running sum is one column per query row by construction
            pltpu.VMEM((block_q, dh), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
