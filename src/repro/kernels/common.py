"""Shared kernel-wrapper helpers.

``interpret=None`` is the public default on every Pallas entry point in this
repo: it resolves to the Mosaic-compiled kernel path exactly when the
process is running on a TPU, and to interpreter mode everywhere else (CPU
*and* GPU — the kernels use TPU-only constructs like ``pltpu.VMEM``
scratch).  Passing an explicit ``True``/``False`` still forces a mode
(debugging a miscompile on TPU, or timing the interpreter).
"""

from __future__ import annotations

import jax


def resolve_interpret(interpret: bool | None) -> bool:
    """Auto-detect Pallas interpret mode: ``None`` -> compiled only on TPU.

    The kernels here use TPU-only constructs (``pltpu.VMEM`` scratch), so
    anything that isn't a TPU — CPU *and* GPU — gets the interpreter; only
    a real TPU takes the Mosaic-compiled path.  Called at trace time
    (``interpret`` is a static argument everywhere); the backend cannot
    change under a live process, so resolving once per trace is safe.
    """
    if interpret is None:
        return jax.default_backend() != "tpu"
    return bool(interpret)
