"""Shared kernel-wrapper helpers.

``interpret=None`` is the public default on every Pallas entry point in this
repo: it resolves to the Mosaic-compiled kernel path exactly when the
process is running on a TPU, and to interpreter mode everywhere else (CPU
*and* GPU — the kernels use TPU-only constructs like ``pltpu.VMEM``
scratch).  Passing an explicit ``True``/``False`` still forces a mode
(debugging a miscompile on TPU, or timing the interpreter).
"""

from __future__ import annotations

import jax


def jit_cache_size(jitted, *, fallback: int | None = None) -> int:
    """Compile-cache entry count of a jitted callable, jax-drift tolerant.

    ``jitted._cache_size()`` is private jit API (there is no public
    equivalent); a jax upgrade may rename or re-sign it.  Callers that can
    derive a conservative stand-in (e.g. the serving engine's set of bucket
    shapes actually run) pass it as ``fallback`` so a private-API break
    degrades the *measurement*, not the serving path or its no-recompile
    tests.  With no fallback the underlying error propagates.
    """
    try:
        return int(jitted._cache_size())
    except (AttributeError, TypeError):
        if fallback is None:
            raise
        return int(fallback)


INT8_IMPLS = ("fused", "lax", "layered")


def resolve_int8_impl(impl: str | None) -> str:
    """Pick the int8 serving implementation: ``None`` -> fastest for the rig.

    ``"fused"`` is the whole-network Pallas kernel (the TPU deployment
    path: weights VMEM-resident, one ``pallas_call`` per voxel tile).
    ``"lax"`` is the vectorized pure-lax forward — on CPU/GPU the Pallas
    *interpreter* is the bottleneck (it executes the kernel body
    block-by-block in Python), so anything that isn't a TPU defaults to the
    lax path and skips Pallas entirely.  ``"layered"`` is the original
    per-layer kernel chain, kept selectable as the measured baseline.
    All three are bit-exact against ``qat.int_forward`` (tested).
    """
    if impl is None:
        return "fused" if jax.default_backend() == "tpu" else "lax"
    if impl not in INT8_IMPLS:
        raise ValueError(f"int8 impl {impl!r} not in {INT8_IMPLS}")
    return impl


def resolve_interpret(interpret: bool | None) -> bool:
    """Auto-detect Pallas interpret mode: ``None`` -> compiled only on TPU.

    The kernels here use TPU-only constructs (``pltpu.VMEM`` scratch), so
    anything that isn't a TPU — CPU *and* GPU — gets the interpreter; only
    a real TPU takes the Mosaic-compiled path.  Called at trace time
    (``interpret`` is a static argument everywhere); the backend cannot
    change under a live process, so resolving once per trace is safe.
    """
    if interpret is None:
        return jax.default_backend() != "tpu"
    return bool(interpret)
