"""Pure-jnp oracle for the int8 dense kernel — same math as
``repro.core.qat.int_dense`` but standalone so the kernel tests don't depend
on the QAT export pipeline."""

from __future__ import annotations

import jax.numpy as jnp


def ref_qat_dense(x_q, w_q, b_q, scale, *, relu: bool = True, float_out: bool = False):
    acc = jnp.dot(x_q.astype(jnp.int32), w_q.astype(jnp.int32)) + b_q.astype(jnp.int32)
    scaled = acc.astype(jnp.float32) * scale
    if float_out:
        return scaled
    y = jnp.round(scaled)
    lo = 0.0 if relu else -128.0
    return jnp.clip(y, lo, 127.0).astype(jnp.int8)
