"""Public wrapper for the int8 dense kernel: pads ragged shapes to MXU tiles,
dispatches the kernel, and slices the result back.  Also provides
``int_forward_pallas`` — the full-integer MRF network inference built from
this kernel, interchangeable with ``repro.core.qat.int_forward``.
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.qat_dense.kernel import qat_dense_call


def _pad_to(x, m, axis):
    pad = (-x.shape[axis]) % m
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


def qat_dense(x_q, w_q, b_q, scale, *, relu: bool = True, float_out: bool = False,
              block: int = 128, interpret: bool | None = None):
    """Ragged-shape int8 dense layer. x_q (M,K) int8, w_q (K,N) int8,
    b_q (N,) int32, scale (N,) fp32 -> (M,N) int8 or fp32.
    ``interpret=None`` auto-detects (compiled on TPU, interpreter elsewhere)."""
    m, n = x_q.shape[0], w_q.shape[1]
    xp = _pad_to(_pad_to(x_q, block, 0), block, 1)
    wp = _pad_to(_pad_to(w_q, block, 0), block, 1)
    bp = _pad_to(b_q, block, 0)
    sp = _pad_to(scale, block, 0)
    out = qat_dense_call(xp, wp, bp, sp, relu=relu, float_out=float_out,
                         block_m=block, block_n=block, block_k=block,
                         interpret=interpret)
    return out[:m, :n]


def int_forward_pallas(int_layers, x, *, interpret: bool | None = None):
    """Full-integer MRF inference on the Pallas path (cf. qat.int_forward)."""
    from repro.core.qat import quantize_input

    h = quantize_input(x, int_layers[0].s_in)
    for i, layer in enumerate(int_layers):
        last = layer.s_out is None
        if last:
            scale = layer.s_in * layer.s_w
            h = qat_dense(h, layer.w_q, layer.b_q, scale,
                          relu=False, float_out=True, interpret=interpret)
        else:
            scale = (layer.s_in * layer.s_w) / layer.s_out
            h = qat_dense(h, layer.w_q, layer.b_q, scale,
                          relu=True, float_out=False, interpret=interpret)
    return h
