"""Public wrappers for the int8 dense kernels.

Three interchangeable implementations of the full-integer MRF network, all
bit-exact against the ``repro.core.qat.int_forward`` oracle (the paper's
FPGA-vs-Python criterion):

* :func:`int_forward_fused` — the fast path on TPU: one whole-network
  ``pallas_call`` per voxel tile (``fused.fused_forward_call``), all layer
  weights VMEM-resident, input quantization / per-layer requantize / head
  scale / optional denormalize fused into the kernel body.  Weights are
  pre-padded **once** (:func:`prepad_int_layers`); per call only the voxel
  (M) axis is padded.
* :func:`int_forward_lax` — the fast path everywhere else: a vectorized
  pure-``lax`` forward with no Pallas dispatch at all, so CPU/GPU rigs
  skip the interpreter tax entirely.  Uses fp32 matmuls whenever the layer
  magnitudes make fp32 accumulation exactly integral (see
  :func:`_f32_dot_is_exact`), else int32 ``dot_general``.
* :func:`int_forward_pallas` — the original per-layer kernel chain, kept as
  the layered reference implementation and for per-layer kernel tests.

Plus :func:`qat_dense` (one ragged-shape int8 layer through the Pallas
kernel) and :func:`qat_dense_lax` (same contract, pure lax) as the
layer-granularity primitives.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.qat_dense.fused import fused_forward_call
from repro.kernels.qat_dense.kernel import qat_dense_call

# Integers with |v| < 2**24 are exactly representable in fp32; every partial
# sum of an int8 x int8 dot stays exact below this.
_F32_EXACT_LIMIT = float(2 ** 24)


def _pad_to(x, m, axis):
    pad = (-x.shape[axis]) % m
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


def qat_dense(x_q, w_q, b_q, scale, *, relu: bool = True, float_out: bool = False,
              block: int = 128, interpret: bool | None = None):
    """Ragged-shape int8 dense layer. x_q (M,K) int8, w_q (K,N) int8,
    b_q (N,) int32, scale (N,) fp32 -> (M,N) int8 or fp32.
    ``interpret=None`` auto-detects (compiled on TPU, interpreter elsewhere)."""
    m, n = x_q.shape[0], w_q.shape[1]
    xp = _pad_to(_pad_to(x_q, block, 0), block, 1)
    wp = _pad_to(_pad_to(w_q, block, 0), block, 1)
    bp = _pad_to(b_q, block, 0)
    sp = _pad_to(scale, block, 0)
    out = qat_dense_call(xp, wp, bp, sp, relu=relu, float_out=float_out,
                         block_m=block, block_n=block, block_k=block,
                         interpret=interpret)
    return out[:m, :n]


# ---------------------------------------------------------------------------
# Vectorized pure-lax fallback (no Pallas dispatch; exact by construction).
# ---------------------------------------------------------------------------

def _f32_dot_is_exact(k: int, b_q) -> bool:
    """True iff ``int8 @ int8 + b`` accumulates exactly in fp32.

    Products are bounded by 128*128 = 2**14; any summation order keeps every
    partial sum an integer of magnitude <= k * 2**14 + max|b|, and integer
    fp32 arithmetic is exact below 2**24.  ``b_q`` must be concrete (weights
    always are in serving); a traced bias falls back to the int32 path.
    """
    try:
        bmax = float(np.max(np.abs(np.asarray(b_q)))) if b_q.size else 0.0
    except (jax.errors.TracerArrayConversionError, TypeError):
        return False
    return k * 16384.0 + bmax < _F32_EXACT_LIMIT


def _lax_epilogue(acc_f32, scale, *, relu: bool, float_out: bool):
    """The oracle epilogue on an fp32 accumulator that holds exact integers:
    fp32 rescale, round-to-nearest-even, clamp — op-for-op ``qat.int_dense``."""
    scaled = acc_f32 * scale
    if float_out:
        return scaled
    y = jnp.round(scaled)
    lo = 0.0 if relu else -128.0
    return jnp.clip(y, lo, 127.0)


def qat_dense_lax(x_q, w_q, b_q, scale, *, relu: bool = True,
                  float_out: bool = False):
    """``qat_dense`` contract on pure lax: (M,N) int8 (requantized) or fp32.

    No padding, no Pallas: one ``dot_general`` (fp32 when exactness allows,
    int32 otherwise) plus the fused-by-XLA epilogue.  Bit-exact vs
    ``ref.ref_qat_dense`` for any shape.
    """
    k = int(x_q.shape[-1])
    if _f32_dot_is_exact(k, b_q):
        acc = jax.lax.dot(x_q.astype(jnp.float32), w_q.astype(jnp.float32),
                          preferred_element_type=jnp.float32)
        acc = acc + b_q.astype(jnp.float32)
    else:
        acc = jax.lax.dot(x_q.astype(jnp.int32), w_q.astype(jnp.int32),
                          preferred_element_type=jnp.int32)
        acc = (acc + b_q).astype(jnp.float32)
    out = _lax_epilogue(acc, scale, relu=relu, float_out=float_out)
    return out if float_out else out.astype(jnp.int8)


def int_forward_lax(int_layers, x):
    """Full-integer MRF inference, vectorized pure lax (cf. qat.int_forward).

    Hidden activations stay fp32 holding exact int8-range integers — values
    identical to the oracle's int8 tensors, minus the per-layer dtype
    round-trips.  Bit-exact against ``qat.int_forward`` for any net whose
    layers pass :func:`_f32_dot_is_exact`; other layers transparently use
    int32 accumulation (still exact, still no Pallas dispatch).
    """
    h = jnp.clip(jnp.round(x / int_layers[0].s_in), -128.0, 127.0)
    for layer in int_layers:
        if _f32_dot_is_exact(int(h.shape[-1]), layer.b_q):
            acc = jax.lax.dot(h, layer.w_q.astype(jnp.float32),
                              preferred_element_type=jnp.float32)
            acc = acc + layer.b_q.astype(jnp.float32)
        else:
            acc = jax.lax.dot(h.astype(jnp.int32),
                              layer.w_q.astype(jnp.int32),
                              preferred_element_type=jnp.int32)
            acc = (acc + layer.b_q).astype(jnp.float32)
        if layer.s_out is None:
            h = acc * (layer.s_in * layer.s_w)
        else:
            requant = (layer.s_in * layer.s_w) / layer.s_out
            h = jnp.clip(jnp.round(acc * requant), 0.0, 127.0)
    return h


# ---------------------------------------------------------------------------
# Pre-padded artifacts + the fused whole-network kernel.
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class PaddedIntNet:
    """A full-integer net with every feature dim pre-padded to the MXU grid.

    Built once at artifact load (weights are static); per-call work is then
    M-only padding.  ``packed`` holds, per layer, ``w_p`` (Kp, Np) int8,
    ``b_p`` (1, Np) int32 and ``s_p`` (1, Np) fp32 (requant multipliers for
    hidden layers, the head scale for the last), exactly the operand layout
    of ``fused.fused_forward_call``.
    """

    packed: tuple          # flat (w_p, b_p, s_p) * n_layers
    s_in: jnp.ndarray      # fp32 scalar — input activation scale
    n_layers: int
    in_dim: int            # true (unpadded) fan-in of the first layer
    in_dim_p: int          # padded fan-in
    out_dim: int           # true fan-out of the head

    @property
    def padded_widths(self) -> tuple:
        return tuple(self.packed[3 * i].shape[1]
                     for i in range(self.n_layers))


def prepad_int_layers(int_layers, *, block: int = 128) -> PaddedIntNet:
    """Pad an ``IntLayer`` list's K/N dims to ``block`` multiples, once.

    Zero padding is arithmetic-neutral through the whole net: padded weight
    columns yield zero accumulators, zero bias, zero scale -> zero
    activations, which then meet zero weight *rows* in the next layer.
    The per-layer scale is precomputed with the oracle's operand grouping
    (``(s_in * s_w) / s_out``) so downstream fp32 math is bit-identical.
    """
    packed = []
    for layer in int_layers:
        if layer.s_out is None:
            scale = layer.s_in * layer.s_w
        else:
            scale = (layer.s_in * layer.s_w) / layer.s_out
        wp = _pad_to(_pad_to(layer.w_q, block, 0), block, 1)
        bp = _pad_to(layer.b_q, block, 0).reshape(1, -1)
        sp = _pad_to(scale.astype(jnp.float32), block, 0).reshape(1, -1)
        packed.extend((wp, bp, sp))
    return PaddedIntNet(
        packed=tuple(packed), s_in=jnp.asarray(int_layers[0].s_in, jnp.float32),
        n_layers=len(int_layers), in_dim=int(int_layers[0].w_q.shape[0]),
        in_dim_p=int(packed[0].shape[0]), out_dim=int(int_layers[-1].w_q.shape[1]))


def int_forward_fused(net, x, *, block_m: int = 256,
                      interpret: bool | None = None, denorm_scale=None):
    """Whole-network fused int8 inference from float features.

    ``net``: a :class:`PaddedIntNet` (pass ``prepad_int_layers(int_layers)``
    output; an ``IntLayer`` list is padded on the fly for convenience).
    Only M is padded here — to the tile grid and the ``block_m`` granule —
    the M-only padding contract of the fused kernel.  ``denorm_scale``:
    optional (out_dim,) fp32 row multiplied after the head scale inside the
    kernel (the serving engine's denormalize epilogue, fused).
    """
    if not isinstance(net, PaddedIntNet):
        net = prepad_int_layers(net)
    m = int(x.shape[0])
    block_m = max(8, min(int(block_m), -(-m // 8) * 8))
    xp = _pad_to(_pad_to(x.astype(jnp.float32), net.in_dim_p, 1), block_m, 0)
    packed = net.packed
    has_denorm = denorm_scale is not None
    if has_denorm:
        np_last = packed[-3].shape[1]
        drow = _pad_to(jnp.asarray(denorm_scale, jnp.float32), np_last, 0)
        packed = packed + (drow.reshape(1, -1),)
    out = fused_forward_call(xp, net.s_in, *packed, n_layers=net.n_layers,
                             block_m=block_m, interpret=interpret,
                             has_denorm=has_denorm)
    return out[:m, :net.out_dim]


# ---------------------------------------------------------------------------
# Layered per-layer kernel chain (the original path, kept as reference).
# ---------------------------------------------------------------------------

def int_forward_pallas(int_layers, x, *, interpret: bool | None = None,
                       prepadded: PaddedIntNet | None = None):
    """Full-integer MRF inference through the per-layer Pallas kernel chain.

    With ``prepadded`` (built once at artifact load), weights skip their
    per-call K/N padding and activations stay on the padded grid between
    layers, so each call pads M once at entry instead of every operand at
    every layer.
    """
    from repro.core.qat import quantize_input

    if prepadded is not None:
        m = int(x.shape[0])
        h = quantize_input(x, prepadded.s_in)
        h = _pad_to(_pad_to(h, prepadded.in_dim_p, 1), 128, 0)
        for i in range(prepadded.n_layers):
            wp, bp, sp = prepadded.packed[3 * i:3 * i + 3]
            last = i == prepadded.n_layers - 1
            h = qat_dense_call(h, wp, bp.reshape(-1), sp.reshape(-1),
                               relu=not last, float_out=last,
                               interpret=interpret)
        return h[:m, :prepadded.out_dim]

    h = quantize_input(x, int_layers[0].s_in)
    for i, layer in enumerate(int_layers):
        last = layer.s_out is None
        if last:
            scale = layer.s_in * layer.s_w
            h = qat_dense(h, layer.w_q, layer.b_q, scale,
                          relu=False, float_out=True, interpret=interpret)
        else:
            scale = (layer.s_in * layer.s_w) / layer.s_out
            h = qat_dense(h, layer.w_q, layer.b_q, scale,
                          relu=True, float_out=False, interpret=interpret)
    return h
