"""Tiled int8 x int8 -> int32 dense layer with fused requantization + ReLU.

This is the deployment form of the paper's full-integer network (QAT export):
the TPU MXU executes int8 matmuls at 2x the bf16 rate (394 TOPS on v5e), and
the fused epilogue (bias add, fp32 rescale, round, clamp) keeps the whole
layer a single VMEM-resident pass — the TPU analogue of the paper's
integer node function, Eq. (1).

Layout: classic (m, n, k) grid with an int32 VMEM accumulator; K is the
innermost (fastest-varying) grid axis so the accumulator pattern is the
standard Pallas revisiting-output-block idiom.

This is the *layer-granularity* kernel: general (any M/K/N over the block
grid) but one launch per layer, so activations round-trip through HBM
between layers.  Serving the tiny MRF net uses ``fused.fused_forward_call``
instead — the whole network in one ``pallas_call`` per voxel tile with all
weights VMEM-resident — and ``ops.int_forward_lax`` off-TPU; this kernel
remains the per-layer reference implementation (``ops.qat_dense`` /
``ops.int_forward_pallas``) and the building block the tests sweep.

The epilogue matches ``repro.core.qat.int_dense`` op-for-op (int32 accumulate,
fp32 multiply, round-to-nearest-even, clamp) — the tests assert **bit-exact**
agreement, mirroring the paper's FPGA-vs-Python exactness check.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

from repro.kernels.common import resolve_interpret


def _kernel(x_ref, w_ref, b_ref, s_ref, o_ref, acc_ref, *,
            n_k: int, relu: bool, float_out: bool):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _zero():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jax.lax.dot(
        x_ref[...].astype(jnp.int8), w_ref[...].astype(jnp.int8),
        preferred_element_type=jnp.int32)

    @pl.when(k == n_k - 1)
    def _epilogue():
        acc = acc_ref[...] + b_ref[...].astype(jnp.int32)
        scaled = acc.astype(jnp.float32) * s_ref[...]
        if float_out:
            o_ref[...] = scaled
        else:
            y = jnp.round(scaled)
            lo = 0.0 if relu else -128.0
            o_ref[...] = jnp.clip(y, lo, 127.0).astype(jnp.int8)


@functools.partial(jax.jit, static_argnames=("relu", "float_out", "block_m",
                                             "block_n", "block_k", "interpret"))
def qat_dense_call(x_q, w_q, b_q, scale, *, relu: bool = True,
                   float_out: bool = False, block_m: int = 128,
                   block_n: int = 128, block_k: int = 128,
                   interpret: bool | None = None):
    """x_q: (M, K) int8; w_q: (K, N) int8; b_q: (N,) int32; scale: (N,) fp32.

    M, K, N must be multiples of the block sizes (ops.py pads).
    Returns (M, N) int8 (requantized) or fp32 (float_out, the linear head).
    ``interpret=None`` auto-detects: compiled on TPU, interpreter elsewhere.
    """
    interpret = resolve_interpret(interpret)
    m, k = x_q.shape
    _, n = w_q.shape
    n_m, n_n, n_k = m // block_m, n // block_n, k // block_k
    out_dtype = jnp.float32 if float_out else jnp.int8
    kern = functools.partial(_kernel, n_k=n_k, relu=relu, float_out=float_out)
    return pl.pallas_call(
        kern,
        grid=(n_m, n_n, n_k),
        in_specs=[
            pl.BlockSpec((block_m, block_k), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((block_k, block_n), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((1, block_n), lambda i, j, kk: (0, j)),  # jaxlint: disable=PALLASTILE -- per-channel scale is a single broadcast row; padding it is one sublane tile
            pl.BlockSpec((1, block_n), lambda i, j, kk: (0, j)),  # jaxlint: disable=PALLASTILE -- bias is a single broadcast row; padding it is one sublane tile
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        scratch_shapes=[pltpu.VMEM((block_m, block_n), jnp.int32)],
        interpret=interpret,
    )(x_q, w_q, b_q.reshape(1, -1), scale.reshape(1, -1))
