"""Fused whole-network int8 forward: one ``pallas_call`` per voxel tile.

The per-layer launch chain (``ops.qat_dense`` once per layer) re-reads
activations from HBM between layers and pads every operand to MXU tiles on
every call — pure overhead for the paper's tiny MRF net, whose *entire*
weight set is a few hundred KiB.  This kernel is the serving analogue of the
paper's on-FPGA design: **all layer weights resident in VMEM** for the whole
forward, with the complete pipeline fused into one kernel body per
``(block_m, ·)`` voxel tile:

    float features -> input quantization (``qat.quantize_input``)
      -> [int8 x int8 -> int32 dot -> +bias -> fp32 requant -> round/clamp]
         per hidden layer (ReLU fused into the [0, 127] clamp, zero-point 0)
      -> fp32 head scale -> (optional) denormalize epilogue (T1/T2 in ms)

Only the voxel (M) axis is gridded; weights use constant index maps so every
grid step revisits the same VMEM-resident blocks.  Feature dims come
pre-padded to the (8, 128) tile grid by ``ops.prepad_int_layers`` — done
once at artifact load, not per call — so the kernel itself pads nothing.
Zero padding is self-consistent through the net: padded weight columns
produce zero activations which meet zero weight rows in the next layer.

Bit-exactness contract: every arithmetic step matches
``repro.core.qat.int_forward`` op-for-op (int32 accumulate, fp32 multiply
with the oracle's operand grouping, round-to-nearest-even, clamp), and the
optional denormalize epilogue multiplies *after* the head scale exactly like
``data.pipeline.denormalize_targets`` composed outside — tests assert
bit-exact agreement for the whole network.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.common import resolve_interpret


def _fused_kernel(x_ref, sin_ref, *refs, n_layers: int, has_denorm: bool):
    o_ref = refs[-1]
    # input quantization (qat.quantize_input, op-for-op)
    h = jnp.clip(jnp.round(x_ref[...] / sin_ref[0, 0]),
                 -128.0, 127.0).astype(jnp.int8)
    out = None
    for i in range(n_layers):
        w = refs[3 * i][...]
        b = refs[3 * i + 1][...]
        s = refs[3 * i + 2][...]
        acc = jax.lax.dot(h, w, preferred_element_type=jnp.int32)
        acc = acc + b.astype(jnp.int32)
        scaled = acc.astype(jnp.float32) * s
        if i == n_layers - 1:
            out = scaled  # linear float head (s = s_in * s_w)
        else:
            # requantize: round-to-nearest-even then the [0, 127] clamp
            # (ReLU fused, zero-point 0) — identical to qat.int_dense
            h = jnp.clip(jnp.round(scaled), 0.0, 127.0).astype(jnp.int8)
    if has_denorm:
        out = out * refs[-2][...]  # denormalize epilogue: (T1, T2) -> ms
    o_ref[...] = out


@functools.partial(jax.jit, static_argnames=("n_layers", "block_m",
                                             "interpret", "has_denorm"))
def fused_forward_call(x_p, s_in, *packed, n_layers: int, block_m: int = 256,
                       interpret: bool | None = None,
                       has_denorm: bool = False):
    """Dispatch the fused net on pre-padded operands.

    ``x_p``: (M, K0p) fp32 with M a multiple of ``block_m`` and K0p the
    first layer's pre-padded fan-in.  ``packed``: per layer ``w_p`` (Kp, Np)
    int8, ``b_p`` (1, Np) int32, ``s_p`` (1, Np) fp32 — requant multipliers
    for hidden layers, the head scale for the last — then, iff
    ``has_denorm``, one (1, Np_last) fp32 denormalization row.  Returns
    (M, Np_last) fp32; the caller slices the true output columns.
    """
    interpret = resolve_interpret(interpret)
    m, k0p = x_p.shape
    np_last = packed[3 * (n_layers - 1)].shape[1]
    in_specs = [
        pl.BlockSpec((block_m, k0p), lambda i: (i, 0)),
        pl.BlockSpec((1, 1), lambda i: (0, 0)),  # jaxlint: disable=PALLASTILE -- s_in is one fp32 scalar; a (1, 1) block is its minimal carrier
    ]
    for li in range(n_layers):
        kp, np_ = packed[3 * li].shape
        in_specs.append(pl.BlockSpec((kp, np_), lambda i: (0, 0)))
        in_specs.append(pl.BlockSpec((1, np_), lambda i: (0, 0)))  # jaxlint: disable=PALLASTILE -- bias is a single broadcast row; padding it is one sublane tile
        in_specs.append(pl.BlockSpec((1, np_), lambda i: (0, 0)))  # jaxlint: disable=PALLASTILE -- per-channel scale is a single broadcast row
    if has_denorm:
        in_specs.append(pl.BlockSpec((1, np_last), lambda i: (0, 0)))  # jaxlint: disable=PALLASTILE -- denormalize row broadcasts over the tile
    kern = functools.partial(_fused_kernel, n_layers=n_layers,
                             has_denorm=has_denorm)
    return pl.pallas_call(
        kern,
        grid=(m // block_m,),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((block_m, np_last), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, np_last), jnp.float32),
        interpret=interpret,
    )(x_p, s_in.reshape(1, 1), *packed)
