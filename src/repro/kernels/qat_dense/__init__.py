"""Int8 QAT dense kernels: per-layer Pallas kernel, fused whole-network
Pallas kernel, vectorized pure-lax fallback, and the reference oracle —
all bit-exact against ``repro.core.qat.int_forward``."""
