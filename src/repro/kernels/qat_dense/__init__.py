"""Int8 QAT dense kernel (pallas) + reference implementation."""
