"""The Barbieri-et-al MRF reconstruction MLP and the paper's FPGA-adapted variant.

Original net: nine fully connected layers, ReLU on hidden layers, linear output
producing (T1, T2).  Adapted net: the first two hidden layers removed so the
whole network + backprop fits the ALVEO U250 resource budget.

Exact widths appear only in the paper's figures (not the text); we reconstruct
widths consistent with the paper's cycle arithmetic (see DESIGN.md §3):
forward cycles = 4 * sum_l ceil(n_l / 16) = 56 for the adapted net.

Params are a simple list of {"w": (in, out), "b": (out,)} dicts — a pytree that
flows through jax.grad, our optimizers, the QAT wrappers, and the Pallas fused
training kernel identically.
"""

from __future__ import annotations

from typing import Sequence

import jax
import jax.numpy as jnp

# Hidden widths (output layer of 2 appended automatically).
# Adapted: sum(ceil(n/16) for n in (64,64,32,16,16,16,2)) = 4+4+2+1+1+1+1 = 14
#          -> 14 * 4 = 56 forward cycles, matching the paper.
ADAPTED_HIDDEN = (64, 64, 32, 16, 16, 16)
# Original = two extra layers in front ("the first two layers were removed").
ORIGINAL_HIDDEN = (128, 128) + ADAPTED_HIDDEN
N_OUTPUTS = 2  # (T1, T2), normalised


def layer_sizes(n_frames: int, hidden: Sequence[int] = ADAPTED_HIDDEN) -> tuple:
    """Full (in, hidden..., out) size tuple. Input = [Re | Im] of the signal."""
    return (2 * n_frames, *hidden, N_OUTPUTS)


def init_params(key: jax.Array, sizes: Sequence[int], dtype=jnp.float32):
    """He-uniform init, biases zero (matches Keras Dense defaults closely)."""
    params = []
    for i, (n_in, n_out) in enumerate(zip(sizes[:-1], sizes[1:])):
        key, sub = jax.random.split(key)
        bound = jnp.sqrt(6.0 / n_in)
        w = jax.random.uniform(sub, (n_in, n_out), dtype, minval=-bound, maxval=bound)
        params.append({"w": w, "b": jnp.zeros((n_out,), dtype)})
    return params


def forward(params, x: jnp.ndarray, *, return_hidden: bool = False):
    """ReLU MLP forward. x: (..., 2*n_frames) -> (..., 2)."""
    hidden = []
    h = x
    for i, layer in enumerate(params):
        z = h @ layer["w"] + layer["b"]
        last = i == len(params) - 1
        h = z if last else jax.nn.relu(z)
        if return_hidden:
            hidden.append(h)
    return (h, hidden) if return_hidden else h


def mse_loss(params, x, y, forward_fn=forward):
    pred = forward_fn(params, x)
    return jnp.mean(jnp.square(pred - y))


def param_count(params) -> int:
    return sum(int(p.size) for p in jax.tree_util.tree_leaves(params))


def node(x, w, b, activation=jax.nn.relu):
    """Eq. (1) of the paper: y = sigma(sum_i x_i w_i + b) for a single node.

    Kept as an explicit function because the paper's FPGA correctness check is
    defined at node granularity (identical inputs/weights/bias on FPGA vs
    Python); our kernel tests mirror that check.
    """
    return activation(jnp.dot(x, w) + b)
