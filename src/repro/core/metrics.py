"""Error metrics from the paper's Table 1: MAPE, MPE, RMSE on T1/T2 (ms)."""

from __future__ import annotations

import jax.numpy as jnp


def mape(pred, true):
    """Mean absolute percentage error (%)."""
    return 100.0 * jnp.mean(jnp.abs(pred - true) / jnp.maximum(jnp.abs(true), 1e-9))


def mpe(pred, true):
    """Mean (signed) percentage error (%) — the paper's bias metric."""
    return 100.0 * jnp.mean((pred - true) / jnp.maximum(jnp.abs(true), 1e-9))


def rmse(pred, true):
    """Root mean squared error, in the units of the inputs (ms for T1/T2)."""
    return jnp.sqrt(jnp.mean(jnp.square(pred - true)))


def table1_metrics(pred_ms, true_ms) -> dict:
    """pred/true: (N, 2) arrays of (T1, T2) in milliseconds."""
    out = {}
    for j, name in enumerate(("T1", "T2")):
        p, t = pred_ms[:, j], true_ms[:, j]
        out[name] = {
            "MAPE_%": float(mape(p, t)),
            "MPE_%": float(mpe(p, t)),
            "RMSE_ms": float(rmse(p, t)),
        }
    return out


def table1_metrics_normalized(pred_norm, true_norm) -> dict:
    """Table 1 metrics from NORMALISED (T1/T1_max, T2/T2_max) arrays.

    Un-normalisation is delegated to ``data.pipeline.denormalize_targets``
    (the one owner of the stream ranges) so every caller reports ms on the
    same scale the stream actually used.
    """
    from repro.data.pipeline import denormalize_targets

    return table1_metrics(denormalize_targets(pred_norm),
                          denormalize_targets(true_norm))
