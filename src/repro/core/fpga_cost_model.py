"""Cycle-accurate cost model of the paper's FPGA training design, plus the
TPU-roofline equivalent for the same workload.

Paper facts modelled (Results §3):
* one generic node block: 16 nodes semi-parallel, 4 cycles per block step;
  forward across all layers of the adapted net = 56 cycles;
* one backprop block (16x32 weight tile), 3 cycles per step; full backward
  pass = 104 cycles;
* f_clk = 200 MHz (250 MHz feasible), 250M training samples
  -> Eq. (3): 5ns * 250e6 * (56 + 104) = 200 s;
* resources: NN+backprop 145k LUT / 5k DSP / 146k FF (8% LUT, 40% DSP of the
  ALVEO U250); PCIe adds 83k LUT / 148k FF / 150 BRAM;
* CPU baseline: ~16 h on a Ryzen 9 3900 -> the paper's "up to 250x" claim.

The model is parametric in the layer widths so it also prices *our*
reconstructed nets and arbitrary MLPs; it reports both the paper-stated cycle
counts and the model-derived counts (see DESIGN.md §3 on width reconstruction).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Sequence

# ---------------------------------------------------------------------------
# FPGA side
# ---------------------------------------------------------------------------

ALVEO_U250 = {"LUT": 1_728_000, "FF": 3_456_000, "DSP": 12_288, "BRAM": 2_688}

PAPER = {
    "fwd_cycles": 56,
    "bwd_cycles": 104,
    "cycles_per_sample": 160,
    "clock_hz": 200e6,
    "n_train_samples": 250_000_000,
    "train_seconds": 200.0,
    "cpu_train_seconds": 16 * 3600.0,  # ~16 h on Ryzen 9 3900
    "resources_nn": {"LUT": 145_000, "DSP": 5_000, "FF": 146_000},
    "resources_pcie": {"LUT": 83_000, "FF": 148_000, "BRAM": 150},
}


@dataclasses.dataclass(frozen=True)
class FPGADesign:
    clock_hz: float = 200e6
    node_block: int = 16          # nodes computed in parallel
    fwd_cycles_per_block: int = 4
    bwd_tile: tuple = (32, 16)    # backprop weight tile (in, out)
    bwd_cycles_per_tile: int = 3  # weight/bias update sweep (paper: "3 clock cycles")
    delta_cycles_per_tile: int = 2  # delta back-propagation sweep (pipelined)


def fwd_cycles(widths: Sequence[int], d: FPGADesign = FPGADesign()) -> int:
    """Forward cycles: the node block is time-multiplexed over every layer's
    output nodes.  widths = (in, h1, ..., out)."""
    outs = widths[1:]
    return d.fwd_cycles_per_block * sum(math.ceil(n / d.node_block) for n in outs)


def bwd_cycles(widths: Sequence[int], d: FPGADesign = FPGADesign()) -> int:
    """Backward cycles (Eq. 2): two sweeps of the 16x32 block per transition —
    a weight/bias-update sweep (3 cycles/tile, the paper's "single
    backpropagation module requires 3 clock cycles") and a delta-propagation
    sweep (2 cycles/tile; not needed into the input layer).  On the adapted
    net this evaluates to 3*24 + 2*16 = 104, the paper's stated count.
    """
    ti, to = d.bwd_tile
    upd_tiles, delta_tiles = 0, 0
    for i, (n_in, n_out) in enumerate(zip(widths[:-1], widths[1:])):
        tiles = math.ceil(n_in / ti) * math.ceil(n_out / to)
        upd_tiles += tiles
        if i > 0:  # no delta propagated into the input layer
            delta_tiles += tiles
    return d.bwd_cycles_per_tile * upd_tiles + d.delta_cycles_per_tile * delta_tiles


def train_seconds(widths: Sequence[int], n_samples: int,
                  d: FPGADesign = FPGADesign()) -> float:
    """Eq. (3) generalised: period * samples * (fwd + bwd) cycles."""
    c = fwd_cycles(widths, d) + bwd_cycles(widths, d)
    return (1.0 / d.clock_hz) * n_samples * c


def paper_eq3_seconds() -> float:
    """The paper's own arithmetic, exactly."""
    return (1.0 / PAPER["clock_hz"]) * PAPER["n_train_samples"] * PAPER["cycles_per_sample"]


def resource_estimate(widths: Sequence[int], d: FPGADesign = FPGADesign()) -> dict:
    """Analytic resource model calibrated to the paper's totals.

    The paper prices a fixed design (16-node block + one backprop block +
    weight/bias storage), so resources are dominated by the *blocks*, not the
    layer count; we model: per-node MAC unit ~ (310 LUT, 19 DSP eq.) from the
    paper's 145k LUT / 5k DSP for 16 nodes + bp block, storage in FF.
    """
    params = sum(i * o + o for i, o in zip(widths[:-1], widths[1:]))
    node_lut, node_dsp = 4_200, 170        # per node-unit incl. control
    bp_lut_per_lane, bp_dsp_per_lane = 2_400, 70
    lanes = d.bwd_tile[1]
    lut = d.node_block * node_lut + lanes * bp_lut_per_lane + 12_000  # +control
    dsp = d.node_block * node_dsp + lanes * bp_dsp_per_lane
    ff = params * 8 + 25_000  # int8 weights in FF/LUTRAM + pipeline regs
    return {
        "LUT": lut, "DSP": dsp, "FF": ff,
        "LUT_frac": lut / ALVEO_U250["LUT"],
        "DSP_frac": dsp / ALVEO_U250["DSP"],
        "params": params,
    }


# ---------------------------------------------------------------------------
# TPU side — the roofline equivalent of the same training workload
# ---------------------------------------------------------------------------

TPU_V5E = {
    "peak_bf16_flops": 197e12,
    "peak_int8_ops": 394e12,
    "hbm_gbps": 819e9,
    "ici_gbps_per_link": 50e9,
    "vmem_bytes": 128 * 1024 * 1024,
}


def mlp_train_flops_per_sample(widths: Sequence[int]) -> int:
    """fwd (2*MACs) + bwd (~2x fwd: dX and dW matmuls) + update (O(params))."""
    macs = sum(i * o for i, o in zip(widths[:-1], widths[1:]))
    return 2 * macs * 3


def tpu_train_seconds(widths: Sequence[int], n_samples: int,
                      chips: int = 1, int8: bool = True,
                      batch_stream_bytes_per_sample: int | None = None,
                      padded_lanes: int = 128) -> dict:
    """Roofline estimate for the fused VMEM-resident training kernel.

    compute term: total train FLOPs / peak — priced on the *padded* 128-lane
    layers the kernel actually executes (MXU tile granularity), not the
    logical widths, so this is a realistic target rather than a fantasy;
    memory term: the only HBM traffic is streaming the samples in (weights
    stay in VMEM) — exactly the paper's 'weights resident on chip, samples
    stream through' regime.
    """
    n_in = widths[0]
    if batch_stream_bytes_per_sample is None:
        # int8 features + fp32 targets
        batch_stream_bytes_per_sample = n_in * (1 if int8 else 4) + 2 * 4
    padded = [max(w, padded_lanes) for w in widths]  # kernel pads to 128 lanes
    flops = mlp_train_flops_per_sample(padded) * n_samples
    peak = TPU_V5E["peak_int8_ops"] if int8 else TPU_V5E["peak_bf16_flops"]
    t_compute = flops / (chips * peak)
    t_memory = batch_stream_bytes_per_sample * n_samples / (chips * TPU_V5E["hbm_gbps"])
    return {
        "t_compute_s": t_compute,
        "t_memory_s": t_memory,
        "t_total_s": max(t_compute, t_memory),
        "bound": "memory" if t_memory > t_compute else "compute",
        "flops": flops,
    }
