# The paper's primary contribution — on-accelerator quantized NN training for
# MRF map reconstruction — implemented as a TPU-native JAX system:
#   mrf_net          the Barbieri original + FPGA-adapted MLPs
#   qat              quantization-aware training + full-integer export/oracle
#   train_loop       software reference training (Adam / SGD, MSE)
#   fpga_cost_model  the paper's cycle/resource model (Eq. 3) + TPU roofline
#   metrics          Table 1 metrics (MAPE / MPE / RMSE)
# The fused on-chip training step itself is kernels/fused_train.
from repro.core import fpga_cost_model, metrics, mrf_net, qat
