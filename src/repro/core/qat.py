"""Quantization-Aware Training (Jacob et al. 2017) for the MRF net — and, as a
first-class framework feature, for any dense projection in the model zoo.

Scheme (matches the paper's 'full integer' network):
* symmetric int8, zero-point 0 everywhere (ReLU nets lose nothing from
  symmetric quantization and it keeps the FPGA/TPU integer path MAC-only);
* weights quantized per-output-channel from their live absmax;
* activations quantized per-tensor with an EMA-calibrated absmax (the QAT
  "observer"), carried functionally as ``QATState``;
* straight-through estimator for gradients;
* full-integer export: int8 weights, int32 biases (scale = s_x * s_w), fp32
  requantization multipliers (TPU-idiomatic: scales live in fp32 registers;
  the accumulator and all tensor data are integers).

The integer forward pass here is the *oracle* that the Pallas int8 kernel
(kernels/qat_dense) must match bit-exactly — mirroring the paper's
FPGA-vs-Python exactness check.
"""

from __future__ import annotations

import dataclasses
import pathlib
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

INT8_MAX = 127.0


@dataclasses.dataclass(frozen=True)
class QATConfig:
    bits: int = 8
    ema: float = 0.99
    per_channel_weights: bool = True

    @property
    def qmax(self) -> float:
        return float(2 ** (self.bits - 1) - 1)


def _round_ste(x):
    return x + jax.lax.stop_gradient(jnp.round(x) - x)


def fake_quant(x, scale, qmax=INT8_MAX):
    """Symmetric fake-quant with STE. ``scale`` broadcasts against x."""
    s = jnp.maximum(scale, 1e-12)
    q = jnp.clip(_round_ste(x / s), -qmax - 1, qmax)
    return q * s


def weight_scale(w, cfg: QATConfig):
    if cfg.per_channel_weights:
        return jnp.max(jnp.abs(w), axis=0, keepdims=True) / cfg.qmax  # (1, out)
    return jnp.max(jnp.abs(w)) / cfg.qmax


# ---------------------------------------------------------------------------
# QAT state (activation observers) and quantized forward for the MRF MLP.
# ---------------------------------------------------------------------------

def init_qat_state(n_layers: int):
    """One activation absmax observer per layer input."""
    return {"act_absmax": jnp.ones((n_layers,), jnp.float32)}


def forward_qat(params, qstate, x, cfg: QATConfig = QATConfig(), *, train: bool = True):
    """Fake-quantized MLP forward.

    Returns (output, new_qstate).  In eval (train=False) the observers freeze.
    The output layer is linear and left un-fake-quantized on its output
    (the paper's head emits real-valued T1/T2; only its weights/inputs are
    integer).
    """
    absmax = qstate["act_absmax"]
    new_absmax = []
    h = x
    for i, layer in enumerate(params):
        cur = jnp.max(jnp.abs(h)) + 1e-12
        obs = jnp.where(train, cfg.ema * absmax[i] + (1.0 - cfg.ema) * cur, absmax[i])
        new_absmax.append(obs)
        a_scale = jax.lax.stop_gradient(obs) / cfg.qmax
        hq = fake_quant(h, a_scale, cfg.qmax)
        wq = fake_quant(layer["w"], weight_scale(layer["w"], cfg), cfg.qmax)
        z = hq @ wq + layer["b"]
        h = z if i == len(params) - 1 else jax.nn.relu(z)
    return h, {"act_absmax": jnp.stack(new_absmax)}


# ---------------------------------------------------------------------------
# Full-integer export + integer oracle (bit-exactness target for the kernel).
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class IntLayer:
    w_q: jnp.ndarray          # int8  (in, out)
    b_q: jnp.ndarray          # int32 (out,)   scale = s_x * s_w
    s_in: jnp.ndarray         # fp32 scalar — input activation scale
    s_w: jnp.ndarray          # fp32 (out,)  — per-channel weight scale
    s_out: jnp.ndarray | None # fp32 scalar — output act scale (None = float head)


def export_int8(params, qstate, cfg: QATConfig = QATConfig()) -> list:
    """Freeze a QAT-trained net into full-integer layers."""
    layers = []
    absmax = qstate["act_absmax"]
    for i, layer in enumerate(params):
        s_in = absmax[i] / cfg.qmax
        s_w = jnp.squeeze(weight_scale(layer["w"], cfg), axis=0)  # (out,)
        w_q = jnp.clip(jnp.round(layer["w"] / jnp.maximum(s_w, 1e-12)), -128, 127).astype(jnp.int8)
        b_q = jnp.round(layer["b"] / jnp.maximum(s_in * s_w, 1e-12)).astype(jnp.int32)
        last = i == len(params) - 1
        s_out = None if last else absmax[i + 1] / cfg.qmax
        layers.append(IntLayer(w_q=w_q, b_q=b_q, s_in=jnp.float32(s_in),
                               s_w=s_w.astype(jnp.float32),
                               s_out=None if last else jnp.float32(s_out)))
    return layers


def save_int8_artifact(path, int_layers: Sequence[IntLayer]) -> pathlib.Path:
    """Persist a full-integer network as a single servable ``.npz`` artifact.

    The artifact is the deployment unit the serving engine loads: exactly the
    ``IntLayer`` fields, nothing float-trainable.  Returns the path actually
    written (``np.savez`` appends ``.npz`` when missing).
    """
    path = pathlib.Path(path)
    if path.suffix != ".npz":
        path = path.with_suffix(path.suffix + ".npz")
    arrs = {"n_layers": np.int64(len(int_layers))}
    for i, layer in enumerate(int_layers):
        arrs[f"w_q_{i}"] = np.asarray(layer.w_q)
        arrs[f"b_q_{i}"] = np.asarray(layer.b_q)
        arrs[f"s_in_{i}"] = np.asarray(layer.s_in)
        arrs[f"s_w_{i}"] = np.asarray(layer.s_w)
        if layer.s_out is not None:
            arrs[f"s_out_{i}"] = np.asarray(layer.s_out)
    path.parent.mkdir(parents=True, exist_ok=True)
    np.savez(path, **arrs)
    return path


def load_int8_artifact(path) -> list:
    """Load a ``save_int8_artifact`` file back into ``IntLayer``s.

    Values round-trip bit-exactly (int8/int32 payloads, fp32 scales), so a
    loaded artifact serves predictions identical to the exporting process —
    asserted by tests/test_serve_recon.py.
    """
    layers = []
    with np.load(path) as z:
        n = int(z["n_layers"])
        for i in range(n):
            s_out = (jnp.asarray(z[f"s_out_{i}"], jnp.float32)
                     if f"s_out_{i}" in z.files else None)
            layers.append(IntLayer(
                w_q=jnp.asarray(z[f"w_q_{i}"], jnp.int8),
                b_q=jnp.asarray(z[f"b_q_{i}"], jnp.int32),
                s_in=jnp.asarray(z[f"s_in_{i}"], jnp.float32),
                s_w=jnp.asarray(z[f"s_w_{i}"], jnp.float32),
                s_out=s_out))
    return layers


def quantize_input(x, s_in) -> jnp.ndarray:
    return jnp.clip(jnp.round(x / s_in), -128, 127).astype(jnp.int8)


def int_dense(x_q, layer: IntLayer):
    """One integer layer: int8 x int8 -> int32 accum -> fp32 requant -> int8.

    This exact sequence (int32 accumulate, fp32 rescale, round-to-nearest-even
    via jnp.round, clip) is what the Pallas kernel must reproduce bit-for-bit.
    """
    acc = jnp.dot(x_q.astype(jnp.int32), layer.w_q.astype(jnp.int32)) + layer.b_q
    if layer.s_out is None:  # linear float head
        return acc.astype(jnp.float32) * (layer.s_in * layer.s_w)
    requant = (layer.s_in * layer.s_w) / layer.s_out
    y = jnp.round(acc.astype(jnp.float32) * requant)
    y = jnp.clip(y, 0, 127)  # ReLU fused into the clamp (zero-point 0)
    return y.astype(jnp.int8)


def int_forward(int_layers: Sequence[IntLayer], x: jnp.ndarray) -> jnp.ndarray:
    """Full-integer inference from float features (quantize once at entry)."""
    h = quantize_input(x, int_layers[0].s_in)
    for layer in int_layers:
        h = int_dense(h, layer)
    return h  # float (batch, 2) from the head
