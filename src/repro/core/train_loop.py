"""Training loops for the MRF net: the float baseline (Adam, the paper's
software setup) and the QAT loop (fake-quant, Adam), plus the evaluation the
paper runs (5000 held-out synthetic signals -> Table 1 metrics).

The *fused on-accelerator* training path (the paper's actual contribution)
lives in kernels/fused_train and is exercised by examples/mrf_fpga_train.py;
this module is the software reference those paths are validated against.
"""

from __future__ import annotations

import dataclasses
import time
from functools import partial
from typing import Callable

import jax
import jax.numpy as jnp

from repro.core import mrf_net, qat
from repro.core.metrics import table1_metrics
from repro.data.pipeline import MRFSampleStream, T1_RANGE_MS, T2_RANGE_MS, make_eval_set, sample_batch
from repro.optim import adam, sgd


@dataclasses.dataclass
class TrainConfig:
    n_frames: int = 32
    hidden: tuple = mrf_net.ADAPTED_HIDDEN
    lr: float = 1e-4            # paper's learning rate
    batch_size: int = 256
    steps: int = 500
    qat: bool = False
    optimizer: str = "adam"     # paper: Adam for software, SGD on FPGA
    seed: int = 0
    log_every: int = 100


def make_train_step(cfg: TrainConfig, opt):
    if cfg.qat:
        def loss_fn(params, qstate, x, y):
            pred, new_qstate = qat.forward_qat(params, qstate, x, train=True)
            return jnp.mean(jnp.square(pred - y)), new_qstate

        @jax.jit
        def step(params, qstate, opt_state, x, y):
            (loss, new_qstate), grads = jax.value_and_grad(loss_fn, has_aux=True)(
                params, qstate, x, y)
            params, opt_state = opt.update(grads, opt_state, params)
            return params, new_qstate, opt_state, loss
        return step

    def loss_fn(params, x, y):
        return mrf_net.mse_loss(params, x, y)

    @jax.jit
    def step(params, qstate, opt_state, x, y):
        loss, grads = jax.value_and_grad(loss_fn)(params, x, y)
        params, opt_state = opt.update(grads, opt_state, params)
        return params, qstate, opt_state, loss
    return step


def train(cfg: TrainConfig, stream: MRFSampleStream | None = None, verbose: bool = True):
    """Train an MRF net; returns (params, qstate, history)."""
    from repro.data.epg import default_sequence

    if stream is None:
        stream = MRFSampleStream(seq=default_sequence(cfg.n_frames), batch_size=cfg.batch_size)
    sizes = mrf_net.layer_sizes(stream.seq.n_frames, cfg.hidden)
    key = jax.random.PRNGKey(cfg.seed)
    key, k_init = jax.random.split(key)
    params = mrf_net.init_params(k_init, sizes)
    qstate = qat.init_qat_state(len(params))
    opt = adam(cfg.lr) if cfg.optimizer == "adam" else sgd(cfg.lr)
    opt_state = opt.init(params)
    step_fn = make_train_step(cfg, opt)

    history = []
    t0 = time.perf_counter()
    for i in range(cfg.steps):
        x, y = sample_batch(stream, jax.random.fold_in(key, i))
        params, qstate, opt_state, loss = step_fn(params, qstate, opt_state, x, y)
        if i % cfg.log_every == 0 or i == cfg.steps - 1:
            history.append((i, float(loss)))
            if verbose:
                print(f"step {i:5d}  loss {float(loss):.6f}")
    wall = time.perf_counter() - t0
    return params, qstate, {"history": history, "wall_seconds": wall, "sizes": sizes}


def evaluate(params, seq, *, qstate=None, int_layers=None, n: int = 5000, seed: int = 123):
    """The paper's test: n held-out synthetic signals -> Table 1 metrics (ms)."""
    x, y = make_eval_set(seq, n=n, seed=seed)
    if int_layers is not None:
        pred = qat.int_forward(int_layers, x)
    elif qstate is not None:
        pred, _ = qat.forward_qat(params, qstate, x, train=False)
    else:
        pred = mrf_net.forward(params, x)
    scale = jnp.array([T1_RANGE_MS[1], T2_RANGE_MS[1]])
    return table1_metrics(jnp.asarray(pred) * scale, jnp.asarray(y) * scale)
