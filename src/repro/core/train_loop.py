"""Software-reference training entry points for the MRF net.

``train()`` is now a thin wrapper over the unified engine
(``repro.train.engine``): the float baseline (Adam, the paper's software
setup), the QAT loop (fake-quant + observers), and the fused on-accelerator
kernel are all the same ``ft.runner`` run with a different backend — which
buys checkpoint/restart, the straggler watchdog, and seekable deterministic
data replay for free while reproducing the original hand-rolled loops
bit-for-bit (same init split, same per-step batch keys, same un-clipped
Adam/SGD updates).

``evaluate()`` is the paper's test: 5000 held-out synthetic signals ->
Table 1 metrics.
"""

from __future__ import annotations

import dataclasses
import tempfile

import jax
import jax.numpy as jnp

from repro.core import mrf_net, qat
from repro.core.metrics import table1_metrics_normalized
from repro.data.pipeline import MRFSampleStream, make_eval_set


@dataclasses.dataclass
class TrainConfig:
    n_frames: int = 32
    hidden: tuple = mrf_net.ADAPTED_HIDDEN
    lr: float = 1e-4            # paper's learning rate
    batch_size: int = 256
    steps: int = 500
    qat: bool = False
    optimizer: str = "adam"     # paper: Adam for software, SGD on FPGA
    seed: int = 0
    log_every: int = 100
    backend: str = ""           # "" -> float, or qat-int8 when qat=True;
                                # may name any repro.train.engine backend
    ckpt_dir: str | None = None  # None -> throwaway temp dir
    ckpt_every: int = 0         # 0 -> no periodic checkpoints
    tile_batch: int = 128       # fused-pallas only
    chunk_steps: int = 1        # >1: lax.scan chunk per dispatch (bit-
                                # identical; see repro.train.engine)


def train(cfg: TrainConfig, stream: MRFSampleStream | None = None,
          verbose: bool = True):
    """Train an MRF net through the unified engine; returns
    (params, qstate, history) — the historical wrapper signature."""
    from repro.configs.base import ModelConfig
    from repro.data.epg import default_sequence
    from repro.ft.runner import RunnerConfig
    from repro.models.mrf import build_mrf
    from repro.train import engine

    if stream is None:
        stream = MRFSampleStream(seq=default_sequence(cfg.n_frames),
                                 batch_size=cfg.batch_size)
    n_frames = stream.seq.n_frames
    sizes = mrf_net.layer_sizes(n_frames, cfg.hidden)
    # Exact key discipline of the original loop: one split for init, the
    # remaining key folded with the step index for each batch.
    key = jax.random.PRNGKey(cfg.seed)
    key, k_init = jax.random.split(key)

    backend = cfg.backend or ("qat-int8" if cfg.qat else "float")
    model_cfg = ModelConfig(
        name=f"mrf-{n_frames}f", family="mrf",
        n_layers=len(cfg.hidden) + 1, d_model=0, n_heads=0, n_kv_heads=0,
        d_ff=0, vocab_size=0, mrf_n_frames=n_frames,
        mrf_hidden=tuple(cfg.hidden)).validate()
    fns = build_mrf(model_cfg)
    ecfg = engine.EngineConfig(backend=backend, lr=cfg.lr,
                               optimizer=cfg.optimizer, max_grad_norm=None,
                               tile_batch=cfg.tile_batch,
                               chunk_steps=cfg.chunk_steps)

    history = []

    def on_metrics(step, metrics, dt):
        i = step - 1
        if i % cfg.log_every == 0 or i == cfg.steps - 1:
            history.append((i, float(metrics["loss"])))
            if verbose:
                print(f"step {i:5d}  loss {float(metrics['loss']):.6f}")

    tmp = None
    ckpt_dir = cfg.ckpt_dir
    if ckpt_dir is None:
        tmp = tempfile.TemporaryDirectory(prefix="mrf_engine_")
        ckpt_dir = tmp.name
    else:
        from repro.ft.checkpoint import latest_step
        resume = latest_step(ckpt_dir)
        if resume:
            # a persistent ckpt_dir means restartability: say so out loud,
            # since history/wall_seconds then cover only the resumed tail
            print(f"resuming from checkpoint step {resume} in {ckpt_dir}")
    try:
        rcfg = RunnerConfig(total_steps=cfg.steps, ckpt_dir=ckpt_dir,
                            ckpt_every=cfg.ckpt_every or cfg.steps + 1)
        # pass the (stream, key) pair rather than a prebuilt factory: the
        # engine derives both the host factory and the in-scan sampler from
        # it, so stepwise and chunked draw identical batches
        state, _, info = engine.train(
            fns, ecfg, rcfg, stream=stream, data_key=key,
            init_key=k_init, batch_size=stream.batch_size,
            on_metrics=on_metrics)
    finally:
        if tmp is not None:
            tmp.cleanup()

    qstate = state.aux if state.aux is not None else qat.init_qat_state(
        len(state.params))
    return state.params, qstate, {"history": history,
                                  "wall_seconds": info["wall_seconds"],
                                  "sizes": sizes}


def evaluate(params, seq, *, qstate=None, int_layers=None, n: int = 5000, seed: int = 123):
    """The paper's test: n held-out synthetic signals -> Table 1 metrics (ms)."""
    x, y = make_eval_set(seq, n=n, seed=seed)
    if int_layers is not None:
        pred = qat.int_forward(int_layers, x)
    elif qstate is not None:
        pred, _ = qat.forward_qat(params, qstate, x, train=False)
    else:
        pred = mrf_net.forward(params, x)
    return table1_metrics_normalized(jnp.asarray(pred), jnp.asarray(y))
