"""Reproduction package: FPGA-accelerated NN training for MRF map
reconstruction, grown toward a production-scale sharded jax system."""
