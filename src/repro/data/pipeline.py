"""Streaming MRF training-data pipeline.

The paper trains on 250M simulated signals.  Materialising that is absurd;
the right systems design (and what we ship) is an *infinite, seeded,
on-the-fly* sample stream: each batch draws (T1, T2) from the physiological
prior, simulates fingerprints with the Bloch/EPG recursion, and applies the
SNR/phase augmentations — all inside one jit'd function, double-buffered so
host->device transfer overlaps compute.

For multi-host training the stream is sharded by host: host i draws from a
key folded with its process index, so the global batch is i.i.d. without any
coordination (the standard tf.data-free JAX input pattern).
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Callable, Iterator

import jax
import jax.numpy as jnp

from repro.data.epg import MRFSequence, augment, to_features

# Physiological brain ranges used by the Barbieri-family MRF papers (ms).
T1_RANGE_MS = (100.0, 4000.0)
T2_RANGE_MS = (10.0, 600.0)


@dataclasses.dataclass(frozen=True)
class MRFSampleStream:
    seq: MRFSequence
    batch_size: int
    snr_range: tuple = (2.0, 50.0)
    t1_range: tuple = T1_RANGE_MS
    t2_range: tuple = T2_RANGE_MS

    @property
    def feature_dim(self) -> int:
        return 2 * self.seq.n_frames


@partial(jax.jit, static_argnames=("stream",))
def sample_batch(stream: MRFSampleStream, key: jax.Array):
    """One training batch: features (B, 2F) and targets (B, 2) in NORMALISED units.

    Targets are (T1/T1_max, T2/T2_max) so the MSE loss weighs both maps; metrics
    un-normalise before computing MAPE/MPE/RMSE (paper reports ms).
    """
    k_t1, k_t2, k_aug = jax.random.split(key, 3)
    b = stream.batch_size
    # Log-uniform draw matches the dictionary-density practice for T1/T2 grids.
    lo1, hi1 = stream.t1_range
    lo2, hi2 = stream.t2_range
    t1 = jnp.exp(jax.random.uniform(k_t1, (b,), minval=jnp.log(lo1), maxval=jnp.log(hi1)))
    t2 = jnp.exp(jax.random.uniform(k_t2, (b,), minval=jnp.log(lo2), maxval=jnp.log(hi2)))
    # Enforce T2 <= T1 (physical constraint in tissue).
    t2 = jnp.minimum(t2, t1)
    from repro.data.epg import simulate_fingerprints  # local import to keep jit graph clean

    sig = simulate_fingerprints(stream.seq, t1, t2)
    sig = augment(k_aug, sig, stream.snr_range)
    x = to_features(sig)
    y = jnp.stack([t1 / hi1, t2 / hi2], axis=-1).astype(jnp.float32)
    return x, y


def make_batch_iterator(stream: MRFSampleStream, seed: int = 0,
                        process_index: int | None = None) -> Iterator:
    """Infinite, host-sharded iterator of (features, targets) device arrays."""
    pidx = jax.process_index() if process_index is None else process_index
    key = jax.random.fold_in(jax.random.PRNGKey(seed), pidx)
    step = 0
    while True:
        yield sample_batch(stream, jax.random.fold_in(key, step))
        step += 1


def batch_at(stream: MRFSampleStream, key: jax.Array, step) -> dict:
    """The seekable sampler itself: ``{"x", "y"}`` batch at a global step.

    ``step`` may be a Python int (host dispatch) or a traced int32 scalar —
    the batch key is ``fold_in(key, step)`` either way, so a chunked train
    loop can synthesize batches *inside* ``lax.scan`` (zero steady-state
    host->device transfers) and draw bit-identical data to the host path.
    ``make_batch_factory`` routes through here so the two can never diverge.
    """
    x, y = sample_batch(stream, jax.random.fold_in(key, step))
    return {"x": x, "y": y}


def make_batch_factory(stream: MRFSampleStream,
                       key: jax.Array) -> Callable[[int], dict]:
    """Seekable deterministic batch factory — the ``ft.runner`` data contract.

    ``factory(step)`` returns the SAME ``{"x", "y"}`` batch for the same step
    every time it is called (the batch key is ``fold_in(key, step)``), so a
    checkpoint-restart replays the stream exactly from the resume step.
    """
    def at(step: int) -> dict:
        return batch_at(stream, key, step)
    return at


def denormalize_targets(y, t1_range: tuple = T1_RANGE_MS,
                        t2_range: tuple = T2_RANGE_MS):
    """Normalised (T1/T1_max, T2/T2_max) targets/predictions -> milliseconds.

    The single place that knows how ``sample_batch`` normalised its targets;
    metrics, the examples, and the serving engine all route through here so a
    changed stream range cannot silently corrupt reconstructed maps.
    ``y``: (..., 2) array-like; returns float32 of the same shape.
    """
    scale = jnp.array([t1_range[1], t2_range[1]], jnp.float32)
    return jnp.asarray(y, jnp.float32) * scale


def host_sharded_key(seed: int = 0, process_index: int | None = None) -> jax.Array:
    """Per-host stream key: host i draws i.i.d. batches without coordination."""
    pidx = jax.process_index() if process_index is None else process_index
    return jax.random.fold_in(jax.random.PRNGKey(seed), pidx)


def make_eval_set(seq: MRFSequence, n: int = 5000, seed: int = 123, snr: float = 20.0):
    """The paper's held-out evaluation: n never-before-seen synthetic signals."""
    stream = MRFSampleStream(seq=seq, batch_size=n, snr_range=(snr, snr))
    return sample_batch(stream, jax.random.PRNGKey(seed))
