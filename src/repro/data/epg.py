"""MRF signal simulation: IR-bSSFP fingerprint generation in JAX.

The paper trains the Barbieri et al. network on 250M *simulated* MRF signals
with varying SNR and global phase.  This module is the simulator substrate:
a Bloch-equation recursion over an IR-bSSFP flip-angle train (the classic
Ma et al. 2013 MRF sequence family), vmapped over (T1, T2) and scanned over
the TR train with ``jax.lax.scan``.

Design notes
------------
* We track the full magnetization vector M = (Mx, My, Mz) of the on-resonance
  isochromat.  bSSFP with alternating RF phase (0, pi, 0, ...) is simulated by
  flipping about the x-axis with alternating sign; the complex signal is the
  transverse magnetization at the echo time TE = TR/2.
* Fingerprints are L2-normalised per signal (standard MRF practice, and what
  makes the NN invariant to proton density), then augmented with a global
  phase e^{i phi} and complex AWGN at a target SNR — the two augmentations the
  paper names explicitly.
* Everything is jit/vmap friendly and dtype-stable in float32.
"""

from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class MRFSequence:
    """An MRF acquisition schedule: per-frame flip angles (rad) and TRs (s)."""

    flip_angles: tuple  # length n_frames, radians
    trs: tuple          # length n_frames, seconds
    inversion: bool = True
    inv_delay: float = 0.018  # TI after the inversion pulse, seconds

    @property
    def n_frames(self) -> int:
        return len(self.flip_angles)


def default_sequence(n_frames: int = 64, seed: int = 0) -> MRFSequence:
    """A Ma-et-al-style sinusoidal flip-angle train with mildly varying TR."""
    rng = np.random.default_rng(seed)
    t = np.arange(n_frames)
    # Two sinusoidal lobes between ~5 and ~70 degrees, plus small jitter.
    lobes = 10.0 + 60.0 * np.abs(np.sin(np.pi * t / (n_frames / 2.0)))
    fa = np.deg2rad(lobes + rng.uniform(-2.0, 2.0, n_frames))
    # Perlin-ish TR variation around 12 ms.
    tr = 0.012 + 0.003 * np.sin(2 * np.pi * t / max(n_frames, 1)) + rng.uniform(0, 5e-4, n_frames)
    return MRFSequence(flip_angles=tuple(fa.tolist()), trs=tuple(tr.tolist()))


def _bloch_step(carry, frame, *, te_frac: float = 0.5):
    """One TR of the bSSFP recursion.

    carry: (M, sign) with M = (3,) magnetization, sign = RF phase alternation.
    frame: (fa, tr, e1_?, ...) -> we pass (fa, tr) and T1/T2 via closure-free
    carry-side constants packed into ``frame``: (fa, tr, r1, r2).
    Returns the complex transverse signal at TE.
    """
    m, sign = carry
    fa, tr, r1, r2 = frame
    a = fa * sign
    # RF rotation about x-axis by angle a (R_x(a) applied componentwise; the
    # rotation-matrix oracle in tests/test_mrf_core.py pins this down).
    ca, sa = jnp.cos(a), jnp.sin(a)
    mx = m[0]
    my = ca * m[1] + sa * m[2]
    mz = -sa * m[1] + ca * m[2]
    m = jnp.stack([mx, my, mz])
    # Relax to TE = te_frac * TR, read signal, then relax the rest of the TR.
    e1a = jnp.exp(-tr * te_frac * r1)
    e2a = jnp.exp(-tr * te_frac * r2)
    m_te = jnp.stack([m[0] * e2a, m[1] * e2a, 1.0 + (m[2] - 1.0) * e1a])
    sig = m_te[0] + 1j * m_te[1]
    e1b = jnp.exp(-tr * (1.0 - te_frac) * r1)
    e2b = jnp.exp(-tr * (1.0 - te_frac) * r2)
    m_next = jnp.stack([m_te[0] * e2b, m_te[1] * e2b, 1.0 + (m_te[2] - 1.0) * e1b])
    return (m_next, -sign), sig


def _simulate_one(t1_s: jnp.ndarray, t2_s: jnp.ndarray, fas: jnp.ndarray,
                  trs: jnp.ndarray, inversion: bool, inv_delay: float) -> jnp.ndarray:
    """Complex fingerprint (n_frames,) for one (T1, T2) pair, times in seconds."""
    r1 = 1.0 / jnp.maximum(t1_s, 1e-6)
    r2 = 1.0 / jnp.maximum(t2_s, 1e-6)
    m0 = jnp.array([0.0, 0.0, -1.0 if inversion else 1.0], dtype=jnp.float32)
    if inversion:
        e1 = jnp.exp(-inv_delay * r1)
        m0 = jnp.array([0.0, 0.0, 1.0 + (-1.0 - 1.0) * e1])
    frames = jnp.stack(
        [fas, trs, jnp.broadcast_to(r1, fas.shape), jnp.broadcast_to(r2, fas.shape)], axis=1
    )
    (_, _), sig = jax.lax.scan(_bloch_step, (m0, jnp.float32(1.0)), frames)
    return sig


@partial(jax.jit, static_argnames=("inversion",))
def _simulate_batch(t1_s, t2_s, fas, trs, inversion, inv_delay):
    f = jax.vmap(lambda a, b: _simulate_one(a, b, fas, trs, inversion, inv_delay))
    return f(t1_s, t2_s)


def simulate_fingerprints(seq: MRFSequence, t1_ms: jnp.ndarray, t2_ms: jnp.ndarray) -> jnp.ndarray:
    """Simulate complex fingerprints for arrays of T1/T2 (in milliseconds).

    Returns complex64 array of shape (batch, n_frames), L2-normalised.
    """
    fas = jnp.asarray(seq.flip_angles, dtype=jnp.float32)
    trs = jnp.asarray(seq.trs, dtype=jnp.float32)
    sig = _simulate_batch(
        jnp.asarray(t1_ms, jnp.float32) / 1e3,
        jnp.asarray(t2_ms, jnp.float32) / 1e3,
        fas, trs, seq.inversion, seq.inv_delay,
    )
    norm = jnp.linalg.norm(sig, axis=-1, keepdims=True)
    return (sig / jnp.maximum(norm, 1e-12)).astype(jnp.complex64)


def augment(key: jax.Array, sig: jnp.ndarray, snr_range=(2.0, 50.0)) -> jnp.ndarray:
    """Apply the paper's augmentations: random global phase + AWGN at random SNR."""
    k_phase, k_snr, k_noise = jax.random.split(key, 3)
    batch = sig.shape[0]
    phase = jax.random.uniform(k_phase, (batch, 1), minval=0.0, maxval=2 * jnp.pi)
    sig = sig * jnp.exp(1j * phase)
    snr = jax.random.uniform(k_snr, (batch, 1), minval=snr_range[0], maxval=snr_range[1])
    # Per-sample signal power is 1 (L2-normalised over n_frames) -> per-frame
    # power 1/n; noise sigma chosen so per-frame amplitude SNR matches.
    n = sig.shape[-1]
    sigma = 1.0 / (snr * jnp.sqrt(jnp.float32(n)))
    noise = sigma * (
        jax.random.normal(k_noise, sig.shape) + 1j * jax.random.normal(jax.random.fold_in(k_noise, 1), sig.shape)
    ) / jnp.sqrt(2.0)
    return (sig + noise).astype(jnp.complex64)


def to_features(sig: jnp.ndarray) -> jnp.ndarray:
    """Complex fingerprints -> NN input features [Re | Im], float32."""
    return jnp.concatenate([jnp.real(sig), jnp.imag(sig)], axis=-1).astype(jnp.float32)
