from repro.data.epg import MRFSequence, simulate_fingerprints, default_sequence
from repro.data.pipeline import MRFSampleStream, make_batch_iterator
