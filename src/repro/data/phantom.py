"""Synthetic 2D brain phantom + simulated MRF acquisition.

The paper's end use-case reconstructs T1/T2 *maps* of a slice; this module
provides the slice: a concentric-ellipse phantom with CSF / grey / white
matter regions at 3T-ish relaxation values, and the per-voxel MRF acquisition
(Bloch simulation + SNR/phase augmentation + feature extraction) that turns
it into a serving request.  Both ``examples/phantom_recon.py`` and the
``launch.serve`` smoke path are thin clients of these two functions.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.data.epg import MRFSequence, augment, simulate_fingerprints, to_features

# tissue classes: (T1 ms, T2 ms) at 3T-ish values
TISSUES = {"background": (0.0, 0.0), "csf": (3500.0, 450.0),
           "grey": (1400.0, 110.0), "white": (800.0, 80.0)}


def make_phantom(n: int = 32):
    """Concentric-ellipse phantom; returns (t1_map, t2_map, mask), all (n, n).

    ``mask`` is True on tissue voxels (the ellipse), False on background."""
    yy, xx = np.mgrid[0:n, 0:n]
    cy = cx = (n - 1) / 2
    r2 = ((yy - cy) / (n * 0.45)) ** 2 + ((xx - cx) / (n * 0.38)) ** 2
    t1 = np.zeros((n, n)); t2 = np.zeros((n, n))
    for name, r_out in (("white", 1.0), ("grey", 0.55), ("csf", 0.18)):
        m = r2 <= r_out
        t1[m], t2[m] = TISSUES[name]
    mask = r2 <= 1.0
    return t1, t2, mask


def acquire_slice(seq: MRFSequence, t1_map, t2_map, mask, *,
                  snr: float = 25.0, key: jax.Array | None = None):
    """Simulate the MRF acquisition of one slice's tissue voxels.

    Returns ``(features, mask)``: NN input features (n_voxels, 2F) for the
    masked voxels in row-major order, ready to wrap in a ``ReconRequest``.
    """
    if key is None:
        key = jax.random.PRNGKey(0)
    mask = np.asarray(mask, bool)
    vox = mask.reshape(-1)
    sig = simulate_fingerprints(
        seq,
        jnp.asarray(np.asarray(t1_map).reshape(-1)[vox]),
        jnp.asarray(np.asarray(t2_map).reshape(-1)[vox]))
    sig = augment(key, sig, snr_range=(snr, snr))
    return to_features(sig), mask


def tissue_errors(t1_hat, t2_hat, t1_map, mask) -> dict:
    """Per-tissue mean |error| in % against the phantom's reference values."""
    out = {}
    for name, (ref1, ref2) in TISSUES.items():
        if name == "background":
            continue
        m = (np.asarray(t1_map) == ref1) & np.asarray(mask)
        if not m.any():
            continue
        out[name] = {
            "T1_err_%": float(np.mean(np.abs(t1_hat[m] - ref1)) / ref1 * 100),
            "T2_err_%": float(np.mean(np.abs(t2_hat[m] - ref2)) / ref2 * 100),
        }
    return out
