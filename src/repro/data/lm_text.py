"""Self-contained LM data pipeline: byte-level tokenizer, sequence packing,
deterministic seekable batches, host sharding.

The corpus is an embedded public-domain text (so the pipeline is fully
implemented and runs offline — tokenize -> pack -> batch, the same mechanics
a production loader has).  ``batch_at(step)`` is a pure function of the step
index, which is what makes checkpoint-restart replay exact (ft/runner.py).
"""

from __future__ import annotations

import dataclasses

import numpy as np

_CORPUS = (
    "Magnetic resonance fingerprinting is a quantitative imaging technique "
    "that encodes tissue parameters in transient signal evolutions. A neural "
    "network maps measured fingerprints to parameter values, replacing "
    "dictionary matching whose cost grows exponentially with dimensionality. "
    "Training the network is the bottleneck: every scanner, field strength, "
    "and sequence variation demands a retrain. Hardware acceleration of the "
    "training loop itself, with integer arithmetic and on-chip weights, "
    "turns hours into seconds and enables scanner-side personalisation. "
    "The quick brown fox jumps over the lazy dog. 0123456789. "
) * 64  # ~40 KB


@dataclasses.dataclass(frozen=True)
class TextPipeline:
    seq_len: int
    batch_size: int
    vocab_size: int = 256          # byte-level
    seed: int = 0
    n_hosts: int = 1
    host: int = 0

    def __post_init__(self):
        data = np.frombuffer(_CORPUS.encode(), dtype=np.uint8)
        object.__setattr__(self, "_tokens", data)

    @property
    def tokens_per_batch(self) -> int:
        return self.seq_len * self.batch_size

    def batch_at(self, step: int) -> dict:
        """Deterministic, seekable batch: (tokens, labels) both (B, S)."""
        rng = np.random.default_rng(self.seed + step * 1_000_003 + self.host)
        n = len(self._tokens) - self.seq_len - 1
        b = self.batch_size // self.n_hosts
        starts = rng.integers(0, n, size=b)
        toks = np.stack([self._tokens[s:s + self.seq_len] for s in starts])
        labs = np.stack([self._tokens[s + 1:s + self.seq_len + 1] for s in starts])
        return {"tokens": toks.astype(np.int32) % self.vocab_size,
                "labels": labs.astype(np.int32) % self.vocab_size}
