"""minitron-8b — 32L d_model=4096 32H (GQA kv=8) d_ff=16384 vocab=256000,
pruned nemotron (squared-ReLU non-gated FFN). [arXiv:2407.14679; hf]"""
from repro.configs.base import ModelConfig
from repro.configs.smoke import smoke_of

CONFIG = ModelConfig(
    name="minitron-8b", family="dense",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8, d_ff=16384,
    vocab_size=256000, gated_mlp=False,
).validate()

def smoke():
    return smoke_of(CONFIG)
