"""The paper's own model: the FPGA-adapted MRF reconstruction MLP
(see repro.core.mrf_net), registered as a first-class arch so
``--arch mrf-fpga`` runs through the same engine as the LM zoo."""
import dataclasses

from repro.configs.base import ModelConfig
from repro.core import mrf_net

N_FRAMES = 32
SIZES = mrf_net.layer_sizes(N_FRAMES, mrf_net.ADAPTED_HIDDEN)

CONFIG = ModelConfig(
    name="mrf-fpga", family="mrf",
    n_layers=len(mrf_net.ADAPTED_HIDDEN) + 1,
    d_model=0, n_heads=0, n_kv_heads=0, d_ff=0, vocab_size=0,
    mrf_n_frames=N_FRAMES, mrf_hidden=mrf_net.ADAPTED_HIDDEN,
).validate()


def smoke() -> ModelConfig:
    """CPU-runnable reduction: fewer fingerprint frames, same topology."""
    return dataclasses.replace(CONFIG, mrf_n_frames=16)
