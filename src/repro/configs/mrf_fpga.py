"""The paper's own model: the FPGA-adapted MRF reconstruction MLP
(see repro.core.mrf_net).  Not part of the LM zoo; exposed here so the
launcher can --arch mrf-fpga for the end-to-end MRF example."""
from repro.core import mrf_net

N_FRAMES = 32
SIZES = mrf_net.layer_sizes(N_FRAMES, mrf_net.ADAPTED_HIDDEN)
ORIGINAL_SIZES = mrf_net.layer_sizes(N_FRAMES, mrf_net.ORIGINAL_HIDDEN)
