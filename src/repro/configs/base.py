"""Model/run configuration schema for the architecture zoo.

One ``ModelConfig`` fully determines an architecture; ``src/repro/configs/<id>.py``
holds the exact assigned configs plus a reduced ``smoke()`` variant per arch.
``ShapeCell`` describes the assigned input-shape cells (train_4k / prefill_32k /
decode_32k / long_500k).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Literal

Family = Literal["dense", "moe", "ssm", "hybrid", "encdec", "vlm", "mrf"]


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: Family
    n_layers: int
    d_model: int
    n_heads: int            # query heads; 0 for attention-free (mamba2)
    n_kv_heads: int
    d_ff: int               # per-expert FFN width for MoE; 0 for attention-free
    vocab_size: int
    d_head: int = 0         # 0 -> d_model // n_heads
    # --- MoE ---
    n_experts: int = 0      # routed experts
    n_shared_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    # --- SSM (mamba2 / hybrid) ---
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_chunk: int = 256
    # --- hybrid / attention flavour ---
    swa_window: int = 0       # 0 = full attention
    global_layer_every: int = 0  # hybrid: every k-th layer uses full attention
    # --- encoder-decoder ---
    n_enc_layers: int = 0     # 0 = decoder-only
    # --- multimodal stub frontend ---
    n_prefix_embeds: int = 0  # precomputed patch/frame embeddings (vlm/audio)
    # --- MRF reconstruction nets (family == "mrf") ---
    mrf_n_frames: int = 0     # fingerprint frames; input dim = 2 * frames
    mrf_hidden: tuple = ()    # hidden widths ((T1, T2) head appended)
    # --- misc ---
    qkv_bias: bool = False
    gated_mlp: bool = True    # SwiGLU (llama-family); False -> GELU MLP
    rope_theta: float = 1e4
    norm_eps: float = 1e-5
    # --- the paper's technique knob ---
    quant: str = "none"       # "none" | "qat-int8" (fake-quant, semantic QAT)
                              # | "int8-hlo" (true int8 fwd dots + STE bwd —
                              #   the deployment form, visible in the HLO)
    # --- §Perf levers ---
    parallel_block: bool = False  # PaLM-style attn ∥ mlp: 1 TP all-reduce/layer
    remat: str = "full"           # "full" | "save_attn" (keep attention
                                  # outputs; skip re-running attention in bwd)
    decode_unroll: bool = False   # python-loop decode layers with per-layer
                                  # donated caches (kills scan ds/DUS/copy
                                  # cache traffic — §Perf cross-cutting)

    # ------------------------------------------------------------------ #
    @property
    def head_dim(self) -> int:
        if self.d_head:
            return self.d_head
        return self.d_model // self.n_heads if self.n_heads else 0

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def sub_quadratic(self) -> bool:
        """Eligible for long_500k decode (bounded per-token state)."""
        return self.family in ("ssm", "hybrid")

    @property
    def d_inner(self) -> int:  # SSM inner width
        return self.ssm_expand * self.d_model

    @property
    def n_ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim if self.ssm_state else 0

    # --- TP head padding (DESIGN.md §5): heads -> multiple of tp ---------- #
    def padded_heads(self, tp: int) -> tuple[int, int]:
        """(q_heads, kv_heads) padded so both divide ``tp``.

        KV heads are group-replicated up to ``tp`` when needed (vLLM-style);
        query heads are zero-padded up to a multiple of ``tp``.  With tp=1
        this is the exact architecture.
        """
        if self.n_heads == 0:
            return (0, 0)
        hq = math.ceil(self.n_heads / tp) * tp
        if self.n_kv_heads % tp == 0 and hq % self.n_kv_heads == 0 and self.n_heads % tp == 0:
            return (self.n_heads, self.n_kv_heads)
        hkv = tp if tp > 1 else self.n_kv_heads
        while hq % hkv:  # ensure grouping divides
            hq += tp
        return (hq, hkv)

    def padded_vocab(self, tp: int) -> int:
        return math.ceil(self.vocab_size / tp) * tp

    def validate(self):
        if self.family == "mrf":
            assert self.mrf_n_frames > 0 and self.mrf_hidden, self.name
            return self
        if self.n_heads:
            assert self.head_dim * self.n_heads >= self.d_model or self.d_head, self.name
        if self.family == "moe":
            assert self.n_experts > 0 and self.top_k > 0, self.name
        if self.family in ("ssm", "hybrid"):
            assert self.ssm_state > 0, self.name
        return self


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    name: str
    seq_len: int
    global_batch: int
    kind: Literal["train", "prefill", "decode"]


TRAIN_4K = ShapeCell("train_4k", 4_096, 256, "train")
PREFILL_32K = ShapeCell("prefill_32k", 32_768, 32, "prefill")
DECODE_32K = ShapeCell("decode_32k", 32_768, 128, "decode")
LONG_500K = ShapeCell("long_500k", 524_288, 1, "decode")
ALL_CELLS = (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)


def cells_for(cfg: ModelConfig) -> list[ShapeCell]:
    """Assigned cells actually runnable for this arch (skips documented in
    DESIGN.md §4: long_500k only for sub-quadratic archs)."""
    out = []
    for c in ALL_CELLS:
        if c.name == "long_500k" and not cfg.sub_quadratic:
            continue
        out.append(c)
    return out


def param_count(cfg: ModelConfig) -> int:
    """Analytic parameter count (exact for our implementation, tp=1)."""
    if cfg.family == "mrf":
        sizes = (2 * cfg.mrf_n_frames, *cfg.mrf_hidden, 2)
        return sum(i * o + o for i, o in zip(sizes[:-1], sizes[1:]))
    d, L = cfg.d_model, cfg.n_layers
    total = cfg.vocab_size * d * 2  # embed + head (untied)
    per_layer = 2 * d  # two RMSNorm gains

    def attn_params():
        hq, hkv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
        p = d * hq * dh + 2 * d * hkv * dh + hq * dh * d
        if cfg.qkv_bias:
            p += (hq + 2 * hkv) * dh
        return p

    def ffn_params(ff):
        return d * ff * (3 if cfg.gated_mlp else 2)

    def ssm_params():
        di, ns, nh = cfg.d_inner, cfg.ssm_state, cfg.n_ssm_heads
        # in_proj: x, z, B, C, dt ; out_proj ; A, D, dt_bias, norm
        return d * (2 * di + 2 * ns + nh) + di * d + 3 * nh + di

    if cfg.family == "dense" or cfg.family == "vlm":
        per_layer += attn_params() + ffn_params(cfg.d_ff)
    elif cfg.family == "moe":
        per_layer += attn_params() + d * cfg.n_experts  # router
        per_layer += cfg.n_experts * ffn_params(cfg.d_ff)
        per_layer += cfg.n_shared_experts * ffn_params(cfg.d_ff)
    elif cfg.family == "ssm":
        per_layer = 2 * d + ssm_params()
    elif cfg.family == "hybrid":
        per_layer += attn_params() + ssm_params() + ffn_params(cfg.d_ff)
    elif cfg.family == "encdec":
        # decoder layer: self-attn + cross-attn + ffn; encoder layer: attn + ffn
        dec = attn_params() * 2 + ffn_params(cfg.d_ff) + 3 * d
        enc = attn_params() + ffn_params(cfg.d_ff) + 2 * d
        return cfg.vocab_size * d * 2 + L * dec + cfg.n_enc_layers * enc + 2 * d
    total += L * per_layer + d  # final norm
    return total


def active_param_count(cfg: ModelConfig) -> int:
    """Active params per token (MoE: top_k + shared experts only)."""
    if cfg.family != "moe":
        return param_count(cfg)
    d = cfg.d_model
    ffn = d * cfg.d_ff * (3 if cfg.gated_mlp else 2)
    inactive = (cfg.n_experts - cfg.top_k) * ffn
    return param_count(cfg) - cfg.n_layers * inactive
