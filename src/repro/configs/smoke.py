"""Reduced same-family smoke variants of the assigned configs: tiny widths,
few layers/experts, small vocab — run a real forward/train step on CPU."""

from __future__ import annotations

import dataclasses

from repro.configs.base import ModelConfig


def smoke_of(cfg: ModelConfig) -> ModelConfig:
    kw = dict(
        name=cfg.name + "-smoke",
        n_layers=2,
        d_model=64,
        d_head=16,
        d_ff=0 if cfg.d_ff == 0 else 128,
        vocab_size=256,
    )
    if cfg.n_heads:
        kw.update(n_heads=4, n_kv_heads=2)
    if cfg.family == "moe":
        kw.update(n_experts=4, top_k=min(cfg.top_k, 2),
                  n_shared_experts=min(cfg.n_shared_experts, 1))
    if cfg.ssm_state:
        kw.update(ssm_state=8, ssm_head_dim=16, ssm_chunk=8)
    if cfg.swa_window:
        kw.update(swa_window=8)
    if cfg.global_layer_every:
        kw.update(global_layer_every=2)
    if cfg.n_enc_layers:
        kw.update(n_enc_layers=2)
    if cfg.n_prefix_embeds:
        kw.update(n_prefix_embeds=8)
    return dataclasses.replace(cfg, **kw).validate()
