"""seamless-m4t-large-v2 — enc-dec 24L+24L d_model=1024 16H (kv=16) d_ff=8192
vocab=256206, multimodal.  Backbone only: the speech frontend is a stub —
input_specs provides precomputed frame embeddings (S_enc = seq/4).
[arXiv:2308.11596; hf]"""
from repro.configs.base import ModelConfig
from repro.configs.smoke import smoke_of

CONFIG = ModelConfig(
    name="seamless-m4t-large-v2", family="encdec",
    n_layers=24, n_enc_layers=24, d_model=1024, n_heads=16, n_kv_heads=16,
    d_ff=8192, vocab_size=256206, d_head=64,
).validate()

def smoke():
    return smoke_of(CONFIG)
