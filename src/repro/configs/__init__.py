"""Assigned architecture configs (one module per arch) + registry."""
from repro.configs import (deepseek_moe_16b, granite_8b, hymba_1_5b,
                           llava_next_34b, mamba2_1_3b, minitron_8b,
                           mrf_fpga, mrf_original, phi35_moe_42b,
                           qwen2_5_14b, seamless_m4t_large_v2,
                           tinyllama_1_1b)
from repro.configs.base import (ALL_CELLS, DECODE_32K, LONG_500K, PREFILL_32K,
                                TRAIN_4K, ModelConfig, ShapeCell, cells_for)

ARCHS = {
    m.CONFIG.name: m for m in (
        phi35_moe_42b, deepseek_moe_16b, mamba2_1_3b, minitron_8b,
        tinyllama_1_1b, granite_8b, qwen2_5_14b, llava_next_34b,
        hymba_1_5b, seamless_m4t_large_v2,
        mrf_fpga, mrf_original,  # the paper's nets, same engine as the zoo
    )
}

def lm_archs() -> list[str]:
    """Arch ids with the LM train/prefill/decode surface (shape-cell sweeps,
    dry-runs); excludes the feed-forward MRF reconstruction nets."""
    return sorted(n for n, m in ARCHS.items() if m.CONFIG.family != "mrf")

def get_config(name: str) -> ModelConfig:
    return ARCHS[name].CONFIG

def get_smoke(name: str) -> ModelConfig:
    return ARCHS[name].smoke()
