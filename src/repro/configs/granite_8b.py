"""granite-8b — 36L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=49152,
llama-arch, code. [arXiv:2405.04324; hf]"""
from repro.configs.base import ModelConfig
from repro.configs.smoke import smoke_of

CONFIG = ModelConfig(
    name="granite-8b", family="dense",
    n_layers=36, d_model=4096, n_heads=32, n_kv_heads=8, d_ff=14336,
    vocab_size=49152,
).validate()

def smoke():
    return smoke_of(CONFIG)
