"""deepseek-moe-16b — 28L d_model=2048 16H (GQA kv=16) d_ff=1408 (per expert,
fine-grained) vocab=102400, MoE: 2 shared + 64 routed top-6.
[arXiv:2401.06066; hf]  (the HF checkpoint's dense layer-0 FFN is modelled as
MoE like the rest — homogeneous stack for the layer scan; DESIGN.md §4)"""
from repro.configs.base import ModelConfig
from repro.configs.smoke import smoke_of

CONFIG = ModelConfig(
    name="deepseek-moe-16b", family="moe",
    n_layers=28, d_model=2048, n_heads=16, n_kv_heads=16, d_ff=1408,
    vocab_size=102400, n_experts=64, top_k=6, n_shared_experts=2,
).validate()

def smoke():
    return smoke_of(CONFIG)
