"""hymba-1.5b — 32L d_model=1600 25H (GQA kv=5) d_ff=5504 vocab=32001,
parallel attn+mamba heads, SWA(1024) + 3 global full-attention layers
(first / middle / last; meta-tokens omitted — DESIGN.md §4), ssm_state=16.
[arXiv:2411.13676; hf]"""
from repro.configs.base import ModelConfig
from repro.configs.smoke import smoke_of

CONFIG = ModelConfig(
    name="hymba-1.5b", family="hybrid",
    n_layers=32, d_model=1600, n_heads=25, n_kv_heads=5, d_ff=5504,
    vocab_size=32001, d_head=64, ssm_state=16, ssm_head_dim=64,
    swa_window=1024, global_layer_every=16,
).validate()

def smoke():
    return smoke_of(CONFIG)
