"""llava-next-34b — 60L d_model=7168 56H (GQA kv=8) d_ff=20480 vocab=64000,
anyres tiling VLM.  Backbone only: the vision tower is a stub — input_specs
provides precomputed patch embeddings (5 anyres tiles x 576 = 2880 tokens).
[hf:llava-hf/llava-v1.6-mistral-7b-hf; unverified]"""
from repro.configs.base import ModelConfig
from repro.configs.smoke import smoke_of

CONFIG = ModelConfig(
    name="llava-next-34b", family="vlm",
    n_layers=60, d_model=7168, n_heads=56, n_kv_heads=8, d_ff=20480,
    vocab_size=64000, d_head=128, n_prefix_embeds=2880,
).validate()

def smoke():
    return smoke_of(CONFIG)
