"""The Barbieri-et-al original 9-layer MRF reconstruction MLP (the software
baseline the paper adapts down to the FPGA budget) — the ``original`` row of
Table 1, trained through the same engine as ``mrf-fpga``."""
import dataclasses

from repro.configs.base import ModelConfig
from repro.configs.mrf_fpga import N_FRAMES
from repro.core import mrf_net

CONFIG = ModelConfig(
    name="mrf-original", family="mrf",
    n_layers=len(mrf_net.ORIGINAL_HIDDEN) + 1,
    d_model=0, n_heads=0, n_kv_heads=0, d_ff=0, vocab_size=0,
    mrf_n_frames=N_FRAMES, mrf_hidden=mrf_net.ORIGINAL_HIDDEN,
).validate()


def smoke() -> ModelConfig:
    return dataclasses.replace(CONFIG, mrf_n_frames=16)
