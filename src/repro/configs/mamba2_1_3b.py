"""mamba2-1.3b — 48L d_model=2048, attention-free SSD (state-space duality),
ssm_state=128. [arXiv:2405.21060; unverified]"""
from repro.configs.base import ModelConfig
from repro.configs.smoke import smoke_of

CONFIG = ModelConfig(
    name="mamba2-1.3b", family="ssm",
    n_layers=48, d_model=2048, n_heads=0, n_kv_heads=0, d_ff=0,
    vocab_size=50280, ssm_state=128, ssm_expand=2, ssm_head_dim=64,
    ssm_chunk=256,
).validate()

def smoke():
    return smoke_of(CONFIG)
