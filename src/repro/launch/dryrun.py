import os
if "--xla_force_host_platform_device_count" not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                               + " --xla_force_host_platform_device_count=512"
                               ).strip()

# (the lines above MUST precede any jax-importing module: jax locks the
#  device count at first backend init — see the multi-pod dry-run contract.
#  Append-if-absent, not assignment: callers that want a smaller fake
#  topology — e.g. the 16-device subprocess in tests/test_distribution.py —
#  set the flag before importing this module and must not be clobbered, and
#  unrelated user-set XLA flags must survive)

import argparse
import dataclasses
import json
import pathlib
import time

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.analysis.hlo_cost import analyze_hlo
from repro.analysis.roofline import (model_flops_decode, model_flops_train,
                                     roofline_terms)
from repro.configs import cells_for, get_config, lm_archs
from repro.configs.base import ModelConfig, ShapeCell, active_param_count, param_count
from repro.dist.sharding import use_rules
from repro.launch import input_specs as specs_mod
from repro.launch.mesh import make_production_mesh, rules_for
from repro.models import registry
from repro.optim import adam
from repro.serve.decode import make_serve_step
from repro.train.step import TrainState, init_train_state, make_train_step

OUT_DIR = pathlib.Path(__file__).resolve().parents[3] / "experiments" / "dryrun"


# --------------------------------------------------------------------------
# per-cell lowering
# --------------------------------------------------------------------------

def _train_artifacts(cfg, cell, rules, tp, microbatches):
    fns = registry.build(cfg, tp=tp)
    opt = adam(1e-4)
    step = make_train_step(fns.loss, opt, microbatches=microbatches)

    params_s = specs_mod.params_specs(cfg, tp)
    state_s = jax.eval_shape(lambda p: init_train_state(p, opt), params_s)
    batch_s = specs_mod.batch_specs(cfg, cell)

    p_axes = fns.param_axes()
    p_shard = specs_mod.to_shardings(p_axes, rules)
    state_shard = TrainState(
        step=NamedSharding(rules.mesh, P()),
        params=p_shard,
        opt_state=type(state_s.opt_state)(
            step=NamedSharding(rules.mesh, P()), mu=p_shard, nu=p_shard),
        ef_residual=None,
    )
    batch_shard = specs_mod.to_shardings(specs_mod.batch_axes(cfg, cell), rules)
    metrics_shard = {"loss": NamedSharding(rules.mesh, P()),
                     "grad_norm": NamedSharding(rules.mesh, P())}
    jitted = jax.jit(step, in_shardings=(state_shard, batch_shard),
                     out_shardings=(state_shard, metrics_shard),
                     donate_argnums=(0,))
    return jitted, (state_s, batch_s)


def _prefill_artifacts(cfg, cell, rules, tp):
    fns = registry.build(cfg, tp=tp)

    def prefill_step(params, batch):
        cache, logits = fns.prefill(params, batch)
        return cache, jnp.argmax(logits, -1).astype(jnp.int32)

    params_s = specs_mod.params_specs(cfg, tp)
    batch_s = specs_mod.batch_specs(cfg, cell)
    p_shard = specs_mod.to_shardings(fns.param_axes(), rules)
    b_shard = specs_mod.to_shardings(specs_mod.batch_axes(cfg, cell), rules)
    cache_shard = specs_mod.to_shardings(registry.cache_axes(cfg), rules)
    tok_shard = specs_mod.to_shardings(("batch",), rules)
    jitted = jax.jit(prefill_step, in_shardings=(p_shard, b_shard),
                     out_shardings=(cache_shard, tok_shard))
    return jitted, (params_s, batch_s)


def _decode_artifacts(cfg, cell, rules, tp, *, serve_bf16=False,
                      serve_weights="fsdp"):
    fns = registry.build(cfg, tp=tp)
    serve = make_serve_step(fns)

    params_s = specs_mod.params_specs(cfg, tp)
    if serve_bf16:  # inference weights in bf16 (halves weight-stream bytes)
        params_s = jax.tree.map(
            lambda s: jax.ShapeDtypeStruct(
                s.shape, jnp.bfloat16 if s.dtype == jnp.float32 else s.dtype),
            params_s)
    dec = specs_mod.decode_specs(cfg, cell, tp)
    p_rules = rules
    if serve_weights == "tp":
        # inference wants TP-only weight sharding: no per-token FSDP gathers
        from repro.dist.sharding import with_overrides
        p_rules = with_overrides(rules, fsdp=None)
    p_shard = specs_mod.to_shardings(fns.param_axes(), p_rules)
    d_ax = specs_mod.decode_axes(cfg)
    cache_shard = specs_mod.to_shardings(d_ax["cache"], rules)
    tok_shard = specs_mod.to_shardings(d_ax["tokens"], rules)
    len_shard = NamedSharding(rules.mesh, P())
    jitted = jax.jit(serve,
                     in_shardings=(p_shard, cache_shard, tok_shard, len_shard),
                     out_shardings=(tok_shard, cache_shard),
                     donate_argnums=(1,))
    return jitted, (params_s, dec["cache"], dec["tokens"], dec["cache_len"])


def lower_cell(cfg: ModelConfig, cell: ShapeCell, mesh, *,
               microbatches: int = 1, sequence_parallel: bool = False,
               quant: str | None = None, parallel_block: bool = False,
               remat: str = "full", decode_unroll: bool = False,
               serve_bf16: bool = False, serve_weights: str = "fsdp",
               label: str = "baseline") -> dict:
    """lower + compile one (arch x shape x mesh) cell; return the §Dry-run /
    §Roofline record."""
    tp = mesh.shape["model"]
    chips = mesh.size
    if quant:
        cfg = dataclasses.replace(cfg, quant=quant)
    if parallel_block:
        cfg = dataclasses.replace(cfg, parallel_block=True)
    if remat != "full":
        cfg = dataclasses.replace(cfg, remat=remat)
    if decode_unroll:
        cfg = dataclasses.replace(cfg, decode_unroll=True)
    rules = rules_for(mesh, global_batch=cell.global_batch,
                      sequence_parallel=sequence_parallel)

    t0 = time.perf_counter()
    with use_rules(rules):
        if cell.kind == "train":
            jitted, args = _train_artifacts(cfg, cell, rules, tp, microbatches)
        elif cell.kind == "prefill":
            jitted, args = _prefill_artifacts(cfg, cell, rules, tp)
        else:
            jitted, args = _decode_artifacts(cfg, cell, rules, tp,
                                             serve_bf16=serve_bf16,
                                             serve_weights=serve_weights)
        lowered = jitted.lower(*args)
        t_lower = time.perf_counter() - t0
        compiled = lowered.compile()
    t_compile = time.perf_counter() - t0 - t_lower

    record = {
        "arch": cfg.name, "shape": cell.name, "kind": cell.kind,
        "mesh": dict(mesh.shape), "chips": chips, "label": label,
        "options": {"microbatches": microbatches, "sp": sequence_parallel,
                    "quant": quant or cfg.quant,
                    "parallel_block": parallel_block, "remat": remat},
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
    }

    # ---- memory analysis (proves it fits) --------------------------------
    try:
        mem = compiled.memory_analysis()
        record["memory"] = {
            "argument_bytes": int(getattr(mem, "argument_size_in_bytes", 0)),
            "output_bytes": int(getattr(mem, "output_size_in_bytes", 0)),
            "temp_bytes": int(getattr(mem, "temp_size_in_bytes", 0)),
            "alias_bytes": int(getattr(mem, "alias_size_in_bytes", 0)),
        }
        record["memory"]["peak_per_device_bytes"] = (
            record["memory"]["argument_bytes"]
            + record["memory"]["output_bytes"]
            + record["memory"]["temp_bytes"]
            - record["memory"]["alias_bytes"])
    except Exception as e:  # pragma: no cover
        record["memory"] = {"error": str(e)[:200]}

    # ---- trip-count-aware HLO cost model (repro.analysis.hlo_cost) -------
    # xla's cost_analysis counts while bodies once; our analyzer resolves
    # trip counts / fusions, giving per-device flops, HBM-proxy bytes and
    # collective wire bytes from the partitioned module.
    hlo = compiled.as_text()
    hc = analyze_hlo(hlo)
    record["hlo_cost"] = {"flops": hc["flops"], "hbm_bytes": hc["hbm_bytes"],
                          "hbm_by_kind": hc["hbm_by_kind"]}
    record["collectives"] = hc["collectives"]
    record["hlo_bytes_len"] = len(hlo)
    xla_cost = {}
    try:  # raw xla numbers kept for reference
        xla_cost = dict(compiled.cost_analysis() or {})
    except Exception:
        pass
    record["xla_cost_raw"] = {k: xla_cost[k] for k in ("flops", "bytes accessed")
                              if k in xla_cost}

    # ---- roofline terms ---------------------------------------------------
    flops = float(hc["flops"])
    bytes_acc = float(hc["hbm_bytes"])
    coll = hc["collectives"]
    n_active = active_param_count(cfg)
    n_total = param_count(cfg)
    tokens = cell.global_batch * (cell.seq_len if cell.kind != "decode" else 1)
    if cell.kind == "train":
        model_flops = model_flops_train(n_active, tokens)
    else:
        model_flops = model_flops_decode(n_active, tokens)
    int8_frac = (float(hc.get("flops_int8", 0.0)) / flops) if flops else 0.0
    record["hlo_cost"]["flops_int8"] = hc.get("flops_int8", 0.0)
    record["params"] = {"total": n_total, "active": n_active}
    record["model_flops_total"] = model_flops
    record["roofline"] = roofline_terms(
        flops_per_device=flops, bytes_per_device=bytes_acc,
        collective_bytes_per_device=float(coll.get("total", 0)),
        chips=chips, model_flops_total=model_flops, int8_fraction=int8_frac)
    return record


# --------------------------------------------------------------------------
# CLI
# --------------------------------------------------------------------------

def run_cells(archs, shapes, meshes, *, label="baseline", out_dir=OUT_DIR,
              **opts):
    out_dir.mkdir(parents=True, exist_ok=True)
    results = []
    for mesh_name in meshes:
        mesh = make_production_mesh(multi_pod=(mesh_name == "multi"))
        for arch in archs:
            cfg = get_config(arch)
            for cell in cells_for(cfg):
                if shapes and cell.name not in shapes:
                    continue
                tag = f"{arch}_{cell.name}_{mesh_name}_{label}"
                path = out_dir / f"{tag}.json"
                print(f"=== {tag} ===", flush=True)
                try:
                    rec = lower_cell(cfg, cell, mesh, label=label, **opts)
                    rec["status"] = "ok"
                except Exception as e:
                    rec = {"arch": arch, "shape": cell.name, "mesh": mesh_name,
                           "label": label, "status": "error",
                           "error": f"{type(e).__name__}: {e}"[:2000]}
                    print("  ERROR:", rec["error"][:300], flush=True)
                path.write_text(json.dumps(rec, indent=1, default=str))
                if rec.get("status") == "ok":
                    r = rec["roofline"]
                    print(f"  compile={rec['compile_s']:.1f}s "
                          f"flops/dev={rec['hlo_cost']['flops']:.3e} "
                          f"coll={rec['collectives'].get('total', 0):.3e}B "
                          f"dom={r['dominant']} bound={r['t_bound_s']:.4f}s "
                          f"frac={r['roofline_fraction']:.2f}", flush=True)
                results.append(rec)
    return results


def main():
    ap = argparse.ArgumentParser(description="multi-pod dry-run")
    ap.add_argument("--arch", action="append", default=None,
                    help="arch id (repeatable; default: all 10)")
    ap.add_argument("--shape", action="append", default=None,
                    help="cell name filter (train_4k/prefill_32k/...)")
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="both")
    ap.add_argument("--label", default="baseline")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--sp", action="store_true", help="sequence parallelism")
    ap.add_argument("--quant", default=None,
                    choices=[None, "qat-int8", "int8-hlo"])
    ap.add_argument("--parallel-block", action="store_true",
                    help="PaLM-style attn ∥ mlp (1 TP all-reduce per layer)")
    ap.add_argument("--remat", default="full", choices=["full", "save_attn"])
    ap.add_argument("--decode-unroll", action="store_true",
                    help="python-loop decode layers, per-layer donated caches")
    ap.add_argument("--serve-bf16", action="store_true",
                    help="bf16 inference weights")
    ap.add_argument("--serve-weights", default="fsdp", choices=["fsdp", "tp"],
                    help="inference weight sharding (tp = no per-token gathers)")
    ap.add_argument("--out", default=str(OUT_DIR))
    args = ap.parse_args()

    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    archs = args.arch or lm_archs()  # shape cells are an LM-zoo concept
    results = run_cells(archs, args.shape, meshes, label=args.label,
                        out_dir=pathlib.Path(args.out),
                        microbatches=args.microbatches,
                        sequence_parallel=args.sp, quant=args.quant,
                        parallel_block=args.parallel_block, remat=args.remat,
                        decode_unroll=args.decode_unroll,
                        serve_bf16=args.serve_bf16,
                        serve_weights=args.serve_weights)
    n_ok = sum(r.get("status") == "ok" for r in results)
    print(f"\n{n_ok}/{len(results)} cells OK")
    return 0 if n_ok == len(results) else 1


if __name__ == "__main__":
    raise SystemExit(main())
