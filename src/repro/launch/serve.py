"""Serving launcher: batched prefill+decode over a request queue.

``python -m repro.launch.serve --arch tinyllama-1.1b --smoke --requests 8``

Implements the real serving control flow: a request pool, one batched
prefill per admission wave, then lockstep batched decode with per-request
stop handling — the structure the decode_32k/long_500k dry-run cells price
at production scale.
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, get_smoke
from repro.models import registry
from repro.models.encdec import enc_len_for
from repro.serve.decode import make_prefill_step, make_serve_step


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen-len", type=int, default=16)
    args = ap.parse_args(argv)

    cfg = get_smoke(args.arch) if args.smoke else get_config(args.arch)
    fns = registry.build(cfg, tp=1)
    params = fns.init(jax.random.PRNGKey(0))
    prefill = jax.jit(make_prefill_step(fns))
    serve = jax.jit(make_serve_step(fns))

    b, s = args.requests, args.prompt_len
    key = jax.random.PRNGKey(1)
    batch = {"tokens": jax.random.randint(key, (b, s), 0, cfg.vocab_size,
                                          jnp.int32)}
    if cfg.family == "vlm":
        batch["prefix_embeds"] = 0.02 * jax.random.normal(
            key, (b, cfg.n_prefix_embeds, cfg.d_model), jnp.bfloat16)
    if cfg.family == "encdec":
        batch["frames"] = 0.02 * jax.random.normal(
            key, (b, enc_len_for(s), cfg.d_model), jnp.bfloat16)

    t0 = time.perf_counter()
    cache, tok, _ = prefill(params, batch)
    jax.block_until_ready(tok)
    t_prefill = time.perf_counter() - t0

    outs = [np.asarray(tok)]
    t0 = time.perf_counter()
    for i in range(args.gen_len - 1):
        tok, cache = serve(params, cache, tok, jnp.int32(s + i))
        outs.append(np.asarray(tok))
    jax.block_until_ready(tok)
    t_decode = time.perf_counter() - t0

    gen = np.stack(outs, axis=1)
    print(f"arch={cfg.name} requests={b} prompt={s} gen={args.gen_len}")
    print(f"prefill: {t_prefill*1e3:.1f} ms  decode: "
          f"{t_decode/max(args.gen_len-1,1)*1e3:.2f} ms/token/batch")
    print("sample token ids:", gen[0][:12].tolist())
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
