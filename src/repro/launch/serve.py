"""Serving launcher: one driver, two families.

Token families (LM zoo): batched prefill + lockstep decode over a request
pool — ``python -m repro.launch.serve --arch tinyllama-1.1b --smoke``.

MRF reconstruction family: the queued map-reconstruction stack
(``repro.serve.recon`` = queue + wave executor) — ``python -m
repro.launch.serve --arch mrf-fpga --backend int8 --smoke`` trains a QAT net
(or loads ``--artifact``), exports and round-trips the servable int8
artifact, reconstructs a phantom-slice request wave through the bucketed
engine, and cross-checks the int8 path against the ``qat.int_forward``
oracle bit-for-bit.  ``--serve-mode pipelined`` serves the same trace
through the double-buffered executor (``--max-wave-voxels`` /
``--max-wait-ms`` control wave formation) and additionally asserts the
pipelined maps are bit-identical to sync serving.

Chaos smoke: ``--fault-schedule`` (a ``serve.faults`` JSON schedule)
and/or the admission knobs (``--max-pending-voxels``,
``--shed-deadline-ms``) switch the MRF family into the overload/fault
accounting path — enqueue everything, drain through the injected faults,
then assert every ticket landed in exactly one terminal state
(done/failed/shed) and that every served map is bit-identical to healthy
serving.  ``--expect-shed`` / ``--expect-degraded`` make the smoke fail
unless load shedding / the fused->lax circuit breaker actually engaged,
so CI proves the machinery fired rather than trivially passing.
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, get_smoke
from repro.models import registry
from repro.models.encdec import enc_len_for
from repro.serve.decode import make_prefill_step, make_serve_step


def run_token_serve(args, cfg) -> int:
    """Batched prefill + decode for the token-generating families."""
    fns = registry.build(cfg, tp=1)
    params = fns.init(jax.random.PRNGKey(0))
    prefill = jax.jit(make_prefill_step(fns))
    serve = jax.jit(make_serve_step(fns))

    b, s = args.requests, args.prompt_len
    k_tok, k_vlm, k_enc = jax.random.split(jax.random.PRNGKey(1), 3)
    batch = {"tokens": jax.random.randint(k_tok, (b, s), 0, cfg.vocab_size,
                                          jnp.int32)}
    if cfg.family == "vlm":
        batch["prefix_embeds"] = 0.02 * jax.random.normal(
            k_vlm, (b, cfg.n_prefix_embeds, cfg.d_model), jnp.bfloat16)
    if cfg.family == "encdec":
        batch["frames"] = 0.02 * jax.random.normal(
            k_enc, (b, enc_len_for(s), cfg.d_model), jnp.bfloat16)

    # warmup: compile prefill + decode outside the timed region so
    # t_prefill / t_decode measure steady-state serving, not XLA compiles
    w_cache, w_tok, _ = prefill(params, batch)
    w_tok, w_cache = serve(params, w_cache, w_tok, jnp.int32(s))
    jax.block_until_ready(w_tok)
    del w_cache, w_tok

    t0 = time.perf_counter()
    cache, tok, _ = prefill(params, batch)
    jax.block_until_ready(tok)
    t_prefill = time.perf_counter() - t0

    # keep device arrays in flight: no per-token host sync (np.asarray
    # inside the loop would block dispatch pipelining every step)
    toks = [tok]
    t0 = time.perf_counter()
    for i in range(args.gen_len - 1):
        tok, cache = serve(params, cache, tok, jnp.int32(s + i))
        toks.append(tok)
    jax.block_until_ready(tok)
    t_decode = time.perf_counter() - t0

    gen = np.stack([np.asarray(t) for t in toks], axis=1)
    print(f"arch={cfg.name} requests={b} prompt={s} gen={args.gen_len}")
    print(f"prefill: {t_prefill*1e3:.1f} ms  decode: "
          f"{t_decode/max(args.gen_len-1,1)*1e3:.2f} ms/token/batch")
    print("sample token ids:", gen[0][:12].tolist())
    return 0


def _train_mrf(args, cfg, *, qat_mode: bool):
    """One training recipe for both serving backends — topology comes from
    the arch config (``cfg.mrf_hidden``), so mrf-original serves its own
    (deeper) net, not the adapted one."""
    from repro.core.train_loop import TrainConfig, train

    steps = (args.train_steps if args.train_steps is not None
             else (60 if args.smoke else 600))
    tcfg = TrainConfig(n_frames=cfg.mrf_n_frames, hidden=cfg.mrf_hidden,
                       steps=steps, qat=qat_mode, lr=1e-3, batch_size=256,
                       log_every=max(steps // 3, 1))
    return train(tcfg, verbose=not args.smoke)


def _obtain_int8_artifact(args, cfg):
    """Load ``--artifact`` or QAT-train + export one; always serve the
    saved-then-reloaded form so the smoke exercises the deployment unit."""
    import tempfile

    from repro.core import qat

    if args.artifact:
        return qat.load_int8_artifact(args.artifact)
    params, qstate, _ = _train_mrf(args, cfg, qat_mode=True)
    ints = qat.export_int8(params, qstate)
    # round-trip through disk so the smoke serves the deployment unit, but
    # don't leak a tempdir per run; pass --artifact to serve a kept file
    with tempfile.TemporaryDirectory(prefix="mrf_artifact_") as tmp:
        path = qat.save_int8_artifact(f"{tmp}/{cfg.name}_int8", ints)
        loaded = qat.load_int8_artifact(path)
        print(f"int8 artifact round-tripped via {path.name}")
    return loaded


def _chaos_serve(args, engine, net_kw, requests) -> int:
    """Overload/fault accounting path: enqueue everything, drain through
    the injected schedule, then audit the lifecycle ledger.

    Enqueue-all-then-drain (not enqueue/poll interleaved) on purpose: the
    pending backlog builds before any wave retires, so admission-policy
    shedding is deterministic — the same requests shed every run, which is
    what a CI gate needs.
    """
    import collections

    from repro.serve.queue import RequestState
    from repro.serve.recon import ReconEngine

    tickets = [engine.enqueue(r) for r in requests]
    engine.drain()
    stats, health = engine.last_wave, engine.health()
    states = collections.Counter(t.state for t in tickets)
    print(f"chaos drain: done={states['done']} failed={states['failed']} "
          f"shed={states['shed']} waves={stats['n_waves']} "
          f"retries={stats['n_retries']} slow={health['n_slow_waves']} "
          f"degraded={health['degraded']}")
    for t in tickets:
        if t.state == RequestState.SHED:
            print(f"  shed   {t.request.request_id}: {t.shed_reason}")
        elif t.state == RequestState.FAILED:
            print(f"  failed {t.request.request_id}: {t.error}")
    bad = [t for t in tickets if t.state not in RequestState.TERMINAL]
    if bad:
        print(f"FAIL: {len(bad)} ticket(s) stranded non-terminal: "
              f"{[t.state for t in bad]}")
        return 1
    done = [t for t in tickets if t.state == RequestState.DONE]
    if not done:
        print("FAIL: chaos schedule starved the drain — nothing served")
        return 1
    # every served map must be bit-identical to healthy (fault-free)
    # serving; the reference runs whatever impl the engine ended on (the
    # degraded lax impl is bit-exact vs fused by the PR 7 parity proof)
    ref_kw = dict(net_kw)
    if ref_kw.get("backend") == "int8":
        ref_kw["int8_impl"] = engine.int8_impl
    ref = ReconEngine(**ref_kw)
    for t in done:
        want, = ref.reconstruct([t.request])
        if not (np.array_equal(t.result.t1_ms, want.t1_ms)
                and np.array_equal(t.result.t2_ms, want.t2_ms)):
            print(f"FAIL: served maps diverge from healthy serving "
                  f"({t.request.request_id})")
            return 1
    print(f"served maps == healthy serving: bit-exact ({len(done)} requests)")
    if args.expect_shed and states["shed"] == 0:
        print("FAIL: --expect-shed but the admission policy shed nothing")
        return 1
    if args.expect_degraded and not health["degraded"]:
        print("FAIL: --expect-degraded but the circuit breaker never "
              "tripped")
        return 1
    print("chaos smoke: clean drain, every ticket terminal")
    return 0


def run_mrf_serve(args, cfg) -> int:
    """The MRF reconstruction family through the batched serving engine."""
    from repro.core import qat
    from repro.data.epg import default_sequence
    from repro.data.phantom import acquire_slice, make_phantom, tissue_errors
    from repro.serve.recon import (ReconEngine, ReconRequest,
                                   latency_percentiles)

    backend = args.backend
    if backend not in ("float", "int8"):
        raise SystemExit(f"--backend {backend} is not an MRF serving backend "
                         "(float | int8)")
    if args.artifact and backend != "int8":
        raise SystemExit("--artifact is an int8 deployment unit; it requires "
                         "--backend int8 (float would silently retrain)")
    if args.requests < 1:
        raise SystemExit("--requests must be >= 1 for the mrf family")

    ints = params = None
    if backend == "int8":
        ints = _obtain_int8_artifact(args, cfg)
        impl = None if args.int8_impl == "auto" else args.int8_impl
        net_kw = dict(backend="int8", int_layers=ints, int8_impl=impl)
    else:
        if args.int8_impl != "auto":
            raise SystemExit("--int8-impl selects the full-integer "
                             "implementation; it requires --backend int8")
        params, _, _ = _train_mrf(args, cfg, qat_mode=False)
        net_kw = dict(backend="float", params=params)

    injector = admission = None
    if args.fault_schedule:
        import json

        from repro.serve.faults import FaultInjector
        injector = FaultInjector(json.loads(args.fault_schedule))
    if args.max_pending_voxels is not None or \
            args.shed_deadline_ms is not None:
        from repro.serve.admission import AdmissionPolicy
        admission = AdmissionPolicy(max_pending_voxels=args.max_pending_voxels,
                                    deadline_ms=args.shed_deadline_ms)
    engine = ReconEngine(mode=args.serve_mode,
                         max_wave_voxels=args.max_wave_voxels,
                         max_wait_ms=args.max_wait_ms,
                         admission=admission, injector=injector,
                         adaptive=args.adaptive,
                         wave_timeout_s=(args.wave_timeout_ms * 1e-3
                                         if args.wave_timeout_ms is not None
                                         else None), **net_kw)
    if backend == "int8":
        print(f"int8 impl: {engine.int8_impl} "
              f"(requested {args.int8_impl})")

    # request pool: one phantom slice per request, distinct noise draws
    seq = default_sequence(cfg.mrf_n_frames)
    n = args.phantom_n
    t1_map, t2_map, mask = make_phantom(n)
    requests = []
    for i in range(args.requests):
        feats, msk = acquire_slice(seq, t1_map, t2_map, mask,
                                   key=jax.random.PRNGKey(i))
        requests.append(ReconRequest(features=feats, mask=msk,
                                     request_id=f"slice-{i}"))

    if injector is not None or admission is not None:
        # no warmup wave: it would consume fault-schedule wave indices and
        # pre-feed the admission service rate
        return _chaos_serve(args, engine, net_kw, requests)

    engine.reconstruct(requests)  # warmup wave (compiles buckets)
    if args.serve_mode == "pipelined":
        # streaming admission: enqueue as slices "arrive", poll dispatches
        # due waves mid-stream, drain flushes the rest double-buffered
        tickets = []
        for r in requests:
            tickets.append(engine.enqueue(r))
            engine.poll()
        engine.drain()
        bad = [t for t in tickets if t.result is None]
        if bad:
            for t in bad:
                print(f"FAIL: request {t.request.request_id!r} "
                      f"{t.state}: {t.error}")
            return 1
        results = [t.result for t in tickets]
    else:
        results = engine.reconstruct(requests)
    wave = engine.last_wave
    pct = latency_percentiles(results)
    print(f"arch={cfg.name} backend={backend} mode={args.serve_mode} "
          f"requests={len(requests)} voxels={wave['total_voxels']} "
          f"waves={wave['n_waves']}")
    print(f"throughput: {wave['voxels_per_s']:.0f} voxels/s   latency "
          f"p50 {pct['p50_ms']:.1f} ms  p99 {pct['p99_ms']:.1f} ms")

    if args.serve_mode == "pipelined":
        # pipelining must be a pure scheduling change: same maps, bit-for-bit
        sync_results = ReconEngine(**net_kw).reconstruct(requests)
        for got, want in zip(results, sync_results):
            if not (np.array_equal(got.t1_ms, want.t1_ms)
                    and np.array_equal(got.t2_ms, want.t2_ms)):
                print(f"FAIL: pipelined maps diverge from sync serving "
                      f"({got.request_id})")
                return 1
        print("pipelined == sync serving: bit-exact")
    for name, e in tissue_errors(results[0].t1_ms, results[0].t2_ms,
                                 t1_map, mask).items():
        print(f"  {name:6s}: T1 err {e['T1_err_%']:5.1f}%   "
              f"T2 err {e['T2_err_%']:5.1f}%")

    if backend == "int8":
        # the acceptance check: engine int8 == software integer oracle,
        # bit-for-bit (the paper's FPGA-vs-Python criterion, served)
        from repro.data.pipeline import denormalize_targets
        oracle = qat.int_forward(ints, requests[0].features)
        want_ms = np.asarray(denormalize_targets(oracle))
        vox = np.asarray(mask, bool)
        if not (np.array_equal(results[0].t1_ms[vox], want_ms[:, 0])
                and np.array_equal(results[0].t2_ms[vox], want_ms[:, 1])):
            print("FAIL: int8 engine diverges from qat.int_forward oracle")
            return 1
        print("int8 engine == qat.int_forward oracle: bit-exact")
    return 0


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    # token-family knobs
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen-len", type=int, default=16)
    # mrf-family knobs
    ap.add_argument("--backend", default="float",
                    help="mrf-* archs: float | int8 (full-integer Pallas)")
    ap.add_argument("--int8-impl", default="auto",
                    choices=["auto", "fused", "lax", "layered"],
                    help="mrf int8: full-integer implementation — fused = "
                         "whole-network Pallas kernel (TPU deployment "
                         "path), lax = vectorized pure-lax fallback (the "
                         "fast path off-TPU), layered = per-layer kernel "
                         "chain (measured baseline); auto picks per rig. "
                         "All bit-exact vs the qat.int_forward oracle "
                         "(checked below)")
    ap.add_argument("--serve-mode", default="sync",
                    choices=["sync", "pipelined"],
                    help="mrf: sync = per-tile retirement baseline; "
                         "pipelined = double-buffered waves, one host sync "
                         "per wave (bit-identical maps, asserted)")
    ap.add_argument("--max-wave-voxels", type=int, default=None,
                    help="mrf: close a wave at this many voxels "
                         "(default: one wave per drain)")
    ap.add_argument("--max-wait-ms", type=float, default=None,
                    help="mrf: admission deadline from enqueue before a "
                         "wave is due (default: no deadline trigger)")
    ap.add_argument("--fault-schedule", default=None,
                    help="mrf chaos: JSON list of serve.faults FaultSpec "
                         'dicts, e.g. \'[{"kind": "kernel_fail", '
                         '"wave": 0}]\' — switches to the chaos '
                         "accounting path")
    ap.add_argument("--max-pending-voxels", type=int, default=None,
                    help="mrf chaos: admission budget — shed arrivals that "
                         "would push the pending backlog past this")
    ap.add_argument("--shed-deadline-ms", type=float, default=None,
                    help="mrf chaos: shed arrivals whose estimated queue "
                         "wait exceeds this deadline")
    ap.add_argument("--adaptive", action="store_true",
                    help="mrf: auto-tune inflight depth + wave cap from "
                         "observed staging/compute (pipelined mode only)")
    ap.add_argument("--wave-timeout-ms", type=float, default=None,
                    help="mrf: flag waves whose completion wait exceeds "
                         "this as stalls (health accounting)")
    ap.add_argument("--expect-shed", action="store_true",
                    help="mrf chaos: fail unless load shedding engaged")
    ap.add_argument("--expect-degraded", action="store_true",
                    help="mrf chaos: fail unless the int8 circuit breaker "
                         "tripped to the lax impl")
    ap.add_argument("--artifact", default=None,
                    help="mrf int8: serve this .npz artifact instead of "
                         "training one")
    ap.add_argument("--train-steps", type=int, default=None,
                    help="mrf: steps for the in-process training "
                         "(default 60 smoke / 600 full)")
    ap.add_argument("--phantom-n", type=int, default=32,
                    help="mrf: phantom slice side length")
    args = ap.parse_args(argv)

    cfg = get_smoke(args.arch) if args.smoke else get_config(args.arch)
    if cfg.family == "mrf":
        return run_mrf_serve(args, cfg)
    return run_token_serve(args, cfg)


if __name__ == "__main__":
    raise SystemExit(main())
