"""Launchers: mesh construction, axis-rule binding, dry-run lowering, and
the training/serving CLIs."""
