"""ShapeDtypeStruct stand-ins + shardings for every (arch x shape) cell.

``input_specs`` returns weak-type-correct, shardable specs for each model
input — no device allocation — so the dry-run can ``jit(...).lower(**specs)``
the full-size configs on the placeholder mesh.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeCell
from repro.dist.sharding import AxisRules, param_shardings
from repro.models import registry
from repro.models.encdec import enc_len_for

S = jax.ShapeDtypeStruct


def batch_specs(cfg: ModelConfig, cell: ShapeCell) -> dict:
    """Train/prefill batch: token ids (+ stub-frontend embeddings)."""
    b, s = cell.global_batch, cell.seq_len
    batch: dict[str, Any] = {"tokens": S((b, s), jnp.int32)}
    if cell.kind == "train":
        batch["labels"] = S((b, s), jnp.int32)
    if cfg.family == "vlm":
        batch["prefix_embeds"] = S((b, cfg.n_prefix_embeds, cfg.d_model),
                                   jnp.bfloat16)
    if cfg.family == "encdec":
        batch["frames"] = S((b, enc_len_for(s), cfg.d_model), jnp.bfloat16)
    return batch


def batch_axes(cfg: ModelConfig, cell: ShapeCell) -> dict:
    ax: dict[str, Any] = {"tokens": ("batch", None)}
    if cell.kind == "train":
        ax["labels"] = ("batch", None)
    if cfg.family == "vlm":
        ax["prefix_embeds"] = ("batch", None, None)
    if cfg.family == "encdec":
        ax["frames"] = ("batch", "act_seq", None)
    return ax


def decode_specs(cfg: ModelConfig, cell: ShapeCell, tp: int) -> dict:
    """serve_step inputs: one new token + a seq_len KV cache."""
    b, s = cell.global_batch, cell.seq_len
    fns = registry.build(cfg, tp=tp)
    cache = jax.eval_shape(lambda: fns.init_cache(b, s))
    return {"cache": cache,
            "tokens": S((b,), jnp.int32),
            "cache_len": S((), jnp.int32)}


def decode_axes(cfg: ModelConfig) -> dict:
    return {"cache": registry.cache_axes(cfg),
            "tokens": ("batch",),
            "cache_len": ()}


def params_specs(cfg: ModelConfig, tp: int):
    fns = registry.build(cfg, tp=tp)
    return jax.eval_shape(lambda: fns.init(jax.random.PRNGKey(0)))


def to_shardings(axes_tree, rules: AxisRules):
    """Alias for the canonical mapping in repro.dist.sharding (kept under
    its launch-era name for the dry-run call sites)."""
    return param_shardings(axes_tree, rules)


def tree_bytes(tree) -> int:
    return sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(tree))
