"""Training launcher: ``python -m repro.launch.train --arch tinyllama-1.1b
--smoke --steps 200`` or ``--arch mrf-fpga --smoke --backend fused-pallas``.

Composes the full stack: config -> model -> optimizer -> fault-tolerant
runner (checkpoint/restart, straggler watchdog) -> metrics log.  On the CPU
container use ``--smoke`` (reduced same-family config); on a TPU cluster the
same driver runs the full config under ``make_production_mesh()`` with the
logical-axis shardings (pass --mesh single|multi).

The MRF reconstruction nets (``--arch mrf-fpga | mrf-original``) run through
the same runner with the backend selected by ``--backend``:
``float`` / ``qat-int8`` / ``fused-pallas`` (see repro.train.engine).
"""

from __future__ import annotations

import argparse
import contextlib
import dataclasses

import jax
import jax.numpy as jnp

from repro.configs import get_config, get_smoke
from repro.data.lm_text import TextPipeline
from repro.dist.sharding import use_rules
from repro.ft.runner import RunnerConfig, run
from repro.models import registry
from repro.models.encdec import enc_len_for
from repro.optim import adam
from repro.train.step import init_train_state, make_train_step


def make_batches(cfg, pipe: TextPipeline):
    def at(step: int):
        batch = pipe.batch_at(step)
        b = batch["tokens"].shape[0]
        if cfg.family == "vlm":
            key = jax.random.PRNGKey(step)
            batch["prefix_embeds"] = 0.02 * jax.random.normal(
                key, (b, cfg.n_prefix_embeds, cfg.d_model), jnp.bfloat16)
            batch["labels"][:, :cfg.n_prefix_embeds] = -1
        if cfg.family == "encdec":
            key = jax.random.PRNGKey(step)
            batch["frames"] = 0.02 * jax.random.normal(
                key, (b, enc_len_for(batch["tokens"].shape[1]), cfg.d_model),
                jnp.bfloat16)
        return batch
    return at


def _metrics_logger(total_steps):
    def log(step, metrics, dt):
        if step % 10 == 0 or step == total_steps:
            gnorm = metrics.get("grad_norm")
            gtxt = "" if gnorm is None else f"gnorm {float(gnorm):.3f} "
            print(f"step {step:5d} loss {float(metrics['loss']):.4f} "
                  f"{gtxt}{dt*1000:.0f} ms", flush=True)
    return log


def _mesh_context(args):
    """(context manager, tp) — nullcontext + tp=1 when running mesh-less."""
    if args.mesh == "none":
        return contextlib.nullcontext(), 1
    from repro.launch.mesh import make_production_mesh, rules_for
    mesh = make_production_mesh(multi_pod=args.mesh == "multi")
    rules = rules_for(mesh, global_batch=args.batch)
    return use_rules(rules), mesh.shape["model"]


def run_mrf(args, cfg) -> int:
    """The MRF nets through the unified engine: one runner, three backends,
    stepwise or chunked dispatch (--chunk-steps)."""
    from repro.core.train_loop import evaluate
    from repro.data.pipeline import host_sharded_key
    from repro.train import engine

    backend = args.backend
    if args.quant == "qat-int8":  # the LM-zoo spelling of the same request
        if backend == "fused-pallas":
            raise SystemExit("--quant qat-int8 conflicts with "
                             "--backend fused-pallas (kernel QAT is a "
                             "different path); drop one of the flags")
        backend = "qat-int8"
    optimizer = args.optimizer or (
        "sgd" if backend == "fused-pallas" else "adam")
    if backend == "fused-pallas":
        if args.microbatches != 1 or args.grad_compress:
            raise SystemExit("--microbatches/--grad-compress have no effect "
                             "with --backend fused-pallas (the update is "
                             "computed in-kernel)")
        # --optimizer adam is fine: the kernel implements Adam in-VMEM with
        # the moment stacks resident next to the weights (multistep.py)

    ckpt_dir = args.ckpt_dir or f"/tmp/repro_ckpt/{cfg.name}-{backend}"
    from repro.ft.checkpoint import latest_step
    resume = latest_step(ckpt_dir)
    if resume:
        print(f"resuming from checkpoint step {resume} in {ckpt_dir}")

    ctx, tp = _mesh_context(args)
    with ctx:
        fns = registry.build(cfg, tp=tp)
        ecfg = engine.EngineConfig(
            backend=backend, lr=args.lr, optimizer=optimizer,
            microbatches=args.microbatches,
            grad_compress=args.grad_compress, tile_batch=args.tile_batch,
            chunk_steps=args.chunk_steps)
        stream = engine.default_stream(cfg, args.batch)
        rcfg = RunnerConfig(total_steps=args.steps, ckpt_dir=ckpt_dir,
                            ckpt_every=args.ckpt_every,
                            inject_fault_at=args.inject_fault_at)
        from repro.configs.base import param_count
        print(f"arch={cfg.name} backend={backend} "
              f"params={param_count(cfg):,} "
              f"tp={tp} chunk_steps={args.chunk_steps}")
        state, step, info = engine.train(
            fns, ecfg, rcfg, stream=stream,
            data_key=host_sharded_key(seed=1), batch_size=args.batch,
            on_metrics=_metrics_logger(args.steps))
    # qat-int8 carries its observers in state.aux: evaluate the fake-quant
    # net the backend actually trained, not the float forward
    m = evaluate(state.params, stream.seq, qstate=state.aux, n=1000)
    print(f"done at step {step}: {info['samples_per_s']:.0f} samples/s; "
          f"T1 MAPE {m['T1']['MAPE_%']:.2f}%  T2 MAPE {m['T2']['MAPE_%']:.2f}%")
    return 0


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced same-family config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--backend", default="float",
                    choices=["float", "qat-int8", "fused-pallas"],
                    help="MRF engine backend (mrf-* archs only)")
    ap.add_argument("--optimizer", default=None, choices=["adam", "sgd"],
                    help="default: adam (sgd for the fused-pallas backend)")
    ap.add_argument("--tile-batch", type=int, default=128,
                    help="fused-pallas batch tile (1 = per-sample SGD)")
    ap.add_argument("--chunk-steps", type=int, default=1,
                    help="train steps per dispatch (mrf-* archs): >1 runs a "
                         "lax.scan chunk with in-scan batch synthesis — "
                         "bit-identical to stepwise, dispatch-bound loops "
                         "run much faster (1 = stepwise, the default)")
    ap.add_argument("--quant", default=None, choices=[None, "qat-int8"],
                    help="the paper's technique: int8 QAT training (LM zoo)")
    ap.add_argument("--grad-compress", action="store_true",
                    help="int8 error-feedback gradient compression")
    ap.add_argument("--ckpt-dir", default=None,
                    help="default: /tmp/repro_ckpt/<arch>[-<backend>] "
                         "(namespaced so runs don't resume each other's "
                         "incompatible state)")
    ap.add_argument("--ckpt-every", type=int, default=100)
    ap.add_argument("--mesh", choices=["none", "single", "multi"],
                    default="none")
    ap.add_argument("--inject-fault-at", type=int, default=None)
    args = ap.parse_args(argv)

    cfg = get_smoke(args.arch) if args.smoke else get_config(args.arch)
    if cfg.family == "mrf":
        return run_mrf(args, cfg)

    if args.quant:
        cfg = dataclasses.replace(cfg, quant=args.quant)
    vocab_cap = min(cfg.vocab_size, 256)

    ctx, tp = _mesh_context(args)
    with ctx:
        fns = registry.build(cfg, tp=tp)
        opt = adam(args.lr)
        step_fn = make_train_step(fns.loss, opt,
                                  microbatches=args.microbatches,
                                  grad_compress=args.grad_compress)
        jit_step = jax.jit(step_fn, donate_argnums=(0,))

        params = fns.init(jax.random.PRNGKey(0))
        state = init_train_state(params, opt, grad_compress=args.grad_compress)
        n_params = sum(p.size for p in jax.tree.leaves(params))
        print(f"arch={cfg.name} params={n_params:,} tp={tp}")

        pipe = TextPipeline(seq_len=args.seq, batch_size=args.batch,
                            vocab_size=vocab_cap)
        batches = make_batches(cfg, pipe)

        ckpt_dir = args.ckpt_dir or f"/tmp/repro_ckpt/{cfg.name}"
        rcfg = RunnerConfig(total_steps=args.steps, ckpt_dir=ckpt_dir,
                            ckpt_every=args.ckpt_every,
                            inject_fault_at=args.inject_fault_at)
        state, step = run(jit_step, state, batches, rcfg,
                          on_metrics=_metrics_logger(args.steps))
    print(f"done at step {step}; final loss above.")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
