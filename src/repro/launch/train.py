"""Training launcher: ``python -m repro.launch.train --arch tinyllama-1.1b
--smoke --steps 200``.

Composes the full stack: config -> model -> optimizer -> fault-tolerant
runner (checkpoint/restart, straggler watchdog) -> metrics log.  On the CPU
container use ``--smoke`` (reduced same-family config); on a TPU cluster the
same driver runs the full config under ``make_production_mesh()`` with the
logical-axis shardings (pass --mesh single|multi).
"""

from __future__ import annotations

import argparse
import dataclasses

import jax
import jax.numpy as jnp

from repro.configs import get_config, get_smoke
from repro.data.lm_text import TextPipeline
from repro.dist.sharding import use_rules
from repro.ft.runner import RunnerConfig, run
from repro.launch import input_specs as specs_mod
from repro.models import registry
from repro.models.encdec import enc_len_for
from repro.optim import adam
from repro.train.step import init_train_state, make_train_step


def make_batches(cfg, pipe: TextPipeline):
    def at(step: int):
        batch = pipe.batch_at(step)
        b = batch["tokens"].shape[0]
        if cfg.family == "vlm":
            key = jax.random.PRNGKey(step)
            batch["prefix_embeds"] = 0.02 * jax.random.normal(
                key, (b, cfg.n_prefix_embeds, cfg.d_model), jnp.bfloat16)
            batch["labels"][:, :cfg.n_prefix_embeds] = -1
        if cfg.family == "encdec":
            key = jax.random.PRNGKey(step)
            batch["frames"] = 0.02 * jax.random.normal(
                key, (b, enc_len_for(batch["tokens"].shape[1]), cfg.d_model),
                jnp.bfloat16)
        return batch
    return at


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced same-family config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--quant", default=None, choices=[None, "qat-int8"],
                    help="the paper's technique: int8 QAT training")
    ap.add_argument("--grad-compress", action="store_true",
                    help="int8 error-feedback gradient compression")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=100)
    ap.add_argument("--mesh", choices=["none", "single", "multi"],
                    default="none")
    ap.add_argument("--inject-fault-at", type=int, default=None)
    args = ap.parse_args(argv)

    cfg = get_smoke(args.arch) if args.smoke else get_config(args.arch)
    if args.quant:
        cfg = dataclasses.replace(cfg, quant=args.quant)
    vocab_cap = min(cfg.vocab_size, 256)

    tp = 1
    ctx = None
    if args.mesh != "none":
        from repro.launch.mesh import make_production_mesh, rules_for
        mesh = make_production_mesh(multi_pod=args.mesh == "multi")
        rules = rules_for(mesh, global_batch=args.batch)
        ctx = use_rules(rules)
        tp = mesh.shape["model"]

    fns = registry.build(cfg, tp=tp)
    opt = adam(args.lr)
    step_fn = make_train_step(fns.loss, opt, microbatches=args.microbatches,
                              grad_compress=args.grad_compress)
    jit_step = jax.jit(step_fn, donate_argnums=(0,))

    params = fns.init(jax.random.PRNGKey(0))
    state = init_train_state(params, opt, grad_compress=args.grad_compress)
    n_params = sum(p.size for p in jax.tree.leaves(params))
    print(f"arch={cfg.name} params={n_params:,} tp={tp}")

    pipe = TextPipeline(seq_len=args.seq, batch_size=args.batch,
                        vocab_size=vocab_cap)
    batches = make_batches(cfg, pipe)

    def log(step, metrics, dt):
        if step % 10 == 0 or step == args.steps:
            print(f"step {step:5d} loss {float(metrics['loss']):.4f} "
                  f"gnorm {float(metrics['grad_norm']):.3f} {dt*1000:.0f} ms",
                  flush=True)

    rcfg = RunnerConfig(total_steps=args.steps, ckpt_dir=args.ckpt_dir,
                        ckpt_every=args.ckpt_every,
                        inject_fault_at=args.inject_fault_at)
    if ctx:
        with ctx:
            state, step = run(jit_step, state, batches, rcfg, on_metrics=log)
    else:
        state, step = run(jit_step, state, batches, rcfg, on_metrics=log)
    print(f"done at step {step}; final loss above.")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
