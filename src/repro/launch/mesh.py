"""Production mesh construction.

A FUNCTION, not a module-level constant, so importing this module never
touches jax device state (the dry-run must set XLA_FLAGS before first init).

Topology (TPU v5e target):
  single pod : (data=16, model=16) = 256 chips — model axis within the
               high-bandwidth ICI domain, data axis across it.
  multi-pod  : (pod=2, data=16, model=16) = 512 chips — the pod axis crosses
               DCN; only data parallelism (gradient all-reduce, optionally
               int8-compressed) crosses it.
"""

from __future__ import annotations

import math

import jax

from repro.dist.sharding import (AxisRules, MULTI_POD_RULES, SINGLE_POD_RULES,
                                 make_compat_mesh)


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = math.prod(shape)
    devices = jax.devices()[:n]
    assert len(devices) == n, (
        f"need {n} devices; run under XLA_FLAGS=--xla_force_host_platform_"
        f"device_count=512 (have {len(jax.devices())})")
    return make_compat_mesh(shape, axes, devices=devices)


def rules_for(mesh, *, global_batch: int, sequence_parallel: bool = False) -> AxisRules:
    """Axis rules bound to a mesh, degrading batch sharding when the global
    batch doesn't divide the batch axes (e.g. long_500k's batch=1)."""
    multi = "pod" in mesh.axis_names
    base = MULTI_POD_RULES if multi else SINGLE_POD_RULES
    batch_axes = ("pod", "data") if multi else ("data",)
    denom = math.prod(mesh.shape[a] for a in batch_axes)
    overrides = {}
    if global_batch % denom != 0:
        if multi and global_batch % mesh.shape["data"] == 0:
            # pod*data doesn't divide the batch but data alone does:
            # shard over data only, replicate across pods
            overrides["batch"] = "data"
        else:
            overrides["batch"] = None  # degrade to replicated batch
    if sequence_parallel:
        overrides["act_seq"] = "model"
    rules = AxisRules(rules={**base.rules, **overrides}, mesh=mesh)
    return rules
