"""One training engine for the MRF nets — stepwise or chunked dispatch.

The repo used to train the MRF net through three disjoint hand-rolled loops
(core/train_loop for float/QAT, examples/mrf_fpga_train for the fused Pallas
kernel, and the production train stack the MRF net couldn't reach).  This
module folds them into the single ``repro.train`` engine: every backend
produces the same ``(TrainState, batch) -> (TrainState, metrics)`` step and
runs under ``ft.runner`` — gaining checkpoint/restart, the straggler
watchdog, and seekable deterministic data replay.

Backends
--------
``float``        value_and_grad on the fp32 MSE loss -> Adam/SGD (the paper's
                 software setup).
``qat-int8``     fake-quant forward with EMA activation observers; the
                 observer state rides in ``TrainState.aux`` so it checkpoints
                 and restores with the params (Jacob et al. 2017 QAT).
``fused-pallas`` the on-accelerator whole-step kernel
                 (kernels/fused_train): forward + backprop + optimizer
                 update (in-kernel SGD or Adam, per ``cfg.optimizer``)
                 inside one pallas_call, the paper's actual contribution.

Chunked execution
-----------------
For the <30k-param MRF net the per-step device work is microseconds, so the
stepwise loop is dispatch-bound: one Python dispatch (and, with a metrics
callback, one blocking host sync) per step.  ``chunk_steps > 1`` switches
the engine to chunked dispatch: ``lax.scan`` over ``chunk_steps`` train
steps inside one jitted, state-donating call, with batches synthesized
*inside* the scan by folding the global step index into the stream key
(``data/pipeline.batch_at`` — the same sampler the stepwise factory uses,
so both paths draw identical batches and the seekable-by-step restart
contract is preserved).  The fused-pallas backend goes one further: a chunk
is **one multi-step kernel launch** with weights (and Adam moments) resident
in VMEM across all ``chunk_steps`` steps — no scan, no kernel re-entry, 2
weight-stack HBM transfers per chunk instead of ``2*chunk_steps``
(kernels/fused_train/multistep.py).  Per-step metrics come back stacked and are fetched
once per chunk, asynchronously (the runner dispatches chunk N+1 before
syncing chunk N's metrics).  Chunked is **bit-identical** to stepwise for
every backend — same final ``TrainState``, same per-step losses — making it
a pure performance change (guarded by tests/test_chunked_training.py).

``build(fns, cfg)`` returns ``(step_fn, init_state)``;
``build_chunked(fns, cfg, stream, data_key)`` returns the chunk dispatcher
``chunk_fn(state, start, n)``; ``train(...)`` is the one-call path the thin
wrappers (core/train_loop, examples, benchmarks) use and selects the mode
from ``cfg.chunk_steps``.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.data.epg import default_sequence
from repro.data.pipeline import MRFSampleStream, batch_at, make_batch_factory
from repro.ft.checkpoint import latest_step
from repro.ft.runner import RunnerConfig, run
from repro.kernels.fused_train import ops as fused_ops
from repro.models import mrf as mrf_model
from repro.models.lm import ModelFns
from repro.optim import adam, sgd
from repro.train.step import (TrainState, init_train_state, make_chunked_step,
                              make_train_step)

BACKENDS = ("float", "qat-int8", "fused-pallas")


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    backend: str = "float"
    lr: float = 1e-4
    optimizer: str = "adam"       # paper: Adam in software, SGD on the FPGA
    microbatches: int = 1
    max_grad_norm: float | None = None  # None = no clipping (paper setup)
    grad_compress: bool = False
    # fused-pallas knobs: tile_batch=1 is the paper-faithful per-sample SGD
    # stream; 128 is the MXU-native minibatch mode.  interpret=None
    # auto-detects: the compiled kernel on TPU, interpreter elsewhere.
    tile_batch: int = 128
    interpret: bool | None = None
    donate: bool = True
    # chunk_steps=1 is the stepwise loop; >1 dispatches lax.scan chunks with
    # in-scan batch synthesis (bit-identical, dispatch-bound loops only pay
    # one Python dispatch + one async metrics fetch per chunk).
    chunk_steps: int = 1

    def __post_init__(self):
        assert self.backend in BACKENDS, (self.backend, BACKENDS)
        assert self.chunk_steps >= 1, self.chunk_steps
        if self.optimizer not in ("adam", "sgd"):
            raise ValueError(f"optimizer must be one of ('adam', 'sgd'), got "
                             f"{self.optimizer!r}")
        if self.backend == "fused-pallas":
            # the kernel computes grads AND the update in-VMEM: there is no
            # grad pytree to accumulate or compress, so these knobs would be
            # silent lies — refuse loudly instead of training the wrong thing
            if self.microbatches != 1:
                raise ValueError(
                    f"fused-pallas computes the update in-kernel: "
                    f"microbatches={self.microbatches} cannot be honored")
            if self.grad_compress:
                raise ValueError("fused-pallas computes the update in-kernel:"
                                 " grad_compress cannot be honored")
            if self.optimizer not in fused_ops.FUSED_OPTIMIZERS:
                raise ValueError(
                    f"fused-pallas implements optimizers "
                    f"{fused_ops.FUSED_OPTIMIZERS} in-kernel, got "
                    f"{self.optimizer!r}")


def _backend_step(fns: ModelFns, cfg: EngineConfig, opt):
    """(un-jitted ``(state, batch) -> (state, metrics)`` step, aux factory)
    for ``cfg.backend`` — the shared core of ``build`` and ``build_chunked``,
    so stepwise and chunked run literally the same step function."""
    if cfg.backend == "fused-pallas":
        # the configured rule (SGD or Adam) lives inside the kernel; ``opt``
        # shapes the optimizer slots (incl. Adam moment stacks) so the
        # TrainState pytree is backend-uniform — the kernel reads and writes
        # those slots through make_engine_step's padding.
        step = make_train_step(
            None, opt,
            fused_step=fused_ops.make_engine_step(
                lr=cfg.lr, optimizer=cfg.optimizer,
                tile_batch=cfg.tile_batch, interpret=cfg.interpret))
        aux_of = lambda params: None
    elif cfg.backend == "qat-int8":
        step = make_train_step(
            mrf_model.qat_loss, opt, microbatches=cfg.microbatches,
            max_grad_norm=cfg.max_grad_norm, grad_compress=cfg.grad_compress,
            aux_loss=True)
        aux_of = mrf_model.init_qat_aux
    else:
        step = make_train_step(
            fns.loss, opt, microbatches=cfg.microbatches,
            max_grad_norm=cfg.max_grad_norm, grad_compress=cfg.grad_compress)
        aux_of = lambda params: None
    return step, aux_of


def _make_init(fns: ModelFns, cfg: EngineConfig, opt, aux_of):
    def init_state(key: jax.Array) -> TrainState:
        params = fns.init(key)
        return init_train_state(params, opt, grad_compress=cfg.grad_compress,
                                aux=aux_of(params))
    return init_state


def build(fns: ModelFns, cfg: EngineConfig
          ) -> tuple[Callable, Callable[[jax.Array], TrainState]]:
    """(jitted step conforming to ``(state, batch) -> (state, metrics)``,
    ``init_state(key) -> TrainState``) for any backend."""
    opt = adam(cfg.lr) if cfg.optimizer == "adam" else sgd(cfg.lr)
    step, aux_of = _backend_step(fns, cfg, opt)
    jit_step = jax.jit(step, donate_argnums=(0,) if cfg.donate else ())
    return jit_step, _make_init(fns, cfg, opt, aux_of)


def _make_fused_chunk(cfg: EngineConfig, stream: MRFSampleStream,
                      data_key: jax.Array):
    """``chunk_fn(state, start, n)`` for the fused backend: ``n`` steps =
    **one multi-step kernel launch** with weights (and Adam moments) resident
    in VMEM across all of them (kernels/fused_train/multistep.py) — where
    stepwise backends fold ``n`` steps into a ``lax.scan``, the fused backend
    doesn't even re-enter the kernel.

    Batches are pre-staged into one ``(n*B, ...)`` stream by the same
    ``batch_at(stream, data_key, start + k)`` contract the scan path uses
    (``n`` is static, so the Python staging loop traces once per chunk
    length and the seekable-by-step restart semantics survive unchanged).
    Per-step metrics come back as the kernel's ``(n,)`` loss trace —
    element-identical to ``n`` stepwise fused calls.
    """
    def chunk_step(state: TrainState, start, n: int):
        staged = [batch_at(stream, data_key, start + k) for k in range(n)]
        x = jnp.concatenate([b["x"] for b in staged])
        y = jnp.concatenate([b["y"] for b in staged])
        new_params, new_opt, losses = fused_ops.fused_train_multistep(
            state.params, state.opt_state, x, y, n_steps=n, lr=cfg.lr,
            optimizer=cfg.optimizer, tile_batch=cfg.tile_batch,
            interpret=cfg.interpret)
        new_state = TrainState(step=state.step + n, params=new_params,
                               opt_state=new_opt,
                               ef_residual=state.ef_residual, aux=state.aux)
        return new_state, {"loss": jnp.mean(losses, axis=1)}
    return chunk_step


def build_chunked(fns: ModelFns, cfg: EngineConfig, stream: MRFSampleStream,
                  data_key: jax.Array
                  ) -> tuple[Callable, Callable[[jax.Array], TrainState]]:
    """(jitted ``chunk_fn(state, start, n) -> (state, stacked_metrics)``,
    ``init_state``) — the chunked dispatcher for any backend.

    Stepwise backends run ``n`` steps inside one ``lax.scan``; the fused
    backend dispatches the multi-step kernel instead (one launch, weights
    VMEM-resident across all ``n`` steps — see ``_make_fused_chunk``).
    Either way batches are synthesized on-device from
    ``batch_at(stream, data_key, start + i)`` so the chunk draws exactly the
    batches the stepwise factory would.  ``n`` is static (the final ragged
    chunk compiles once at its own length); ``start`` is a traced scalar, so
    chunk dispatches never recompile as the run advances.
    """
    opt = adam(cfg.lr) if cfg.optimizer == "adam" else sgd(cfg.lr)
    step, aux_of = _backend_step(fns, cfg, opt)
    if cfg.backend == "fused-pallas":
        chunk = _make_fused_chunk(cfg, stream, data_key)
    else:
        chunk = make_chunked_step(step, lambda s: batch_at(stream, data_key, s))
    jit_chunk = jax.jit(chunk, static_argnums=(2,),
                        donate_argnums=(0,) if cfg.donate else ())
    return jit_chunk, _make_init(fns, cfg, opt, aux_of)


def default_stream(model_cfg, batch_size: int) -> MRFSampleStream:
    return MRFSampleStream(seq=default_sequence(model_cfg.mrf_n_frames),
                           batch_size=batch_size)


def train(fns: ModelFns, engine_cfg: EngineConfig, runner_cfg: RunnerConfig,  # jaxlint: disable=SHARD -- delegates to step.make_train_step; placement via explicit `shardings` arg
          *, batches: Callable[[int], Any] | None = None,
          stream: MRFSampleStream | None = None,
          data_key: jax.Array | None = None, init_key: jax.Array | None = None,
          batch_size: int = 256, shardings=None, on_metrics=None):
    """Train an MRF net end to end through ``ft.runner``.

    Returns ``(state, step, info)`` where info carries wall-clock seconds and
    the samples/s throughput.  ``batches`` (a seekable ``step -> batch``
    factory) overrides the default stream+key construction — stepwise mode
    only: chunked runs synthesize batches on-device and need the
    ``stream``/``data_key`` pair itself.
    """
    chunked = engine_cfg.chunk_steps > 1
    if chunked and batches is not None:
        raise ValueError(
            "chunk_steps > 1 synthesizes batches on-device inside the scan: "
            "pass the (stream, data_key) pair instead of a host batches "
            "factory, so the data source is unambiguous and the chunked and "
            "stepwise paths draw identical batches")
    def stream_and_key():
        return (stream if stream is not None
                else default_stream(fns.cfg, batch_size),
                data_key if data_key is not None else jax.random.PRNGKey(1))

    if chunked:
        stream, data_key = stream_and_key()
        step_fn = None  # the chunked runner never consults the stepwise path
        chunk_fn, init_state = build_chunked(fns, engine_cfg, stream, data_key)
        batch_size = stream.batch_size
    else:
        chunk_fn = None
        step_fn, init_state = build(fns, engine_cfg)
        if batches is None:
            stream, data_key = stream_and_key()
            batches = make_batch_factory(stream, data_key)
            batch_size = stream.batch_size
    state0 = init_state(init_key if init_key is not None
                        else jax.random.PRNGKey(0))

    resume0 = latest_step(runner_cfg.ckpt_dir) or 0
    executed = 0  # steps run THIS invocation (a resume skips earlier ones)

    count_metrics = None
    if on_metrics is not None:
        def count_metrics(step, metrics, dt):
            nonlocal executed
            executed += 1
            on_metrics(step, metrics, dt)

    t0 = time.perf_counter()
    state, step = run(step_fn, state0, batches, runner_cfg,
                      shardings=shardings, on_metrics=count_metrics,
                      chunk_fn=chunk_fn, chunk_steps=engine_cfg.chunk_steps)
    wall = time.perf_counter() - t0
    if on_metrics is None:
        # no callback -> the runner skipped per-step syncs and we never saw
        # per-step ticks; progress-from-resume is the executed count.  Note
        # this omits steps re-executed after a mid-run crash/restart (wall
        # still includes them) — register a callback for exact throughput
        # accounting under fault injection.
        executed = step - resume0
    info = {"wall_seconds": wall, "steps_executed": executed,
            "samples_per_s": executed * batch_size / max(wall, 1e-9)}
    return state, step, info
