"""One training engine for the MRF nets.

The repo used to train the MRF net through three disjoint hand-rolled loops
(core/train_loop for float/QAT, examples/mrf_fpga_train for the fused Pallas
kernel, and the production train stack the MRF net couldn't reach).  This
module folds them into the single ``repro.train`` engine: every backend
produces the same ``(TrainState, batch) -> (TrainState, metrics)`` step and
runs under ``ft.runner`` — gaining checkpoint/restart, the straggler
watchdog, and seekable deterministic data replay.

Backends
--------
``float``        value_and_grad on the fp32 MSE loss -> Adam/SGD (the paper's
                 software setup).
``qat-int8``     fake-quant forward with EMA activation observers; the
                 observer state rides in ``TrainState.aux`` so it checkpoints
                 and restores with the params (Jacob et al. 2017 QAT).
``fused-pallas`` the on-accelerator whole-step kernel
                 (kernels/fused_train): forward + backprop + SGD inside one
                 pallas_call, the paper's actual contribution.

``build(fns, cfg)`` returns ``(step_fn, init_state)``; ``train(...)`` is the
one-call path the thin wrappers (core/train_loop, examples, benchmarks) use.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable

import jax

from repro.data.epg import default_sequence
from repro.data.pipeline import MRFSampleStream, make_batch_factory
from repro.ft.runner import RunnerConfig, run
from repro.kernels.fused_train import ops as fused_ops
from repro.models import mrf as mrf_model
from repro.models.lm import ModelFns
from repro.optim import adam, sgd
from repro.train.step import TrainState, init_train_state, make_train_step

BACKENDS = ("float", "qat-int8", "fused-pallas")


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    backend: str = "float"
    lr: float = 1e-4
    optimizer: str = "adam"       # paper: Adam in software, SGD on the FPGA
    microbatches: int = 1
    max_grad_norm: float | None = None  # None = no clipping (paper setup)
    grad_compress: bool = False
    # fused-pallas knobs: tile_batch=1 is the paper-faithful per-sample SGD
    # stream; 128 is the MXU-native minibatch mode.  interpret=None
    # auto-detects: the compiled kernel on TPU, interpreter elsewhere.
    tile_batch: int = 128
    interpret: bool | None = None
    donate: bool = True

    def __post_init__(self):
        assert self.backend in BACKENDS, (self.backend, BACKENDS)
        if self.backend == "fused-pallas":
            # the kernel is a whole-step SGD update: there is no grad pytree
            # to accumulate or compress, so these knobs would be silent lies
            assert self.microbatches == 1 and not self.grad_compress, (
                "fused-pallas computes the update in-kernel: microbatches/"
                "grad_compress do not apply")


def build(fns: ModelFns, cfg: EngineConfig
          ) -> tuple[Callable, Callable[[jax.Array], TrainState]]:
    """(jitted step conforming to ``(state, batch) -> (state, metrics)``,
    ``init_state(key) -> TrainState``) for any backend."""
    opt = adam(cfg.lr) if cfg.optimizer == "adam" else sgd(cfg.lr)

    if cfg.backend == "fused-pallas":
        # SGD lives inside the kernel; ``opt`` only shapes the (unused)
        # optimizer slots so the TrainState pytree is backend-uniform.
        step = make_train_step(
            None, opt,
            fused_step=fused_ops.make_engine_step(
                lr=cfg.lr, tile_batch=cfg.tile_batch,
                interpret=cfg.interpret))
        aux_of = lambda params: None
    elif cfg.backend == "qat-int8":
        step = make_train_step(
            mrf_model.qat_loss, opt, microbatches=cfg.microbatches,
            max_grad_norm=cfg.max_grad_norm, grad_compress=cfg.grad_compress,
            aux_loss=True)
        aux_of = mrf_model.init_qat_aux
    else:
        step = make_train_step(
            fns.loss, opt, microbatches=cfg.microbatches,
            max_grad_norm=cfg.max_grad_norm, grad_compress=cfg.grad_compress)
        aux_of = lambda params: None

    jit_step = jax.jit(step, donate_argnums=(0,) if cfg.donate else ())

    def init_state(key: jax.Array) -> TrainState:
        params = fns.init(key)
        return init_train_state(params, opt, grad_compress=cfg.grad_compress,
                                aux=aux_of(params))

    return jit_step, init_state


def default_stream(model_cfg, batch_size: int) -> MRFSampleStream:
    return MRFSampleStream(seq=default_sequence(model_cfg.mrf_n_frames),
                           batch_size=batch_size)


def train(fns: ModelFns, engine_cfg: EngineConfig, runner_cfg: RunnerConfig,
          *, batches: Callable[[int], Any] | None = None,
          stream: MRFSampleStream | None = None,
          data_key: jax.Array | None = None, init_key: jax.Array | None = None,
          batch_size: int = 256, shardings=None, on_metrics=None):
    """Train an MRF net end to end through ``ft.runner``.

    Returns ``(state, step, info)`` where info carries wall-clock seconds and
    the samples/s throughput.  ``batches`` (a seekable ``step -> batch``
    factory) overrides the default stream+key construction.
    """
    if batches is None:
        if stream is None:
            stream = default_stream(fns.cfg, batch_size)
        if data_key is None:
            data_key = jax.random.PRNGKey(1)
        batches = make_batch_factory(stream, data_key)
        batch_size = stream.batch_size
    step_fn, init_state = build(fns, engine_cfg)
    state0 = init_state(init_key if init_key is not None
                        else jax.random.PRNGKey(0))

    executed = 0  # steps run THIS invocation (a resume skips earlier ones)

    def count_metrics(step, metrics, dt):
        nonlocal executed
        executed += 1
        if on_metrics:
            on_metrics(step, metrics, dt)

    t0 = time.perf_counter()
    state, step = run(step_fn, state0, batches, runner_cfg,
                      shardings=shardings, on_metrics=count_metrics)
    wall = time.perf_counter() - t0
    info = {"wall_seconds": wall, "steps_executed": executed,
            "samples_per_s": executed * batch_size / max(wall, 1e-9)}
    return state, step, info
