"""Distributed training step for the architecture zoo.

Composes: CE loss forward (scanned+remat'd layer stack) -> grads ->
optional microbatch accumulation (lax.scan over the leading microbatch axis,
trading one weight all-gather per microbatch for a 1/M activation footprint)
-> grad clip -> optional int8 error-feedback gradient compression (what the
DCN-crossing pod all-reduce would carry) -> Adam/SGD update.

All state (params, optimizer moments, compression residuals) is a pytree
whose sharding follows the param logical axes, so the optimizer is
ZeRO-partitioned for free under pjit.
"""

from __future__ import annotations

from functools import partial
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.optim import clip_by_global_norm, error_feedback_compress
from repro.optim.optimizers import Optimizer


class TrainState(NamedTuple):
    step: jnp.ndarray
    params: Any
    opt_state: Any
    ef_residual: Any | None  # int8-compression error feedback


def init_train_state(params, opt: Optimizer, *, grad_compress: bool = False):
    return TrainState(
        step=jnp.zeros((), jnp.int32),
        params=params,
        opt_state=opt.init(params),
        ef_residual=jax.tree.map(jnp.zeros_like, params) if grad_compress else None,
    )


def make_train_step(loss_fn, opt: Optimizer, *, microbatches: int = 1,
                    max_grad_norm: float = 1.0, grad_compress: bool = False):
    """Returns train_step(state, batch) -> (state, metrics).

    ``batch`` leaves have a leading global-batch dim; with microbatches=M the
    step reshapes to (M, B/M, ...) and accumulates grads sequentially.
    """

    def grads_of(params, batch):
        return jax.value_and_grad(loss_fn)(params, batch)

    def train_step(state: TrainState, batch):
        params = state.params
        if microbatches == 1:
            loss, grads = grads_of(params, batch)
        else:
            def resh(x):
                b = x.shape[0]
                assert b % microbatches == 0, (b, microbatches)
                return x.reshape(microbatches, b // microbatches, *x.shape[1:])

            mb = jax.tree.map(resh, batch)

            def acc(carry, mb_i):
                loss_sum, g_sum = carry
                loss_i, g_i = grads_of(params, mb_i)
                return (loss_sum + loss_i,
                        jax.tree.map(jnp.add, g_sum, g_i)), None

            zero = jax.tree.map(jnp.zeros_like, params)
            (loss, grads), _ = jax.lax.scan(acc, (jnp.float32(0.0), zero), mb)
            loss = loss / microbatches
            grads = jax.tree.map(lambda g: g / microbatches, grads)

        grads, gnorm = clip_by_global_norm(grads, max_grad_norm)
        residual = state.ef_residual
        if grad_compress:
            grads, residual = error_feedback_compress(grads, residual)
        new_params, new_opt = opt.update(grads, state.opt_state, params)
        new_state = TrainState(step=state.step + 1, params=new_params,
                               opt_state=new_opt, ef_residual=residual)
        return new_state, {"loss": loss, "grad_norm": gnorm}

    return train_step
