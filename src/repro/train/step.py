"""Distributed training step for the architecture zoo AND the MRF nets.

Composes: loss forward -> grads -> optional microbatch accumulation
(lax.scan over the leading microbatch axis, trading one weight all-gather per
microbatch for a 1/M activation footprint) -> grad clip -> optional int8
error-feedback gradient compression (what the DCN-crossing pod all-reduce
would carry) -> Adam/SGD update.

All state (params, optimizer moments, compression residuals, backend aux) is
a pytree whose sharding follows the param logical axes, so the optimizer is
ZeRO-partitioned for free under pjit.

Backends
--------
``make_train_step`` is the single step factory every training path goes
through; the backend plugs in at one of two levels:

* ``aux_loss=True``: the loss carries functional auxiliary state
  (``loss_fn(params, aux, batch) -> (loss, new_aux)``) — e.g. the QAT
  activation observers.  ``aux`` lives in ``TrainState.aux`` so it rides
  through checkpoint/restore and buffer donation with everything else.
* ``fused_step``: a whole-step override
  (``(params, opt_state, aux, batch) -> (new_params, new_opt_state,
  new_aux, metrics)``) for updates computed *on the accelerator*
  (kernels/fused_train), where grads never materialise in HBM and the
  optimizer rule — including Adam's moment state — runs inside the kernel.
  The factory wraps it into the same ``(state, batch) -> (state, metrics)``
  contract, and **refuses** knobs the fused path cannot honor
  (``microbatches > 1``, ``grad_compress``): there is no grad pytree to
  accumulate or compress, so accepting them would train a silently
  different objective.

Every step the factory returns is *scan-compatible*: the whole
``TrainState`` — including the backend ``aux`` (QAT observers) — is the
scan carry, so ``make_chunked_step`` can fold ``n`` steps into one
``lax.scan`` dispatch with per-step metrics stacked on the scan output.
"""

from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.optim import clip_by_global_norm, error_feedback_compress
from repro.optim.optimizers import Optimizer, global_norm


class TrainState(NamedTuple):
    step: jnp.ndarray
    params: Any
    opt_state: Any
    ef_residual: Any | None  # int8-compression error feedback
    aux: Any | None = None   # backend state (e.g. QAT observers); checkpointed


def init_train_state(params, opt: Optimizer, *, grad_compress: bool = False,
                     aux=None):
    return TrainState(
        step=jnp.zeros((), jnp.int32),
        params=params,
        opt_state=opt.init(params),
        ef_residual=jax.tree.map(jnp.zeros_like, params) if grad_compress else None,
        aux=aux,
    )


def make_train_step(loss_fn, opt: Optimizer, *, microbatches: int = 1,
                    max_grad_norm: float | None = 1.0,
                    grad_compress: bool = False, aux_loss: bool = False,
                    fused_step=None):
    """Returns train_step(state, batch) -> (state, metrics).

    ``batch`` leaves have a leading global-batch dim; with microbatches=M the
    step reshapes to (M, B/M, ...) and accumulates grads sequentially.
    ``max_grad_norm=None`` disables clipping (gnorm is still reported).
    With ``aux_loss``, ``loss_fn(params, aux, batch) -> (loss, new_aux)`` and
    the aux threads through ``state.aux``.  ``fused_step`` replaces the whole
    grads+apply pipeline (see module docstring); ``loss_fn`` may be None then.
    """
    if fused_step is not None:
        if microbatches != 1:
            raise ValueError(
                f"fused_step computes grads+update in-kernel: there is no "
                f"grad pytree to accumulate, so microbatches={microbatches} "
                f"cannot be honored (use a stepwise backend)")
        if grad_compress:
            raise ValueError(
                "fused_step computes grads+update in-kernel: there is no "
                "grad pytree to compress, so grad_compress cannot be honored "
                "(use a stepwise backend)")

        def train_step(state: TrainState, batch):  # jaxlint: disable=SHARD -- fused_step owns placement: the Pallas path is single-core by design
            new_params, new_opt, new_aux, metrics = fused_step(
                state.params, state.opt_state, state.aux, batch)
            new_state = TrainState(step=state.step + 1, params=new_params,
                                   opt_state=new_opt,
                                   ef_residual=state.ef_residual, aux=new_aux)
            return new_state, metrics
        return train_step

    def grads_of(params, aux, batch):  # jaxlint: disable=SHARD -- sharding is the loss_fn's contract; models annotate their own batch axes
        if aux_loss:
            (loss, new_aux), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, aux, batch)
            return loss, grads, new_aux
        loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        return loss, grads, aux

    def train_step(state: TrainState, batch):  # jaxlint: disable=SHARD -- sharding is the loss_fn's contract; models annotate their own batch axes
        params = state.params
        if microbatches == 1:
            loss, grads, aux = grads_of(params, state.aux, batch)
        else:
            def resh(x):
                b = x.shape[0]
                assert b % microbatches == 0, (b, microbatches)
                return x.reshape(microbatches, b // microbatches, *x.shape[1:])

            mb = jax.tree.map(resh, batch)

            def acc(carry, mb_i):
                loss_sum, g_sum, aux_i = carry
                loss_i, g_i, aux_i = grads_of(params, aux_i, mb_i)
                return (loss_sum + loss_i,
                        jax.tree.map(jnp.add, g_sum, g_i), aux_i), None

            zero = jax.tree.map(jnp.zeros_like, params)
            (loss, grads, aux), _ = jax.lax.scan(
                acc, (jnp.float32(0.0), zero, state.aux), mb)
            loss = loss / microbatches
            grads = jax.tree.map(lambda g: g / microbatches, grads)

        if max_grad_norm is None:
            gnorm = global_norm(grads)
        else:
            grads, gnorm = clip_by_global_norm(grads, max_grad_norm)
        residual = state.ef_residual
        if grad_compress:
            grads, residual = error_feedback_compress(grads, residual)
        new_params, new_opt = opt.update(grads, state.opt_state, params)
        new_state = TrainState(step=state.step + 1, params=new_params,
                               opt_state=new_opt, ef_residual=residual,
                               aux=aux)
        return new_state, {"loss": loss, "grad_norm": gnorm}

    return train_step


def make_chunked_step(train_step, batch_at):
    """Fold ``n`` train steps into one ``lax.scan`` dispatch.

    ``train_step``: any ``(state, batch) -> (state, metrics)`` from
    ``make_train_step`` (all three backends qualify — the carry is the full
    ``TrainState`` incl. ``aux``, and the fused-pallas whole-step kernel
    traces under scan like any other jax op).
    ``batch_at``: ``step -> batch`` with a *traced* int32 step — batches are
    synthesized on-device inside the scan, so a chunk moves zero bytes
    host->device and pays one Python dispatch for ``n`` steps.

    Returns ``chunk_step(state, start, n) -> (state, metrics)`` where
    ``metrics`` leaves are stacked ``(n, ...)`` per-step values — identical,
    element for element, to what ``n`` stepwise calls would have produced
    (``start`` is the global step of the chunk's first step, so the
    seekable-by-step data contract survives restarts).  ``n`` must be static
    (each distinct chunk length compiles once).
    """
    def chunk_step(state: TrainState, start, n: int):
        def body(carry, offset):
            new_state, metrics = train_step(carry, batch_at(start + offset))
            return new_state, metrics
        return jax.lax.scan(body, state, jnp.arange(n, dtype=jnp.int32))
    return chunk_step
