"""Static import-integrity check: every ``repro.*`` import target exists.

The seed shipped ten modules importing ``repro.dist.sharding`` without the
``repro/dist/`` package on disk, which broke collection of the entire test
suite.  This checker walks the repo's python files with ``ast`` (no code is
executed, so it is safe on files that set ``XLA_FLAGS`` or spawn meshes at
import time) and verifies that every ``import repro.x.y`` /
``from repro.x.y import z`` statement names a module that resolves under
``src/``.  For ``from A import z`` only module ``A`` is resolvable
statically (``z`` may be an attribute), except that when ``z`` is itself a
submodule directory/file it is checked too.

Run via ``scripts/check_imports.py`` (CI) or ``tests/test_import_integrity.py``
(tier-1).
"""

from __future__ import annotations

import ast
import pathlib

#: repo-relative directories scanned for python files
SCAN_DIRS = ("src", "tests", "scripts", "benchmarks", "examples",
             "experiments")

#: repo-relative prefixes excluded from the scan: lint fixtures are
#: deliberately synthetic (jaxlint's project fixtures are mini-repos whose
#: ``repro.*`` modules exist only inside the fixture tree)
EXCLUDE_PREFIXES = ("tests/fixtures/",)


def _module_exists(src_root: pathlib.Path, module: str) -> bool:
    path = src_root.joinpath(*module.split("."))
    return path.with_suffix(".py").is_file() or (path / "__init__.py").is_file()


def _iter_repro_imports(tree: ast.AST):
    """Yield (lineno, module, names) for repro-rooted import statements.

    ``names`` is the imported-name list for ``from`` imports (empty for
    plain ``import``); relative imports are skipped (the repo uses absolute
    imports throughout).
    """
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for alias in node.names:
                if alias.name == "repro" or alias.name.startswith("repro."):
                    yield node.lineno, alias.name, []
        elif isinstance(node, ast.ImportFrom) and node.level == 0:
            mod = node.module or ""
            if mod == "repro" or mod.startswith("repro."):
                yield node.lineno, mod, [a.name for a in node.names]


def find_missing_imports(repo_root: pathlib.Path) -> list[str]:
    """Return human-readable ``file:line: module`` records for every
    repro-rooted import whose target module does not exist under src/."""
    repo_root = pathlib.Path(repo_root)
    src_root = repo_root / "src"
    missing: list[str] = []
    for scan in SCAN_DIRS:
        base = repo_root / scan
        if not base.is_dir():
            continue
        for py in sorted(base.rglob("*.py")):
            rel = py.relative_to(repo_root).as_posix()
            if any(rel.startswith(p) for p in EXCLUDE_PREFIXES):
                continue
            try:
                tree = ast.parse(py.read_text(), filename=str(py))
            except SyntaxError as e:
                missing.append(f"{py.relative_to(repo_root)}: syntax error "
                               f"prevents checking ({e.msg}, line {e.lineno})")
                continue
            for lineno, mod, names in _iter_repro_imports(tree):
                where = f"{py.relative_to(repo_root)}:{lineno}"
                if not _module_exists(src_root, mod):
                    missing.append(f"{where}: import target '{mod}' has no "
                                   f"module under src/")
                    continue
                for name in names:
                    sub = f"{mod}.{name}"
                    subpath = src_root.joinpath(*sub.split("."))
                    # only flag names that LOOK like submodules on a package:
                    # a dir without __init__.py, or nothing at all when the
                    # parent has no __init__ namespace to hold attributes
                    if (subpath.is_dir()
                            and not (subpath / "__init__.py").is_file()):
                        missing.append(f"{where}: '{sub}' is a directory "
                                       f"without __init__.py")
    return missing


def main(repo_root: pathlib.Path | None = None) -> int:
    if repo_root is None:
        repo_root = pathlib.Path(__file__).resolve().parents[3]
    missing = find_missing_imports(repo_root)
    if missing:
        print(f"import-integrity: {len(missing)} broken repro.* import(s):")
        for m in missing:
            print(f"  {m}")
        return 1
    print("import-integrity: all repro.* import targets exist")
    return 0
