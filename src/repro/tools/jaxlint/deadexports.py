"""Dead-public-API inventory: ``jaxlint --report dead-exports``.

Lists public symbols defined under ``src/repro`` that no other file in the
repo references, plus modules nothing imports.  This is a *report*, not a
lint failure: dormant subsystems (``analysis/roofline`` driving the int8
kernel sprint, ``optim/grad_compression`` awaiting the data-parallel
gradient exchange) are named ROADMAP work — the report keeps them visible
instead of letting them rot silently or forcing their deletion.

Conservativeness: usage is identifier-based (any ``Name`` load, attribute
access, or ``from X import name`` anywhere in the scan dirs counts), so a
same-named symbol elsewhere keeps a dead one "alive" — the report
under-counts, it never over-counts.  Re-export lines in ``__init__.py``
files do NOT count as usage (they are API surface, not use), so a symbol
that is only ever re-exported still shows up.
"""

from __future__ import annotations

import ast
import pathlib

from repro.tools.import_integrity import SCAN_DIRS


def _public_symbols(src_root: pathlib.Path):
    """Yield (module, name, lineno, file) for public top-level defs."""
    for py in sorted((src_root / "repro").rglob("*.py")):
        if py.name == "__init__.py":
            continue  # __init__ contents are re-export surface
        module = ".".join(py.relative_to(src_root).with_suffix("").parts)
        try:
            tree = ast.parse(py.read_text())
        except SyntaxError:
            continue
        for stmt in tree.body:
            names: list[tuple[str, int]] = []
            if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef,
                                 ast.ClassDef)):
                names.append((stmt.name, stmt.lineno))
            elif isinstance(stmt, ast.Assign):
                names.extend((t.id, t.lineno) for t in stmt.targets
                             if isinstance(t, ast.Name))
            elif isinstance(stmt, ast.AnnAssign) and \
                    isinstance(stmt.target, ast.Name):
                names.append((stmt.target.id, stmt.lineno))
            for name, lineno in names:
                if not name.startswith("_"):
                    yield module, name, lineno, py


def _usages(repo_root: pathlib.Path):
    """(identifiers used per file, modules imported anywhere)."""
    used_by_file: dict[pathlib.Path, set] = {}
    imported_modules: set[str] = set()
    for scan in SCAN_DIRS:
        base = repo_root / scan
        if not base.is_dir():
            continue
        for py in sorted(base.rglob("*.py")):
            try:
                tree = ast.parse(py.read_text())
            except SyntaxError:
                continue
            used: set[str] = set()
            is_init = py.name == "__init__.py"
            for node in ast.walk(tree):
                if isinstance(node, ast.Name):
                    used.add(node.id)
                elif isinstance(node, ast.Attribute):
                    used.add(node.attr)
                elif isinstance(node, ast.Import):
                    for a in node.names:
                        imported_modules.add(a.name)
                elif isinstance(node, ast.ImportFrom) and node.level == 0:
                    mod = node.module or ""
                    imported_modules.add(mod)
                    for a in node.names:
                        # `from repro.optim import grad_compression` imports
                        # a *module*; count it as such either way
                        imported_modules.add(f"{mod}.{a.name}")
                        if not is_init:
                            used.add(a.asname or a.name)
            used_by_file[py] = used
    return used_by_file, imported_modules


def dead_exports(repo_root) -> dict:
    """{"symbols": [(module, name, lineno)], "modules": [module]} with no
    in-repo reference outside their defining file."""
    repo_root = pathlib.Path(repo_root)
    src_root = repo_root / "src"
    used_by_file, imported = _usages(repo_root)

    dead_syms = []
    seen_modules = set()
    for module, name, lineno, py in _public_symbols(src_root):
        seen_modules.add(module)
        if not any(name in used for f, used in used_by_file.items()
                   if f != py):
            dead_syms.append((module, name, lineno))

    dead_mods = sorted(
        m for m in seen_modules
        if m not in imported
        and not any(im.startswith(m + ".") for im in imported))
    return {"symbols": dead_syms, "modules": dead_mods}


def dead_exports_report(repo_root) -> list[str]:
    """Human-readable report lines (informational — exit 0 either way)."""
    repo_root = pathlib.Path(repo_root)
    dead = dead_exports(repo_root)
    lines = ["jaxlint dead-exports report (informational; identifier-based,"
             " so a hit means 'no in-repo reference found')", ""]
    if dead["modules"]:
        lines.append("modules imported nowhere:")
        lines.extend(f"  {m}" for m in dead["modules"])
        lines.append("")
    if dead["symbols"]:
        lines.append("public symbols with no in-repo reference:")
        for module, name, lineno in dead["symbols"]:
            path = "src/" + module.replace(".", "/") + ".py"
            lines.append(f"  {module}.{name}  ({path}:{lineno})")
    if not dead["modules"] and not dead["symbols"]:
        lines.append("no dead exports found")
    return lines
