"""Dead-public-API inventory: ``jaxlint --report dead-exports``.

Lists public symbols defined under ``src/repro`` that no other file in the
repo references, plus modules nothing imports.  Dormant subsystems
(``analysis/roofline`` driving the int8 kernel sprint,
``optim/grad_compression`` awaiting the data-parallel gradient exchange)
are named ROADMAP work — the report keeps them visible instead of letting
them rot silently or forcing their deletion.

With ``--allowlist FILE`` the report becomes a *CI gate*: every dead
export must appear in the allowlist with a one-line reason, and every
allowlist entry must still be dead — a symbol that gained a caller (or
was deleted) makes its entry *stale* and fails the gate too, so the file
can only ever describe the present.  Entry format, one per line::

    repro.ft.elastic.survivor_mesh -- held for the elastic resume path
    module:repro.launch.dryrun -- CLI-only entry point, imported by no one

(`module:` prefixes a never-imported module; everything else is
``module.symbol``.  ``#`` starts a comment.)

Conservativeness: usage is identifier-based (any ``Name`` load, attribute
access, or ``from X import name`` anywhere in the scan dirs counts), so a
same-named symbol elsewhere keeps a dead one "alive" — the report
under-counts, it never over-counts.  Re-export lines in ``__init__.py``
files do NOT count as usage (they are API surface, not use), so a symbol
that is only ever re-exported still shows up.
"""

from __future__ import annotations

import ast
import pathlib

from repro.tools.import_integrity import SCAN_DIRS


def _public_symbols(src_root: pathlib.Path):
    """Yield (module, name, lineno, file) for public top-level defs."""
    for py in sorted((src_root / "repro").rglob("*.py")):
        if py.name == "__init__.py":
            continue  # __init__ contents are re-export surface
        module = ".".join(py.relative_to(src_root).with_suffix("").parts)
        try:
            tree = ast.parse(py.read_text())
        except SyntaxError:
            continue
        for stmt in tree.body:
            names: list[tuple[str, int]] = []
            if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef,
                                 ast.ClassDef)):
                names.append((stmt.name, stmt.lineno))
            elif isinstance(stmt, ast.Assign):
                names.extend((t.id, t.lineno) for t in stmt.targets
                             if isinstance(t, ast.Name))
            elif isinstance(stmt, ast.AnnAssign) and \
                    isinstance(stmt.target, ast.Name):
                names.append((stmt.target.id, stmt.lineno))
            for name, lineno in names:
                if not name.startswith("_"):
                    yield module, name, lineno, py


def _usages(repo_root: pathlib.Path):
    """(identifiers used per file, modules imported anywhere)."""
    used_by_file: dict[pathlib.Path, set] = {}
    imported_modules: set[str] = set()
    for scan in SCAN_DIRS:
        base = repo_root / scan
        if not base.is_dir():
            continue
        for py in sorted(base.rglob("*.py")):
            try:
                tree = ast.parse(py.read_text())
            except SyntaxError:
                continue
            used: set[str] = set()
            is_init = py.name == "__init__.py"
            for node in ast.walk(tree):
                if isinstance(node, ast.Name):
                    used.add(node.id)
                elif isinstance(node, ast.Attribute):
                    used.add(node.attr)
                elif isinstance(node, ast.Import):
                    for a in node.names:
                        imported_modules.add(a.name)
                elif isinstance(node, ast.ImportFrom) and node.level == 0:
                    mod = node.module or ""
                    imported_modules.add(mod)
                    for a in node.names:
                        # `from repro.optim import grad_compression` imports
                        # a *module*; count it as such either way
                        imported_modules.add(f"{mod}.{a.name}")
                        if not is_init:
                            used.add(a.asname or a.name)
            used_by_file[py] = used
    return used_by_file, imported_modules


def dead_exports(repo_root) -> dict:
    """{"symbols": [(module, name, lineno)], "modules": [module]} with no
    in-repo reference outside their defining file."""
    repo_root = pathlib.Path(repo_root)
    src_root = repo_root / "src"
    used_by_file, imported = _usages(repo_root)

    dead_syms = []
    seen_modules = set()
    for module, name, lineno, py in _public_symbols(src_root):
        seen_modules.add(module)
        if not any(name in used for f, used in used_by_file.items()
                   if f != py):
            dead_syms.append((module, name, lineno))

    dead_mods = sorted(
        m for m in seen_modules
        if m not in imported
        and not any(im.startswith(m + ".") for im in imported))
    return {"symbols": dead_syms, "modules": dead_mods}


def dead_exports_report(repo_root) -> list[str]:
    """Human-readable report lines (informational — exit 0 either way)."""
    repo_root = pathlib.Path(repo_root)
    dead = dead_exports(repo_root)
    lines = ["jaxlint dead-exports report (informational; identifier-based,"
             " so a hit means 'no in-repo reference found')", ""]
    if dead["modules"]:
        lines.append("modules imported nowhere:")
        lines.extend(f"  {m}" for m in dead["modules"])
        lines.append("")
    if dead["symbols"]:
        lines.append("public symbols with no in-repo reference:")
        for module, name, lineno in dead["symbols"]:
            path = "src/" + module.replace(".", "/") + ".py"
            lines.append(f"  {module}.{name}  ({path}:{lineno})")
    if not dead["modules"] and not dead["symbols"]:
        lines.append("no dead exports found")
    return lines


def parse_allowlist(path) -> tuple[dict[str, str], list[str]]:
    """{entry key: reason} plus problem lines (reasonless entries)."""
    path = pathlib.Path(path)
    entries: dict[str, str] = {}
    problems: list[str] = []
    for i, raw in enumerate(path.read_text().splitlines(), start=1):
        line = raw.split("#", 1)[0].strip() if raw.lstrip().startswith("#") \
            else raw.strip()
        if not line:
            continue
        key, sep, reason = line.partition(" -- ")
        key, reason = key.strip(), reason.strip()
        if not sep or not reason:
            problems.append(f"{path}:{i}: entry `{key}` carries no reason "
                            f"— write `<name> -- why it stays`")
        entries[key] = reason
    return entries, problems


def dead_exports_gate(repo_root, allowlist_path) -> tuple[list[str], int]:
    """Gate lines + exit code: 1 on non-allowlisted dead exports, stale
    allowlist entries, or reasonless entries."""
    repo_root = pathlib.Path(repo_root)
    allowlist_path = pathlib.Path(allowlist_path)
    if not allowlist_path.is_file():
        return [f"dead-exports gate: allowlist {allowlist_path} not found"], 1
    dead = dead_exports(repo_root)
    dead_keys: dict[str, str] = {}
    for module, name, lineno in dead["symbols"]:
        path = "src/" + module.replace(".", "/") + ".py"
        dead_keys[f"{module}.{name}"] = f"{path}:{lineno}"
    for m in dead["modules"]:
        dead_keys[f"module:{m}"] = "src/" + m.replace(".", "/") + ".py"
    entries, problems = parse_allowlist(allowlist_path)

    lines = list(problems)
    for key in sorted(set(dead_keys) - set(entries)):
        lines.append(f"dead export not in the allowlist: {key} "
                     f"({dead_keys[key]}) — wire it up, delete it, or add "
                     f"it to {allowlist_path.name} with a reason")
    for key in sorted(set(entries) - set(dead_keys)):
        lines.append(f"stale allowlist entry: {key} is no longer a dead "
                     f"export — remove it from {allowlist_path.name}")
    if lines:
        return lines, 1
    return [f"dead-exports gate: clean ({len(dead_keys)} allowlisted, "
            f"0 stale)"], 0
