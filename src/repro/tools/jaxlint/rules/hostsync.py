"""HOSTSYNC: no host synchronization on the hot loop.

The paper's dispatch-efficiency story (and PR 4/5's measured speedups)
depends on the training and serving loops staying *asynchronous*: the host
dispatches work and only rejoins the device at designed sync points (one
metrics fetch per chunk, one ``block_until_ready`` per wave).  A stray
``np.asarray`` / ``.item()`` / ``float(tracer)`` / ``jax.device_get`` /
``block_until_ready`` anywhere else stalls the pipeline for a full
round-trip per step — the exact regression PRs 3-5 hand-removed.

The rule fires only in the hot-loop modules (``config.hot_loop_modules``)
and skips the sanctioned sync points (``config.sync_allowlist``, matched
by function qualname).  ``float(<literal>)`` is ignored — ``float("-inf")``
is not a device fetch.
"""

from __future__ import annotations

import ast

from repro.tools.jaxlint.core import register


def _sync_pattern(call: ast.Call) -> str | None:
    f = call.func
    if isinstance(f, ast.Attribute):
        if (f.attr == "asarray" and isinstance(f.value, ast.Name)
                and f.value.id in ("np", "numpy", "onp")):
            return f"{f.value.id}.asarray"
        if f.attr == "item" and not call.args and not call.keywords:
            return ".item()"
        if f.attr in ("block_until_ready", "device_get"):
            return f.attr
    elif isinstance(f, ast.Name):
        if f.id in ("block_until_ready", "device_get"):
            return f.id
        if (f.id == "float" and call.args
                and not isinstance(call.args[0], ast.Constant)):
            return "float()"
    return None


@register("HOSTSYNC", "host sync (np.asarray/.item()/float()/device_get/"
                      "block_until_ready) on a hot-loop path")
def check(ctx):
    module = next((m for m in ctx.config.hot_loop_modules
                   if ctx.module_path == m or ctx.module_path.endswith("/" + m)),
                  None)
    if module is None:
        return
    allowed = ctx.config.sync_allowlist.get(module, ())
    for node in ast.walk(ctx.tree):
        if not isinstance(node, ast.Call):
            continue
        pat = _sync_pattern(node)
        if pat is None:
            continue
        qual = ctx.qualname_of(node)
        if any(qual == a or qual.startswith(a + ".") for a in allowed):
            continue
        where = f"in `{qual}`" if qual else "at module level"
        yield ctx.finding(
            node, "HOSTSYNC",
            f"host sync `{pat}` {where} — hot-loop modules stay async "
            f"outside the sanctioned sync points (see sync_allowlist)")
