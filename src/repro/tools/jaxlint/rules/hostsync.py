"""HOSTSYNC: no host synchronization on the hot loop.

The paper's dispatch-efficiency story (and PR 4/5's measured speedups)
depends on the training and serving loops staying *asynchronous*: the host
dispatches work and only rejoins the device at designed sync points (one
metrics fetch per chunk, one ``block_until_ready`` per wave).  A stray
``np.asarray`` / ``.item()`` / ``float(tracer)`` / ``jax.device_get`` /
``block_until_ready`` anywhere else stalls the pipeline for a full
round-trip per step — the exact regression PRs 3-5 hand-removed.

The rule fires only in the hot-loop modules (``config.hot_loop_modules``)
and skips the sanctioned sync points (``config.sync_allowlist``, matched
by function qualname).  ``float(<literal>)`` is ignored — ``float("-inf")``
is not a device fetch.

The *project pass* adds the outsourced-sync case: a hot-loop module
calling a helper in another module whose body (or a helper of that
helper — two hops) performs a sync stalls the loop just the same.  The
finding lands at the hot-loop *call site* (the attribution the cache
relies on) with the helper's sync location in the message.  Callees that
are themselves hot-loop modules are skipped — their own per-file run
covers them.
"""

from __future__ import annotations

import ast

from repro.tools.jaxlint.core import register, register_project

#: hops the project pass follows from a hot-loop call site into helpers
_HELPER_DEPTH = 2


def _sync_pattern(call: ast.Call) -> str | None:
    f = call.func
    if isinstance(f, ast.Attribute):
        if (f.attr == "asarray" and isinstance(f.value, ast.Name)
                and f.value.id in ("np", "numpy", "onp")):
            return f"{f.value.id}.asarray"
        if f.attr == "item" and not call.args and not call.keywords:
            return ".item()"
        if f.attr in ("block_until_ready", "device_get"):
            return f.attr
    elif isinstance(f, ast.Name):
        if f.id in ("block_until_ready", "device_get"):
            return f.id
        if (f.id == "float" and call.args
                and not isinstance(call.args[0], ast.Constant)):
            return "float()"
    return None


@register("HOSTSYNC", "host sync (np.asarray/.item()/float()/device_get/"
                      "block_until_ready) on a hot-loop path")
def check(ctx):
    module = next((m for m in ctx.config.hot_loop_modules
                   if ctx.module_path == m or ctx.module_path.endswith("/" + m)),
                  None)
    if module is None:
        return
    allowed = ctx.config.sync_allowlist.get(module, ())
    for node in ast.walk(ctx.tree):
        if not isinstance(node, ast.Call):
            continue
        pat = _sync_pattern(node)
        if pat is None:
            continue
        qual = ctx.qualname_of(node)
        if any(qual == a or qual.startswith(a + ".") for a in allowed):
            continue
        where = f"in `{qual}`" if qual else "at module level"
        yield ctx.finding(
            node, "HOSTSYNC",
            f"host sync `{pat}` {where} — hot-loop modules stay async "
            f"outside the sanctioned sync points (see sync_allowlist)")


def _hot_module_of(ctx, config) -> str | None:
    return next((m for m in config.hot_loop_modules
                 if ctx.module_path == m
                 or ctx.module_path.endswith("/" + m)), None)


def _first_sync(project, path: str, fn, depth: int, seen: set,
                hot_paths: set):
    """(path, line, pattern, qualname) of the first host sync reachable
    inside ``fn`` within ``_HELPER_DEPTH`` hops, else None."""
    if id(fn) in seen:
        return None
    seen.add(id(fn))
    ctx = project.files[path]
    for node in ast.walk(fn):
        if isinstance(node, ast.Call):
            pat = _sync_pattern(node)
            if pat is not None:
                return (path, node.lineno, pat,
                        ctx.qualnames.get(fn, fn.name))
    if depth >= _HELPER_DEPTH:
        return None
    for node in ast.walk(fn):
        if not isinstance(node, ast.Call):
            continue
        for cpath, cfn in project.resolve_call(path, node):
            if cpath in hot_paths:
                continue
            found = _first_sync(project, cpath, cfn, depth + 1, seen,
                                hot_paths)
            if found is not None:
                return found
    return None


@register_project("HOSTSYNC")
def project_check(project, targets):
    cfg = project.config
    hot_paths = {p for p, c in project.files.items()
                 if _hot_module_of(c, cfg) is not None}
    for path in targets:
        ctx = project.files.get(path)
        if ctx is None:
            continue
        module = _hot_module_of(ctx, cfg)
        if module is None:
            continue
        allowed = cfg.sync_allowlist.get(module, ())
        reported: set = set()
        for node in ast.walk(ctx.tree):
            if not isinstance(node, ast.Call):
                continue
            qual = ctx.qualname_of(node)
            if any(qual == a or qual.startswith(a + ".") for a in allowed):
                continue
            for cpath, cfn in project.resolve_call(path, node):
                if cpath in hot_paths:
                    continue  # covered by that file's own per-file run
                sync = _first_sync(project, cpath, cfn, 1, set(),
                                   hot_paths)
                if sync is None:
                    continue
                spath, sline, pat, squal = sync
                key = (node.lineno, spath, sline)
                if key in reported:
                    continue
                reported.add(key)
                where = f"in `{qual}`" if qual else "at module level"
                yield ctx.finding(
                    node, "HOSTSYNC",
                    f"call {where} reaches host sync `{pat}` in "
                    f"`{squal}` ({spath}:{sline}) — the helper stalls "
                    f"the hot loop exactly like an inline sync; hoist "
                    f"it behind a sanctioned sync point or pragma the "
                    f"call")
