"""PALLASTILE: Pallas block shapes must respect TPU tiling + VMEM limits.

TPU vector memory is tiled ``(8, 128)`` (sublane x lane) for fp32: a
``BlockSpec`` / scratch block whose last dim is not a multiple of 128, or
whose second-to-last dim is not a multiple of 8, gets padded up by Mosaic —
silently wasting VMEM and MXU occupancy — and several such shapes only run
at all because the CPU interpreter (`interpret=True`, the default
everywhere off-TPU in this repo) doesn't enforce the layout.  The rule
flags misaligned literals and also estimates each ``pallas_call``'s VMEM
footprint (sum over block + scratch shapes x dtype), erroring above
``config.vmem_cap_bytes`` (~16 MB/core on current TPUs).

Resolution is static-only: a dim resolves when it is an int literal, a
module-level int constant (``PAD = 128``), or the enclosing function
parameter's int default (``block_m: int = 128``).  The *project pass*
widens the constant environment to imported module-level ints — ``from
repro.kernels.tiles import BLOCK_N`` and ``tiles.BLOCK_N`` spellings both
resolve — and reports only the findings the per-file environment could
not prove.  Unresolvable dims are skipped for alignment and contribute
nothing to the (thus lower-bound) VMEM estimate.  Intentionally-narrow
blocks — a ``(1, N)`` bias row, a ``(Bq, 1)`` online-softmax column — are
real and fine: they earn a ``# jaxlint: disable=PALLASTILE -- why`` on
the line.
"""

from __future__ import annotations

import ast

from repro.tools.jaxlint.astutil import dotted, int_defaults, kw
from repro.tools.jaxlint.core import DTYPE_BYTES, register, register_project


def _is_blockspec(call: ast.Call) -> bool:
    d = dotted(call.func)
    return d is not None and d.split(".")[-1] == "BlockSpec"


def _is_vmem(call: ast.Call) -> bool:
    d = dotted(call.func)
    return d is not None and d.split(".")[-1] == "VMEM"


def _shape_tuple(call: ast.Call):
    if call.args and isinstance(call.args[0], (ast.Tuple, ast.List)):
        return call.args[0]
    return None


def _resolve(elt, env: dict[str, int]) -> int | None:
    if isinstance(elt, ast.Constant) and isinstance(elt.value, int) \
            and not isinstance(elt.value, bool):
        return elt.value
    if isinstance(elt, ast.Name):
        return env.get(elt.id)
    if isinstance(elt, ast.Attribute):
        # dotted constants: `tiles.BLOCK_N` (project int_env keys)
        d = dotted(elt)
        if d is not None:
            return env.get(d)
    return None


def _dtype_bytes(call: ast.Call, default: int) -> int:
    # pltpu.VMEM((shape), jnp.float32) — dtype is the second positional arg
    if len(call.args) >= 2:
        d = dotted(call.args[1])
        if d is not None and d.split(".")[-1] in DTYPE_BYTES:
            return DTYPE_BYTES[d.split(".")[-1]]
    return default


def _env_for(ctx, node, extra: dict | None = None) -> dict[str, int]:
    env = dict(extra) if extra else {}
    env.update(ctx.int_constants)
    fn = ctx.enclosing_function(node)
    while fn is not None:
        for name, val in int_defaults(fn).items():
            env.setdefault(name, val)
        fn = ctx.enclosing_function(fn)
    return env


def _alignment_findings(ctx, call, env):
    shape = _shape_tuple(call)
    if shape is None or len(shape.elts) < 2:
        return
    src = ast.unparse(shape)
    lane, sub = ctx.config.lane, ctx.config.sublane
    last = _resolve(shape.elts[-1], env)
    if last is not None and last % lane != 0:
        yield ctx.finding(
            shape, "PALLASTILE",
            f"block shape {src}: last dim {last} is not a multiple of "
            f"{lane} (TPU lane width) — Mosaic pads every block to "
            f"({sub}, {lane}) tiles; only the interpreter tolerates this "
            f"for free")
    second = _resolve(shape.elts[-2], env)
    if second is not None and second % sub != 0:
        yield ctx.finding(
            shape, "PALLASTILE",
            f"block shape {src}: second-to-last dim {second} is not a "
            f"multiple of {sub} (TPU sublane) — the block pads up to "
            f"({sub}, {lane}) tiles on the compiled path")


def _spec_bytes(call, env, default_bytes) -> int:
    """Lower-bound VMEM bytes of one BlockSpec/VMEM call (0 if any dim is
    unresolvable)."""
    shape = _shape_tuple(call)
    if shape is None:
        return 0
    total = 1
    for elt in shape.elts:
        v = _resolve(elt, env)
        if v is None:
            return 0
        total *= v
    return total * _dtype_bytes(call, default_bytes)


def _iter_spec_calls(node):
    """BlockSpec/VMEM calls inside a pallas_call's spec keywords."""
    for name in ("in_specs", "out_specs", "scratch_shapes"):
        val = kw(node.keywords, name)
        if val is None:
            continue
        for sub in ast.walk(val):
            if isinstance(sub, ast.Call) and (_is_blockspec(sub)
                                              or _is_vmem(sub)):
                yield sub


def _kernel_file(ctx) -> bool:
    cfg = ctx.config
    return (ctx.module_path.startswith(cfg.kernel_path_prefix)
            and ctx.module_path.endswith(cfg.kernel_file_suffix))


def _check_env(ctx, extra: dict | None):
    cfg = ctx.config
    seen: set = set()
    for node in ast.walk(ctx.tree):
        if not isinstance(node, ast.Call):
            continue
        d = dotted(node.func)
        if d is not None and d.split(".")[-1] == "pallas_call":
            env = _env_for(ctx, node, extra)
            vmem = 0
            for spec in _iter_spec_calls(node):
                seen.add(spec)
                yield from _alignment_findings(ctx, spec, env)
                vmem += _spec_bytes(spec, env, cfg.default_dtype_bytes)
            if vmem > cfg.vmem_cap_bytes:
                yield ctx.finding(
                    node, "PALLASTILE",
                    f"pallas_call estimated VMEM footprint >= "
                    f"{vmem / 2**20:.1f} MiB (blocks + scratch, lower "
                    f"bound) exceeds the {cfg.vmem_cap_bytes / 2**20:.0f} "
                    f"MiB budget — shrink block shapes or split the kernel")
    # BlockSpec/VMEM literals outside a pallas_call (helpers, constants)
    for node in ast.walk(ctx.tree):
        if isinstance(node, ast.Call) and node not in seen \
                and (_is_blockspec(node) or _is_vmem(node)):
            yield from _alignment_findings(ctx, node,
                                           _env_for(ctx, node, extra))


@register("PALLASTILE", "Pallas block shape off the (8, 128) TPU tile grid "
                        "or pallas_call over the VMEM budget")
def check(ctx):
    if not _kernel_file(ctx):
        return
    yield from _check_env(ctx, None)


@register_project("PALLASTILE")
def project_check(project, targets):
    """Rerun with imported module-level int constants in the environment;
    yield only what the per-file environment could not prove."""
    for path in targets:
        ctx = project.files.get(path)
        if ctx is None or not _kernel_file(ctx):
            continue
        extra = project.int_env(path)
        if not extra:
            continue
        base = {f.key for f in _check_env(ctx, None)}
        for f in _check_env(ctx, extra):
            if f.key not in base:
                yield f
