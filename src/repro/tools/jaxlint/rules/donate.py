"""DONATE: no reads of a buffer after it was donated to a jit.

``jax.jit(..., donate_argnums=...)`` lets XLA reuse the argument's buffers
for the outputs — the engine's state-donating train step and chunked
dispatcher both rely on it to keep the update in-place.  The flip side:
after ``new_state = jit_step(state, batch)``, ``state`` is a deleted
buffer, and touching it raises ``RuntimeError: Array has been deleted``
*only on backends that actually donate* — CPU tests pass, the TPU run
crashes.  (The runner's step-0 checkpoint exists precisely because
``init_state`` is donated on the first dispatch.)

Mechanics, per function scope: find local bindings
``f = jax.jit(g, donate_argnums=...)`` (including conditional
``(0,) if flag else ()`` — treated as "may donate") and
``@functools.partial(jax.jit, donate_argnums=...)`` decorations, record
which *named* variables are passed in donated positions at each call of
``f``, then flag any later read of those names that is not preceded by a
rebinding (``state = f(state)`` rebinding on the call line is the blessed
idiom).

The per-file check only sees donors defined in the same module.  The
*project pass* closes the cross-module half: it collects every donating
jit defined anywhere in the project (``module_donors``), maps them
through each file's imports (``from repro.train.step import train_step``
and ``import repro.train.step as ts`` spellings both resolve), and
re-runs the same line-ordered scan seeded with those imported donors —
reads of state donated to an *imported* step now fire in the caller's
file, which is exactly where the rebinding belongs.  A donating callable
received as a bare function argument remains invisible — keep such
contracts documented at the callee.
"""

from __future__ import annotations

import ast

from repro.tools.jaxlint.astutil import (dotted, is_jit_expr, kw,
                                         literal_ints, unwrap_partial)
from repro.tools.jaxlint.core import register, register_project


def _donating_binding(node: ast.Assign) -> tuple[str, list[int]] | None:
    """``f = jax.jit(g, donate_argnums=...)`` -> ("f", positions)."""
    if len(node.targets) != 1 or not isinstance(node.targets[0], ast.Name):
        return None
    call = node.value
    if not isinstance(call, ast.Call) or not is_jit_expr(call.func):
        return None
    positions = literal_ints(kw(call.keywords, "donate_argnums"))
    if not positions:
        return None
    return node.targets[0].id, positions


def _donating_def(fn) -> list[int]:
    """donate positions of an ``@(functools.partial(jax.)jit, donate_...)``
    decorated function (empty when it doesn't donate)."""
    for dec in fn.decorator_list:
        if isinstance(dec, ast.Call):
            inner, kws = unwrap_partial(dec)
            if inner is not None and is_jit_expr(inner):
                return literal_ints(kw(kws, "donate_argnums"))
            if is_jit_expr(dec.func):
                return literal_ints(kw(dec.keywords, "donate_argnums"))
    return []


def module_donors(tree) -> dict[str, list[int]]:
    """Public donating callables of a module: name -> donate positions
    (``@partial(jax.jit, donate_argnums=...)`` defs and module-level
    ``f = jax.jit(g, donate_argnums=...)`` bindings)."""
    out: dict[str, list[int]] = {}
    for stmt in tree.body:
        if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef)):
            d = _donating_def(stmt)
            if d:
                out[stmt.name] = d
        elif isinstance(stmt, ast.Assign):
            b = _donating_binding(stmt)
            if b is not None:
                out[b[0]] = b[1]
    return out


def _scan_scope(ctx, body, qual: str, extra_donors=None,
                collect_local: bool = True):
    donors: dict[str, list[int]] = dict(extra_donors or {})
    stores: dict[str, list[int]] = {}    # name -> store linenos
    loads: dict[str, list] = {}          # name -> Name load nodes
    donated: list[tuple[str, int, str]] = []  # (var, call line, callee)

    def walk(stmts):
        for st in stmts:
            if isinstance(st, (ast.FunctionDef, ast.AsyncFunctionDef)):
                if collect_local:
                    d = _donating_def(st)
                    if d:
                        donors[st.name] = d
                continue  # nested scopes are scanned separately
            if isinstance(st, ast.Assign) and collect_local:
                b = _donating_binding(st)
                if b is not None:
                    donors[b[0]] = b[1]
            for node in ast.walk(st):
                if isinstance(node, ast.Name):
                    if isinstance(node.ctx, ast.Store):
                        stores.setdefault(node.id, []).append(node.lineno)
                    elif isinstance(node.ctx, ast.Load):
                        loads.setdefault(node.id, []).append(node)
                if isinstance(node, ast.Call):
                    callee = dotted(node.func)
                    if callee in donors:
                        for pos in donors[callee]:
                            if pos < len(node.args) and \
                                    isinstance(node.args[pos], ast.Name):
                                donated.append((node.args[pos].id,
                                                node.lineno, callee))

    walk(body)
    for var, call_line, callee in donated:
        rebinds = stores.get(var, [])
        for load in loads.get(var, []):
            if load.lineno <= call_line:
                continue
            # a rebinding between the donating call (inclusive: the
            # `state = f(state)` idiom) and the read makes the read safe
            if any(call_line <= s <= load.lineno for s in rebinds):
                continue
            where = f" in `{qual}`" if qual else ""
            yield ctx.finding(
                load, "DONATE",
                f"`{var}` is read after being donated to `{callee}` "
                f"(donating call at line {call_line}{where}) — donated "
                f"buffers are deleted on backends that honor donation; "
                f"rebind the result or drop donate_argnums")
            break  # one finding per donated variable per call


@register("DONATE", "argument read after being passed to a "
                    "donate_argnums jit")
def check(ctx):
    yield from _scan_scope(ctx, ctx.tree.body, "")
    for node in ast.walk(ctx.tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            yield from _scan_scope(ctx, node.body,
                                   ctx.qualnames.get(node, node.name))


@register_project("DONATE")
def project_check(project, targets):
    """Cross-module half: rerun the scan seeded with *imported* donors only
    (local collection off — the per-file check already covered those)."""
    for path in targets:
        ctx = project.files.get(path)
        if ctx is None:
            continue
        extra = project.imported_donors(path)
        if not extra:
            continue
        yield from _scan_scope(ctx, ctx.tree.body, "", extra_donors=extra,
                               collect_local=False)
        for node in ast.walk(ctx.tree):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                yield from _scan_scope(
                    ctx, node.body, ctx.qualnames.get(node, node.name),
                    extra_donors=extra, collect_local=False)
