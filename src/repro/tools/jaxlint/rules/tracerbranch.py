"""TRACERBRANCH: no Python control flow on traced values.

Inside a function that jax traces (``jax.jit`` target or ``pl.pallas_call``
kernel), a Python ``if``/``while`` on a traced value raises
``TracerBoolConversionError`` at trace time at best — and at worst, when
the value is concrete on CPU test rigs but traced on the TPU path (e.g.
under the Pallas interpreter), it silently bakes one branch into the
compiled program and recompiles per value.  ``len(tracer)`` is the same
hazard through ``__len__``.

Mechanics: module-local traced-function discovery (see
``astutil.traced_functions``), then a conservative forward taint pass —
parameters (minus statics) are tainted, assignments propagate taint, and
``.shape`` / ``.ndim`` / ``.dtype`` / ``.size`` accesses *clear* it (shapes
are static under tracing, so ``if x.shape[0] > 1:`` is fine).  Nested
function defs (scan bodies, ``pl.when`` callees) inherit the outer taint
plus their own parameters.
"""

from __future__ import annotations

import ast

from repro.tools.jaxlint.astutil import all_params, traced_functions
from repro.tools.jaxlint.core import register

#: attribute accesses that yield static (non-traced) values
NEUTRAL_ATTRS = frozenset({"shape", "ndim", "dtype", "size"})


def _target_names(node) -> set[str]:
    """Names bound by an assignment target.  ``h_s[l] = h`` taints the
    container ``h_s``, never the index ``l`` (which stays whatever it was)."""
    out: set[str] = set()
    if isinstance(node, ast.Name):
        out.add(node.id)
    elif isinstance(node, (ast.Tuple, ast.List)):
        for elt in node.elts:
            out |= _target_names(elt)
    elif isinstance(node, ast.Starred):
        out |= _target_names(node.value)
    elif isinstance(node, (ast.Subscript, ast.Attribute)):
        out |= _target_names(node.value)
    return out


class _FnScan:
    def __init__(self, ctx, fn_name: str):
        self.ctx = ctx
        self.fn_name = fn_name
        self.findings: list = []

    # -- expressions -------------------------------------------------------

    def expr_taint(self, node, tainted, hits: list) -> bool:
        """True when the expression's value carries taint; records len()
        and if-expression findings as side effects."""
        if node is None:
            return False
        if isinstance(node, ast.Name):
            if node.id in tainted:
                hits.append(node.id)
                return True
            return False
        if isinstance(node, ast.Attribute) and node.attr in NEUTRAL_ATTRS:
            return False  # static under tracing; do not descend
        if isinstance(node, ast.Call) and isinstance(node.func, ast.Name) \
                and node.func.id == "len":
            lh: list = []
            t = False
            for a in node.args:
                t = self.expr_taint(a, tainted, lh) or t
            if t:
                hits.extend(lh)
                self.findings.append(self.ctx.finding(
                    node, "TRACERBRANCH",
                    f"len() of traced value `{lh[0]}` in traced "
                    f"`{self.fn_name}` — use a static shape "
                    f"(`{lh[0]}.shape[0]`) instead"))
            return t
        if isinstance(node, ast.IfExp):
            th: list = []
            if self.expr_taint(node.test, tainted, th):
                self.findings.append(self.ctx.finding(
                    node, "TRACERBRANCH",
                    f"conditional expression on traced value `{th[0]}` in "
                    f"traced `{self.fn_name}` — use jnp.where/lax.select"))
            t = self.expr_taint(node.body, tainted, hits)
            t = self.expr_taint(node.orelse, tainted, hits) or t
            return t or bool(th)
        t = False
        for child in ast.iter_child_nodes(node):
            t = self.expr_taint(child, tainted, hits) or t
        return t

    # -- statements --------------------------------------------------------

    def run(self, stmts, tainted: set) -> None:
        for st in stmts:
            self.stmt(st, tainted)

    def stmt(self, st, tainted: set) -> None:
        if isinstance(st, (ast.Assign, ast.AugAssign, ast.AnnAssign)):
            if st.value is not None and \
                    self.expr_taint(st.value, tainted, []):
                targets = st.targets if isinstance(st, ast.Assign) \
                    else [st.target]
                for tgt in targets:
                    tainted |= _target_names(tgt)
        elif isinstance(st, (ast.If, ast.While)):
            hits: list = []
            if self.expr_taint(st.test, tainted, hits):
                kind = "if" if isinstance(st, ast.If) else "while"
                self.findings.append(self.ctx.finding(
                    st, "TRACERBRANCH",
                    f"Python `{kind}` branches on traced value `{hits[0]}` "
                    f"in traced `{self.fn_name}` — use lax.cond/select, or "
                    f"hoist it to a static argument"))
            self.run(st.body, tainted)
            self.run(st.orelse, tainted)
        elif isinstance(st, ast.For):
            if self.expr_taint(st.iter, tainted, []):
                tainted |= _target_names(st.target)
            self.run(st.body, tainted)
            self.run(st.orelse, tainted)
        elif isinstance(st, (ast.FunctionDef, ast.AsyncFunctionDef)):
            # nested defs trace under the same jit: inherit taint + own args
            inner = set(tainted) | set(all_params(st))
            inner.discard("self")
            self.run(st.body, inner)
        else:
            for _field, value in ast.iter_fields(st):
                if isinstance(value, list):
                    for v in value:
                        if isinstance(v, ast.stmt):
                            self.stmt(v, tainted)
                        elif isinstance(v, ast.expr):
                            self.expr_taint(v, tainted, [])
                elif isinstance(value, ast.stmt):
                    self.stmt(value, tainted)
                elif isinstance(value, ast.expr):
                    self.expr_taint(value, tainted, [])


@register("TRACERBRANCH", "Python if/while/len() on values traced under "
                          "jax.jit or pl.pallas_call")
def check(ctx):
    for fn, tainted in traced_functions(ctx.tree).items():
        scan = _FnScan(ctx, ctx.qualnames.get(fn, fn.name))
        scan.run(fn.body, set(tainted))
        yield from scan.findings
