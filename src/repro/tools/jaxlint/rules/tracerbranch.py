"""TRACERBRANCH: no Python control flow on traced values.

Inside a function that jax traces (``jax.jit`` target or ``pl.pallas_call``
kernel), a Python ``if``/``while`` on a traced value raises
``TracerBoolConversionError`` at trace time at best — and at worst, when
the value is concrete on CPU test rigs but traced on the TPU path (e.g.
under the Pallas interpreter), it silently bakes one branch into the
compiled program and recompiles per value.  ``len(tracer)`` is the same
hazard through ``__len__``.

Mechanics: module-local traced-function discovery (see
``astutil.traced_functions``), then a conservative forward taint pass —
parameters (minus statics) are tainted, assignments propagate taint, and
``.shape`` / ``.ndim`` / ``.dtype`` / ``.size`` accesses *clear* it (shapes
are static under tracing, so ``if x.shape[0] > 1:`` is fine).  Nested
function defs (scan bodies, ``pl.when`` callees) inherit the outer taint
plus their own parameters.

The *project pass* makes the taint interprocedural: when a traced
function passes a tainted value into a call that resolves to a helper
anywhere in the project (``train/step`` handing its loop counter to
``data/pipeline.batch_at``), the helper's body is scanned with those
parameters tainted, recursively up to ``config.max_call_depth`` hops.
Findings land at the *caller's* call site (the file whose analysis
produced them — the cache-attribution invariant), with the helper's own
location threaded through the message.  Helpers that are themselves
traced in their own module are skipped: their file's per-file run already
covers them.
"""

from __future__ import annotations

import ast

from repro.tools.jaxlint.astutil import (all_params, positional_params,
                                         traced_functions)
from repro.tools.jaxlint.core import Finding, register, register_project

#: attribute accesses that yield static (non-traced) values
NEUTRAL_ATTRS = frozenset({"shape", "ndim", "dtype", "size"})


def _target_names(node) -> set[str]:
    """Names bound by an assignment target.  ``h_s[l] = h`` taints the
    container ``h_s``, never the index ``l`` (which stays whatever it was)."""
    out: set[str] = set()
    if isinstance(node, ast.Name):
        out.add(node.id)
    elif isinstance(node, (ast.Tuple, ast.List)):
        for elt in node.elts:
            out |= _target_names(elt)
    elif isinstance(node, ast.Starred):
        out |= _target_names(node.value)
    elif isinstance(node, (ast.Subscript, ast.Attribute)):
        out |= _target_names(node.value)
    return out


class _FnScan:
    def __init__(self, ctx, fn_name: str):
        self.ctx = ctx
        self.fn_name = fn_name
        self.findings: list = []

    # -- expressions -------------------------------------------------------

    def expr_taint(self, node, tainted, hits: list) -> bool:
        """True when the expression's value carries taint; records len()
        and if-expression findings as side effects."""
        if node is None:
            return False
        if isinstance(node, ast.Name):
            if node.id in tainted:
                hits.append(node.id)
                return True
            return False
        if isinstance(node, ast.Attribute) and node.attr in NEUTRAL_ATTRS:
            return False  # static under tracing; do not descend
        if isinstance(node, ast.Call) and isinstance(node.func, ast.Name) \
                and node.func.id == "len":
            lh: list = []
            t = False
            for a in node.args:
                t = self.expr_taint(a, tainted, lh) or t
            if t:
                hits.extend(lh)
                self.findings.append(self.ctx.finding(
                    node, "TRACERBRANCH",
                    f"len() of traced value `{lh[0]}` in traced "
                    f"`{self.fn_name}` — use a static shape "
                    f"(`{lh[0]}.shape[0]`) instead"))
            return t
        if isinstance(node, ast.IfExp):
            th: list = []
            if self.expr_taint(node.test, tainted, th):
                self.findings.append(self.ctx.finding(
                    node, "TRACERBRANCH",
                    f"conditional expression on traced value `{th[0]}` in "
                    f"traced `{self.fn_name}` — use jnp.where/lax.select"))
            t = self.expr_taint(node.body, tainted, hits)
            t = self.expr_taint(node.orelse, tainted, hits) or t
            return t or bool(th)
        t = False
        for child in ast.iter_child_nodes(node):
            t = self.expr_taint(child, tainted, hits) or t
        return t

    # -- statements --------------------------------------------------------

    def run(self, stmts, tainted: set) -> None:
        for st in stmts:
            self.stmt(st, tainted)

    def stmt(self, st, tainted: set) -> None:
        if isinstance(st, (ast.Assign, ast.AugAssign, ast.AnnAssign)):
            if st.value is not None and \
                    self.expr_taint(st.value, tainted, []):
                targets = st.targets if isinstance(st, ast.Assign) \
                    else [st.target]
                for tgt in targets:
                    tainted |= _target_names(tgt)
        elif isinstance(st, (ast.If, ast.While)):
            hits: list = []
            if self.expr_taint(st.test, tainted, hits):
                kind = "if" if isinstance(st, ast.If) else "while"
                self.findings.append(self.ctx.finding(
                    st, "TRACERBRANCH",
                    f"Python `{kind}` branches on traced value `{hits[0]}` "
                    f"in traced `{self.fn_name}` — use lax.cond/select, or "
                    f"hoist it to a static argument"))
            self.run(st.body, tainted)
            self.run(st.orelse, tainted)
        elif isinstance(st, ast.For):
            if self.expr_taint(st.iter, tainted, []):
                tainted |= _target_names(st.target)
            self.run(st.body, tainted)
            self.run(st.orelse, tainted)
        elif isinstance(st, (ast.FunctionDef, ast.AsyncFunctionDef)):
            # nested defs trace under the same jit: inherit taint + own args
            inner = set(tainted) | set(all_params(st))
            inner.discard("self")
            self.run(st.body, inner)
        else:
            for _field, value in ast.iter_fields(st):
                if isinstance(value, list):
                    for v in value:
                        if isinstance(v, ast.stmt):
                            self.stmt(v, tainted)
                        elif isinstance(v, ast.expr):
                            self.expr_taint(v, tainted, [])
                elif isinstance(value, ast.stmt):
                    self.stmt(value, tainted)
                elif isinstance(value, ast.expr):
                    self.expr_taint(value, tainted, [])


@register("TRACERBRANCH", "Python if/while/len() on values traced under "
                          "jax.jit or pl.pallas_call")
def check(ctx):
    for fn, tainted in traced_functions(ctx.tree).items():
        scan = _FnScan(ctx, ctx.qualnames.get(fn, fn.name))
        scan.run(fn.body, set(tainted))
        yield from scan.findings


# -- interprocedural project pass -------------------------------------------

class _CallTaint(_FnScan):
    """Same scan, but also records every call with the taint set live at
    the moment it is reached (nested-def calls carry the inner taint)."""

    def __init__(self, ctx, fn_name: str):
        super().__init__(ctx, fn_name)
        self.calls: list = []

    def expr_taint(self, node, tainted, hits: list) -> bool:
        if isinstance(node, ast.Call):
            self.calls.append((node, set(tainted)))
        return super().expr_taint(node, tainted, hits)


def _expr_has_taint(node, tainted) -> bool:
    if isinstance(node, ast.Name):
        return node.id in tainted
    if isinstance(node, ast.Attribute) and node.attr in NEUTRAL_ATTRS:
        return False
    return any(_expr_has_taint(c, tainted)
               for c in ast.iter_child_nodes(node))


def _tainted_params(call: ast.Call, tainted, cfn) -> frozenset:
    """Callee parameter names receiving a tainted argument at this call."""
    params = positional_params(cfn)
    tset = set()
    for i, a in enumerate(call.args):
        if i < len(params) and _expr_has_taint(a, tainted):
            tset.add(params[i])
    for k in call.keywords:
        if k.arg and _expr_has_taint(k.value, tainted):
            tset.add(k.arg)
    tset.discard("self")
    return frozenset(tset)


def _flow(project, path: str, fn, tparams, depth, seen, traced_in) -> list:
    """Findings inside ``fn`` (attributed to ``path``) when ``tparams``
    arrive traced, plus deeper flows re-attributed to fn's call sites."""
    ctx = project.files[path]
    scan = _CallTaint(ctx, ctx.qualnames.get(fn, fn.name))
    t = set(tparams)
    scan.run(fn.body, t)
    return list(scan.findings) + _outgoing(project, path, scan.calls,
                                           depth, seen, traced_in)


def _outgoing(project, path: str, calls, depth, seen, traced_in) -> list:
    if depth > project.config.max_call_depth:
        return []
    out: list = []
    for call, tsnap in calls:
        if not tsnap:
            continue
        for cpath, cfn in project.resolve_call(path, call):
            if cfn in traced_in(cpath):
                continue  # traced in its own file: covered per-file there
            tset = _tainted_params(call, tsnap, cfn)
            key = (id(cfn), tset)
            if not tset or key in seen:
                continue
            seen.add(key)
            cqual = project.files[cpath].qualnames.get(cfn, cfn.name)
            for f in _flow(project, cpath, cfn, tset, depth + 1, seen,
                           traced_in):
                out.append(Finding(
                    path=path, line=call.lineno, rule="TRACERBRANCH",
                    message=f"traced value flows into `{cqual}` "
                            f"({f.path}:{f.line}): {f.message}"))
    return out


@register_project("TRACERBRANCH")
def project_check(project, targets):
    traced_cache: dict = {}

    def traced_in(p: str) -> dict:
        if p not in traced_cache:
            traced_cache[p] = traced_functions(project.files[p].tree)
        return traced_cache[p]

    for path in targets:
        ctx = project.files.get(path)
        if ctx is None:
            continue
        for fn, tainted in traced_in(path).items():
            scan = _CallTaint(ctx, ctx.qualnames.get(fn, fn.name))
            scan.run(fn.body, set(tainted))
            # the per-file check already reported scan.findings; only the
            # cross-call flows are new
            yield from _outgoing(project, path, scan.calls, 1, set(),
                                 traced_in)
