"""SHARD: batch-bearing entry points must route through ``dist.shard``.

The repo's scaling contract (ROADMAP, PR 1) is logical-axis sharding: model
and engine code annotates batch-bearing arrays with
``dist.sharding.shard(x, "batch", ...)``, which degrades to identity
mesh-less, so one code path serves unit tests and data-parallel production.
An entry point that takes a batch and never routes it through ``shard``
works fine on one device and silently stops scaling on a mesh — the same
class of regression PR 3 fixed by annotating the serving forward.

Granularity differs by mode.  The *per-file* check (single-file lints,
no project graph) is the degraded approximation: a ``serve/``/``train/``
module that calls ``shard`` anywhere is considered to uphold the
contract.  The *project pass replaces it* with the real semantics: each
batch-bearing entry point must have a ``shard`` call somewhere on its
*reachable* call chain — resolved across modules — so a module whose only
``shard`` sits in a function the entry point never calls now fires
(invisible to v1), and an entry point that delegates sharding to an
imported forward is now accepted without a pragma.  Audited entry
points: top-level public functions, public methods of public classes,
and functions nested one level inside public factories (the ``make_*``
pattern returns the real entry point).  Entry points whose sharding
happens behind a callable the resolver cannot follow (a stored function
attribute, a callback argument) carry a pragma naming the delegate.
"""

from __future__ import annotations

import ast

from repro.tools.jaxlint.core import register, register_project


def _calls_shard(root) -> bool:
    for node in ast.walk(root):
        if isinstance(node, ast.Call):
            f = node.func
            if (isinstance(f, ast.Name) and f.id == "shard") or \
                    (isinstance(f, ast.Attribute) and f.attr == "shard"):
                return True
    return False


def _module_calls_shard(tree: ast.Module) -> bool:
    return _calls_shard(tree)


def _entry_points(tree: ast.Module):
    """Yield candidate entry-point FunctionDefs (see module docstring)."""
    for stmt in tree.body:
        if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef)):
            if stmt.name.startswith("_"):
                continue
            yield stmt
            for sub in stmt.body:
                if isinstance(sub, (ast.FunctionDef, ast.AsyncFunctionDef)):
                    yield sub
        elif isinstance(stmt, ast.ClassDef) and not stmt.name.startswith("_"):
            for sub in stmt.body:
                if isinstance(sub, (ast.FunctionDef, ast.AsyncFunctionDef)) \
                        and not sub.name.startswith("_"):
                    yield sub


@register("SHARD", "batch-bearing public entry point in serve/ or train/ "
                   "never routes inputs through dist.shard")
def check(ctx):
    path = ctx.module_path
    if path.endswith("__init__.py") or not any(
            path.startswith(p) for p in ctx.config.shard_module_prefixes):
        return
    if _module_calls_shard(ctx.tree):
        return
    batchy = set(ctx.config.batch_param_names)
    for fn in _entry_points(ctx.tree):
        a = fn.args
        params = [p.arg for p in (*a.posonlyargs, *a.args, *a.kwonlyargs)]
        hit = next((p for p in params if p in batchy), None)
        if hit is None:
            continue
        qual = ctx.qualnames.get(fn, fn.name)
        yield ctx.finding(
            fn, "SHARD",
            f"batch-bearing entry point `{qual}({hit})` — module never "
            f"routes inputs through dist.sharding.shard; annotate the "
            f"batch axis or carry a pragma naming where sharding happens")


def _batch_param(fn, batchy) -> str | None:
    a = fn.args
    params = [p.arg for p in (*a.posonlyargs, *a.args, *a.kwonlyargs)]
    return next((p for p in params if p in batchy), None)


@register_project("SHARD", replaces_file=True)
def project_check(project, targets):
    """Replaces the per-file check: an entry point upholds the contract iff
    a ``shard`` call is *reachable* from it through resolved calls (any
    module) — not merely present somewhere in the same file."""
    cfg = project.config
    batchy = set(cfg.batch_param_names)
    for path in targets:
        ctx = project.files.get(path)
        if ctx is None:
            continue
        mpath = ctx.module_path
        if mpath.endswith("__init__.py") or not any(
                mpath.startswith(p) for p in cfg.shard_module_prefixes):
            continue
        for fn in _entry_points(ctx.tree):
            hit = _batch_param(fn, batchy)
            if hit is None:
                continue
            if any(_calls_shard(f)
                   for _p, f in project.reachable(path, fn)):
                continue
            qual = ctx.qualnames.get(fn, fn.name)
            yield ctx.finding(
                fn, "SHARD",
                f"batch-bearing entry point `{qual}({hit})` — no "
                f"dist.sharding.shard call is reachable from it (calls "
                f"resolved across modules); annotate the batch axis or "
                f"carry a pragma naming the unresolvable delegate")
