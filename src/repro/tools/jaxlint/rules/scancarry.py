"""SCANCARRY: a scan/while/fori body whose carry-out shape can't match in.

``lax.scan``/``lax.while_loop``/``lax.fori_loop`` require the carry
pytree structure to be identical between input and output — a body that
returns a different tuple arity or different dict keys fails at trace
time with an unhelpful tree-structure error, and *only on the code path
that actually traces it* (a chunked-dispatch run, not the stepwise unit
test).  This is the failure mode of every ``TrainState`` extension so
far: add an ``aux`` slot to the carry tuple, forget to thread it through
the scan body's return, and the error surfaces two layers away in the
engine.

The rule statically compares every carry structure it can prove:

* the ``init`` argument when it is a tuple/list/dict literal (or a local
  name bound to one),
* the body's unpacking of its carry parameter (``a, b = carry``),
* each ``return`` — for scan, the first element of the returned pair;
  for while/fori, the returned expression — again resolving one level of
  local name bindings.

Any two provable structures that disagree (kind, tuple arity, dict key
set) fire.  Unknown structures stay silent: the rule errs toward missing
a mismatch over flagging a correct body.  Bodies reached through
``functools.partial(f, bound, ...)`` shift the carry parameter index past
the bound arguments.
"""

from __future__ import annotations

import ast

from repro.tools.jaxlint.astutil import dotted, kw, positional_params
from repro.tools.jaxlint.core import register

#: loop combinator -> (body arg index, body kw, init arg index, init kw,
#:                     carry param index within the body)
COMBINATORS = {
    "scan": (0, "f", 1, "init", 0),
    "while_loop": (1, "body_fun", 2, "init_val", 0),
    "fori_loop": (2, "body_fun", 3, "init_val", 1),
}


def _combinator_of(call: ast.Call, lax_imports) -> str | None:
    d = dotted(call.func)
    if d is None:
        return None
    parts = d.split(".")
    if len(parts) == 1:
        return lax_imports.get(d)
    if parts[-1] in COMBINATORS and parts[-2] == "lax":
        return parts[-1]
    return None


def _lax_imports(tree) -> dict[str, str]:
    """Bare names bound to lax loop combinators (``from jax.lax import
    scan``)."""
    out: dict[str, str] = {}
    for node in ast.walk(tree):
        if isinstance(node, ast.ImportFrom) and node.level == 0 \
                and node.module in ("jax.lax", "lax"):
            for a in node.names:
                if a.name in COMBINATORS:
                    out[a.asname or a.name] = a.name
    return out


# -- provable carry structures ---------------------------------------------

def _struct_of(node, env: dict) -> tuple | None:
    """("tuple", arity) | ("dict", frozenset keys) | None (unknown)."""
    if isinstance(node, (ast.Tuple, ast.List)):
        if any(isinstance(e, ast.Starred) for e in node.elts):
            return None
        return ("tuple", len(node.elts))
    if isinstance(node, ast.Dict):
        if any(k is None for k in node.keys):
            return None
        keys = []
        for k in node.keys:
            if not (isinstance(k, ast.Constant) and isinstance(k.value, str)):
                return None
            keys.append(k.value)
        return ("dict", frozenset(keys))
    if isinstance(node, ast.Name):
        return env.get(node.id)
    return None


def _local_structs(stmts) -> dict:
    """name -> provable structure from simple assignments in a body
    (last assignment wins; best-effort straight-line view)."""
    env: dict = {}
    for node in ast.walk(ast.Module(body=list(stmts), type_ignores=[])):
        if isinstance(node, ast.Assign) and len(node.targets) == 1 \
                and isinstance(node.targets[0], ast.Name):
            s = _struct_of(node.value, env)
            if s is not None:
                env[node.targets[0].id] = s
    return env


def _describe(struct) -> str:
    kind, detail = struct
    if kind == "tuple":
        return f"a {detail}-tuple"
    return f"a dict with keys {{{', '.join(sorted(detail))}}}"


def _resolve_body(call: ast.Call, combo: str, by_name: dict):
    """(body node: FunctionDef|Lambda, carry param shift) or (None, 0)."""
    idx, kword, _i, _ik, _c = COMBINATORS[combo]
    node = call.args[idx] if idx < len(call.args) else kw(call.keywords, kword)
    if node is None:
        return None, 0
    shift = 0
    if isinstance(node, ast.Call):
        d = dotted(node.func)
        if d is not None and d.split(".")[-1] == "partial" and node.args:
            shift = len(node.args) - 1
            node = node.args[0]
        else:
            return None, 0
    if isinstance(node, ast.Lambda):
        return node, shift
    if isinstance(node, ast.Name):
        fn = by_name.get(node.id)
        return fn, shift
    return None, 0


def _functions_by_name(tree) -> dict:
    out: dict = {}
    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            # last definition wins; name collisions make resolution
            # ambiguous, so drop colliders to stay FP-averse
            out[node.name] = None if node.name in out else node
    return out


def _carry_structs(body, combo: str, shift: int):
    """Yield (label, struct, node) for each provable carry structure of
    ``body``: parameter unpack and returns."""
    _bi, _bk, _ii, _ik, carry_idx = COMBINATORS[combo]
    params = positional_params(body) if not isinstance(body, ast.Lambda) \
        else [a.arg for a in body.args.args]
    idx = carry_idx + shift
    carry_param = params[idx] if idx < len(params) else None

    if isinstance(body, ast.Lambda):
        env: dict = {}
        out = body.body
        if combo == "scan":
            if isinstance(out, ast.Tuple) and len(out.elts) == 2:
                s = _struct_of(out.elts[0], env)
                if s is not None:
                    yield ("returned carry", s, out)
        else:
            s = _struct_of(out, env)
            if s is not None:
                yield ("returned carry", s, out)
        return

    env = _local_structs(body.body)
    if carry_param is not None:
        for node in ast.walk(body):
            if isinstance(node, ast.Assign) and len(node.targets) == 1 \
                    and isinstance(node.targets[0], (ast.Tuple, ast.List)) \
                    and isinstance(node.value, ast.Name) \
                    and node.value.id == carry_param:
                tgt = node.targets[0]
                if not any(isinstance(e, ast.Starred) for e in tgt.elts):
                    yield ("carry unpacked in the body",
                           ("tuple", len(tgt.elts)), node)
                break
    for node in ast.walk(body):
        if not isinstance(node, ast.Return) or node.value is None:
            continue
        if any(isinstance(p, (ast.FunctionDef, ast.AsyncFunctionDef,
                              ast.Lambda))
               for p in _walk_parents(body, node)):
            continue  # returns of nested defs are not the body's carry
        if combo == "scan":
            val = node.value
            if isinstance(val, ast.Tuple) and len(val.elts) == 2:
                s = _struct_of(val.elts[0], env)
                if s is not None:
                    yield ("returned carry", s, node)
        else:
            s = _struct_of(node.value, env)
            if s is not None:
                yield ("returned carry", s, node)


def _walk_parents(root, target):
    """Ancestor chain of ``target`` inside ``root`` (small local search —
    bodies are short; avoids needing the file-level parent map)."""
    chain: list = []

    def visit(node, stack):
        if node is target:
            chain.extend(stack)
            return True
        return any(visit(c, stack + [node])
                   for c in ast.iter_child_nodes(node))

    visit(root, [])
    return chain[1:]  # drop root itself


@register("SCANCARRY", "lax.scan/while_loop/fori_loop body whose carry-out "
                       "structure provably differs from carry-in")
def check(ctx):
    lax_imports = _lax_imports(ctx.tree)
    by_name = _functions_by_name(ctx.tree)
    for node in ast.walk(ctx.tree):
        if not isinstance(node, ast.Call):
            continue
        combo = _combinator_of(node, lax_imports)
        if combo is None:
            continue
        _bi, _bk, init_idx, init_kw, _c = COMBINATORS[combo]
        init = node.args[init_idx] if init_idx < len(node.args) \
            else kw(node.keywords, init_kw)
        structs: list = []
        if init is not None:
            fn = ctx.enclosing_function(node)
            env = _local_structs(fn.body) if fn is not None \
                else _local_structs(ctx.tree.body)
            s = _struct_of(init, env)
            if s is not None:
                structs.append((f"`{combo}` init", s, node))
        body, shift = _resolve_body(node, combo, by_name)
        if body is not None:
            structs.extend(_carry_structs(body, combo, shift))
        for i in range(1, len(structs)):
            label0, s0, _n0 = structs[0]
            label, s, where = structs[i]
            if s != s0:
                yield ctx.finding(
                    where if where.lineno else node, "SCANCARRY",
                    f"carry structure mismatch in `{combo}`: {label0} is "
                    f"{_describe(s0)} but {label} is {_describe(s)} — the "
                    f"carry pytree must be identical in and out or the "
                    f"trace fails (dropped slot / extra slot / renamed "
                    f"key)")
