"""RECOMPILE: jit re-trace hazards — the cost the bucket system exists to kill.

``jax.jit``'s compilation cache is keyed on the *function object* plus the
static argument values.  Three patterns silently defeat it:

* **jit inside a loop** — ``jit(f)`` (or ``partial(jit, ...)``, or an
  ``@jit``-decorated def) evaluated in a ``for``/``while`` body creates a
  fresh traced callable every iteration: trace + lower + compile per step,
  exactly the dispatch overhead the serve bucket system and the chunked
  trainer were built to amortize away.
* **immediately-invoked jit** — ``jit(fn)(x)`` inside a function body
  builds a new wrapper per call; the cache behind it never gets a second
  hit through the same object.  Bind once (module level or a factory) and
  call the binding.
* **loop variable in a static position** — calling a jit with
  ``static_argnums``/``static_argnames`` and feeding the loop variable
  into a static slot retraces once per distinct value: the per-call
  Python scalar the serve path instead rounds into a fixed bucket set.

Factories that build a jit once and return it (``make_*``) are the
blessed idiom and do not fire — only jit *evaluation* under a loop, the
immediate-invoke shape, and static-slot loop feeds do.
"""

from __future__ import annotations

import ast

from repro.tools.jaxlint.astutil import (is_jit_expr, kw, literal_ints,
                                         literal_strings, positional_params,
                                         unwrap_partial)
from repro.tools.jaxlint.core import register


def _is_jit_call(call: ast.Call) -> bool:
    """``jit(...)`` / ``jax.jit(...)`` / ``partial(jax.jit, ...)``."""
    if is_jit_expr(call.func):
        return True
    inner, _ = unwrap_partial(call)
    return inner is not None and is_jit_expr(inner)


def _jit_decorator(fn) -> ast.AST | None:
    for dec in fn.decorator_list:
        if is_jit_expr(dec):
            return dec
        if isinstance(dec, ast.Call):
            inner, _ = unwrap_partial(dec)
            if (inner is not None and is_jit_expr(inner)) \
                    or is_jit_expr(dec.func):
                return dec
    return None


def _static_positions(keywords, fn=None) -> tuple[list[int], list[str]]:
    nums = literal_ints(kw(keywords, "static_argnums"))
    names = literal_strings(kw(keywords, "static_argnames"))
    if fn is not None and nums:
        pos = positional_params(fn)
        names = names + [pos[i] for i in nums if 0 <= i < len(pos)]
    return nums, names


def _static_bindings(tree) -> dict[str, tuple[list[int], list[str]]]:
    """Callable name -> (static argnums, static argnames) for jits with
    static arguments: ``f = jax.jit(g, static_argnums=...)`` bindings and
    ``@partial(jax.jit, static_argnames=...)`` decorated defs."""
    out: dict[str, tuple[list[int], list[str]]] = {}
    for node in ast.walk(tree):
        if isinstance(node, ast.Assign) and len(node.targets) == 1 \
                and isinstance(node.targets[0], ast.Name) \
                and isinstance(node.value, ast.Call) \
                and is_jit_expr(node.value.func):
            nums, names = _static_positions(node.value.keywords)
            if nums or names:
                out[node.targets[0].id] = (nums, names)
        elif isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            dec = _jit_decorator(node)
            if isinstance(dec, ast.Call):
                _inner, kws = unwrap_partial(dec)
                kws = kws or dec.keywords
                nums, names = _static_positions(kws, node)
                if nums or names:
                    out[node.name] = (nums, names)
    return out


class _Scan:
    def __init__(self, ctx):
        self.ctx = ctx
        self.statics = _static_bindings(ctx.tree)
        self.findings: list = []

    def _where(self, node) -> str:
        qual = self.ctx.qualname_of(node)
        return f" in `{qual}`" if qual else ""

    def visit(self, node, loops: tuple) -> None:
        """``loops`` is the stack of active For-target name sets (a While
        contributes an empty set — it marks loop depth, not targets)."""
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            dec = _jit_decorator(node)
            if dec is not None and loops:
                self.findings.append(self.ctx.finding(
                    node, "RECOMPILE",
                    f"`@jit`-decorated `{node.name}` defined inside a loop"
                    f"{self._where(node)} — a fresh traced callable every "
                    f"iteration; hoist the definition out of the loop"))
            # a def body is a new deferred scope: loop stack does not
            # carry in (the body runs when called, not per iteration)
            for child in ast.iter_child_nodes(node):
                self.visit(child, ())
            return
        if isinstance(node, (ast.For, ast.AsyncFor, ast.While)):
            targets = set()
            if isinstance(node, (ast.For, ast.AsyncFor)):
                for sub in ast.walk(node.target):
                    if isinstance(sub, ast.Name):
                        targets.add(sub.id)
                self.visit(node.iter, loops)
            else:
                self.visit(node.test, loops)
            inner = loops + (targets,)
            for st in node.body:
                self.visit(st, inner)
            for st in node.orelse:
                self.visit(st, loops)
            return
        if isinstance(node, ast.Call):
            self._check_call(node, loops)
        for child in ast.iter_child_nodes(node):
            self.visit(child, loops)

    def _check_call(self, call: ast.Call, loops: tuple) -> None:
        if _is_jit_call(call) and loops:
            self.findings.append(self.ctx.finding(
                call, "RECOMPILE",
                f"jit evaluated inside a loop{self._where(call)} — "
                f"trace + compile every iteration; bind the jitted "
                f"callable once outside the loop"))
        # immediately-invoked: jit(f)(x) — the outer call's func is a jit
        if isinstance(call.func, ast.Call) and _is_jit_call(call.func) \
                and not loops and self.ctx.enclosing_function(call) is not None:
            self.findings.append(self.ctx.finding(
                call, "RECOMPILE",
                f"immediately-invoked jit{self._where(call)} — a fresh "
                f"wrapper (and trace) per call; bind `jit(...)` once and "
                f"reuse it"))
        # loop variable feeding a static position of a known jit
        if loops and isinstance(call.func, ast.Name) \
                and call.func.id in self.statics:
            nums, names = self.statics[call.func.id]
            active = set().union(*loops) if loops else set()
            hits = []
            for i in nums:
                if i < len(call.args) and isinstance(call.args[i], ast.Name) \
                        and call.args[i].id in active:
                    hits.append(call.args[i].id)
            for k in call.keywords:
                if k.arg in names and isinstance(k.value, ast.Name) \
                        and k.value.id in active:
                    hits.append(k.value.id)
            if hits:
                self.findings.append(self.ctx.finding(
                    call, "RECOMPILE",
                    f"loop variable `{hits[0]}` feeds a static argument of "
                    f"jitted `{call.func.id}`{self._where(call)} — one "
                    f"retrace per distinct value; make it traced, or bucket "
                    f"it the way the serve executor pads to fixed shapes"))


@register("RECOMPILE", "jit re-trace hazard: jit built inside a loop, "
                       "immediately-invoked jit, or a loop variable in a "
                       "static argument position")
def check(ctx):
    scan = _Scan(ctx)
    for st in ctx.tree.body:
        scan.visit(st, ())
    yield from scan.findings
