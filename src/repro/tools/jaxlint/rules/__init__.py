"""Rule modules self-register on import (see ``core.register``)."""

from repro.tools.jaxlint.rules import (donate, hostsync, pallastile,  # noqa: F401
                                       shard, tracerbranch)
