"""Rule modules self-register on import (see ``core.register``)."""

from repro.tools.jaxlint.rules import (donate, hostsync, keyreuse,  # noqa: F401
                                       pallastile, recompile, scancarry,
                                       shard, tracerbranch)
