"""KEYREUSE: a PRNG key consumed twice without an intervening split/fold_in.

jax's threefry keys are *consumed*, not advanced: two calls of
``jax.random.normal(key, ...)`` with the same key return the same bits,
and two ``split(key)`` calls return the same children.  In this repo the
stakes are concrete — the chunked-training batch synthesis and the EPG
dictionary generation both derive per-step keys from one root; a silent
reuse correlates batches (or dictionary noise draws) and quietly degrades
training without any error.  The blessed idioms are ``k1, k2 =
jax.random.split(key)`` and ``batch_key = jax.random.fold_in(key, step)``.

The rule is line-ordered per scope: a *consumption* is a key variable
passed (first positional or ``key=`` keyword) to a ``jax.random``
sampler or to ``split``; ``fold_in`` is a *derivation* (same parent with
different data is exactly its point) and does not count.  Two
consumptions of one binding without an intervening rebinding of that name
fire, as does a single consumption inside a ``for``/``while`` body (or a
comprehension) when the key is bound outside the loop and never rebound
per iteration — every pass draws with the same key.  Recognition covers
``jax.random.X`` / ``from jax import random`` / ``import jax.random as
jr`` / ``from jax.random import X`` spellings; numpy's stateful
``np.random`` is explicitly excluded (reuse is not a hazard there).
"""

from __future__ import annotations

import ast

from repro.tools.jaxlint.astutil import dotted
from repro.tools.jaxlint.core import register

#: jax.random sampling primitives that consume their key
SAMPLERS = frozenset({
    "ball", "bernoulli", "beta", "binomial", "bits", "categorical",
    "cauchy", "chisquare", "choice", "dirichlet", "double_sided_maxwell",
    "exponential", "gamma", "geometric", "gumbel", "laplace", "loggamma",
    "logistic", "maxwell", "multivariate_normal", "normal", "orthogonal",
    "pareto", "permutation", "poisson", "rademacher", "randint", "rayleigh",
    "shuffle", "t", "truncated_normal", "uniform", "wald", "weibull_min",
})

#: consuming callees (split consumes too: two splits of one key collide)
CONSUMERS = SAMPLERS | {"split"}

_NUMPY_BASES = frozenset({"np", "numpy", "onp", "jnp"})


def _random_env(tree) -> tuple[dict[str, str], set]:
    """(bare names bound to jax.random functions, jax.random module
    aliases)."""
    fn_names: dict[str, str] = {}
    aliases: set = set()
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for a in node.names:
                if a.name == "jax.random" and a.asname:
                    aliases.add(a.asname)
        elif isinstance(node, ast.ImportFrom) and node.level == 0:
            if node.module == "jax":
                for a in node.names:
                    if a.name == "random":
                        aliases.add(a.asname or "random")
            elif node.module == "jax.random":
                for a in node.names:
                    if a.name in CONSUMERS:
                        fn_names[a.asname or a.name] = a.name
    return fn_names, aliases


def _consumer_of(call: ast.Call, fn_names, aliases) -> str | None:
    """Canonical jax.random consumer name for this call, else None."""
    d = dotted(call.func)
    if d is None:
        return None
    parts = d.split(".")
    if len(parts) == 1:
        return fn_names.get(d)
    if parts[-1] not in CONSUMERS:
        return None
    if parts[0] in _NUMPY_BASES:
        return None
    if parts[-2] in aliases:
        return parts[-1]
    if len(parts) >= 3 and parts[-2] == "random" and parts[-3] == "jax":
        return parts[-1]
    return None


def _key_arg(call: ast.Call) -> str | None:
    """Name of the key variable this consumer call consumes, if a Name."""
    if call.args and isinstance(call.args[0], ast.Name):
        return call.args[0].id
    for k in call.keywords:
        if k.arg == "key" and isinstance(k.value, ast.Name):
            return k.value.id
    return None


def _stored_names(node) -> set:
    """All names stored anywhere under ``node`` (incl. loop targets)."""
    out: set = set()
    for sub in ast.walk(node):
        if isinstance(sub, ast.Name) and isinstance(sub.ctx, ast.Store):
            out.add(sub.id)
    return out


class _Scope:
    """Line-ordered key-consumption scan of one function (or module) body;
    nested defs are separate scopes."""

    def __init__(self, ctx, fn_names, aliases, qual: str):
        self.ctx = ctx
        self.fn_names = fn_names
        self.aliases = aliases
        self.qual = qual
        self.last_use: dict[str, tuple] = {}   # name -> (line, fn)
        self.loop_stored: list[set] = []       # stack of in-loop stores
        self.flagged: set = set()              # (name, line) dedup
        self.findings: list = []

    def _consume(self, call: ast.Call) -> None:
        fn = _consumer_of(call, self.fn_names, self.aliases)
        if fn is None:
            return
        name = _key_arg(call)
        if name is None:
            return
        in_loop_unbound = any(name not in stored
                              for stored in self.loop_stored) \
            and bool(self.loop_stored)
        prev = self.last_use.get(name)
        where = f" in `{self.qual}`" if self.qual else ""
        if prev is not None and (name, call.lineno) not in self.flagged:
            self.flagged.add((name, call.lineno))
            self.findings.append(self.ctx.finding(
                call, "KEYREUSE",
                f"key `{name}` consumed by `{fn}` was already consumed by "
                f"`{prev[1]}` at line {prev[0]}{where} — same key, same "
                f"bits; split or fold_in between uses"))
        elif in_loop_unbound and (name, call.lineno) not in self.flagged:
            self.flagged.add((name, call.lineno))
            self.findings.append(self.ctx.finding(
                call, "KEYREUSE",
                f"key `{name}` consumed by `{fn}` inside a loop without a "
                f"per-iteration rebinding{where} — every iteration draws "
                f"with the same key; derive with fold_in(key, i) or split "
                f"outside the loop"))
        self.last_use[name] = (call.lineno, fn)

    def _store(self, name: str) -> None:
        self.last_use.pop(name, None)

    # -- expression walk (consumptions + comprehension loops) --------------

    def expr(self, node) -> None:
        if node is None or isinstance(node, (ast.FunctionDef,
                                             ast.AsyncFunctionDef,
                                             ast.Lambda)):
            return  # nested callables are their own scope
        if isinstance(node, (ast.ListComp, ast.SetComp, ast.DictComp,
                             ast.GeneratorExp)):
            stored = set()
            for gen in node.generators:
                stored |= _stored_names(gen.target)
                self.expr(gen.iter)
            self.loop_stored.append(stored)
            for gen in node.generators:
                for cond in gen.ifs:
                    self.expr(cond)
            if isinstance(node, ast.DictComp):
                self.expr(node.key)
                self.expr(node.value)
            else:
                self.expr(node.elt)
            self.loop_stored.pop()
            return
        if isinstance(node, ast.Call):
            self._consume(node)
        for child in ast.iter_child_nodes(node):
            if isinstance(child, ast.expr):
                self.expr(child)

    # -- statement walk ----------------------------------------------------

    def run(self, stmts) -> None:
        for st in stmts:
            self.stmt(st)

    def stmt(self, st) -> None:
        if isinstance(st, (ast.FunctionDef, ast.AsyncFunctionDef,
                           ast.ClassDef)):
            return  # separate scope
        if isinstance(st, (ast.Assign, ast.AnnAssign, ast.AugAssign)):
            if st.value is not None:
                self.expr(st.value)
            targets = st.targets if isinstance(st, ast.Assign) else [st.target]
            for tgt in targets:
                for name in _stored_names(tgt):
                    self._store(name)
            return
        if isinstance(st, ast.If):
            # exclusive branches are not sequential reuse: scan each from
            # the same pre-state, keep only consumptions both agree on
            self.expr(st.test)
            snap = dict(self.last_use)
            self.run(st.body)
            after_body = self.last_use
            self.last_use = dict(snap)
            self.run(st.orelse)
            self.last_use = {n: u for n, u in after_body.items()
                             if n in self.last_use}
            return
        if isinstance(st, ast.Try):
            snap = dict(self.last_use)
            self.run(st.body)
            after_body = self.last_use
            for handler in st.handlers:
                self.last_use = dict(snap)
                self.run(handler.body)
            self.last_use = {n: u for n, u in after_body.items()
                             if n in self.last_use}
            self.run(st.orelse)
            self.run(st.finalbody)
            return
        if isinstance(st, (ast.For, ast.AsyncFor, ast.While)):
            if isinstance(st, ast.While):
                self.expr(st.test)
                stored = _stored_names(st)
            else:
                self.expr(st.iter)
                stored = _stored_names(st) | _stored_names(st.target)
            self.loop_stored.append(stored)
            self.run(st.body)
            self.loop_stored.pop()
            self.run(st.orelse)
            return
        # generic: sub-statements in order, expressions as encountered
        for _field, value in ast.iter_fields(st):
            if isinstance(value, list):
                for v in value:
                    if isinstance(v, ast.stmt):
                        self.stmt(v)
                    elif isinstance(v, ast.expr):
                        self.expr(v)
            elif isinstance(value, ast.stmt):
                self.stmt(value)
            elif isinstance(value, ast.expr):
                self.expr(value)


@register("KEYREUSE", "jax.random key consumed twice (or every loop "
                      "iteration) without an intervening split/fold_in")
def check(ctx):
    fn_names, aliases = _random_env(ctx.tree)
    scopes = [(ctx.tree.body, "")]
    for node in ast.walk(ctx.tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            scopes.append((node.body, ctx.qualnames.get(node, node.name)))
    for body, qual in scopes:
        scan = _Scope(ctx, fn_names, aliases, qual)
        scan.run(body)
        yield from scan.findings
