"""jaxlint core: findings, rule registry, pragma handling, and the runner.

A *rule* is a function ``check(ctx) -> Iterable[Finding]`` registered under
an UPPERCASE name via :func:`register`; ``ctx`` is a :class:`FileContext`
carrying the parsed tree, the config, and shared maps (qualnames, parents,
module int constants).  A rule may additionally carry a *project pass*
(:func:`register_project`): ``project_check(project, targets)`` runs once
per lint with the whole-program :class:`~.projectgraph.Project` and makes
the rule interprocedural — taint following calls into helper modules,
donation crossing imports, sharding verified over the reachable call
chain.  Project passes MUST attribute every finding to a file in
``targets`` whose analysis produced it (the caller/entry-point file, never
the callee) — that attribution discipline is what lets the incremental
cache reuse per-file results (a file's findings depend only on its own
content plus its import closure; see ``cache.py``).

The runner parses each file once, runs every per-file rule, runs the
project passes over the file set, then applies per-line pragmas:

    x = np.asarray(y)  # jaxlint: disable=HOSTSYNC -- sanctioned sync point

A pragma suppresses the named rule(s) on its own line **only when it
carries a trailing ``-- reason``** — a bare ``disable=RULE`` is inert and
itself reported as a PRAGMA finding, as is a pragma naming an unknown
rule.  Several rules share one pragma (``disable=RULE1,RULE2 -- reason``)
and several pragmas may sit on one line.  PRAGMA findings cannot be
suppressed.
"""

from __future__ import annotations

import argparse
import ast
import dataclasses
import pathlib
import re
import sys
import time
from typing import Callable, Iterable

#: hot-loop modules: HOSTSYNC applies only here (module-relative paths)
HOT_LOOP_MODULES = (
    "repro/ft/runner.py",
    "repro/serve/executor.py",
    "repro/serve/decode.py",
    "repro/train/step.py",
)

#: sanctioned sync points per hot-loop module: qualname prefixes where a
#: host sync is the *designed* behaviour (the one-fetch-per-chunk retire,
#: the one-sync-per-wave waits).  Everything else needs a fix or a pragma.
SYNC_ALLOWLIST = {
    "repro/ft/runner.py": ("_chunked_loop.retire",),
    "repro/serve/executor.py": ("InflightWave.wait", "InflightWave.wait_tiles"),
}

#: parameter names that mark a public entry point as batch-bearing (SHARD)
BATCH_PARAM_NAMES = ("batch", "batches", "tokens", "features",
                     "features_list", "fingerprints", "voxels")

#: dtype attribute name -> bytes, for the PALLASTILE VMEM estimate
DTYPE_BYTES = {"float64": 8, "int64": 8, "float32": 4, "int32": 4,
               "uint32": 4, "bfloat16": 2, "float16": 2, "int16": 2,
               "int8": 1, "uint8": 1, "bool_": 1}

#: directories linted in a repo scan besides ``src/`` (scoped ruleset is
#: inherent: HOSTSYNC keys off hot_loop_modules, SHARD off
#: shard_module_prefixes, PALLASTILE off kernel paths — none of which
#: match these dirs, so they get TRACERBRANCH/DONATE/KEYREUSE/RECOMPILE/
#: SCANCARRY plus pragma hygiene)
EXTRA_SCAN_DIRS = ("benchmarks", "examples", "scripts")


@dataclasses.dataclass(frozen=True)
class LintConfig:
    hot_loop_modules: tuple = HOT_LOOP_MODULES
    sync_allowlist: dict = dataclasses.field(
        default_factory=lambda: dict(SYNC_ALLOWLIST))
    batch_param_names: tuple = BATCH_PARAM_NAMES
    #: modules whose public entry points the SHARD rule audits
    shard_module_prefixes: tuple = ("repro/serve/", "repro/train/")
    #: files the PALLASTILE rule audits (str.endswith takes the tuple:
    #: per-layer kernels live in kernel.py, whole-network ones in fused.py,
    #: multi-step training launches in multistep.py)
    kernel_path_prefix: str = "repro/kernels/"
    kernel_file_suffix: tuple = ("kernel.py", "fused.py", "multistep.py")
    #: TPU tiling contract: last dim % lane, second-to-last % sublane
    lane: int = 128
    sublane: int = 8
    #: per-pallas_call VMEM budget (~16 MB/core on current TPUs); the
    #: estimate is a lower bound (unresolvable dims contribute nothing)
    vmem_cap_bytes: int = 16 * 1024 * 1024
    #: bytes assumed for BlockSpec blocks whose dtype is not statically
    #: visible (scratch pltpu.VMEM(...) carries its dtype; operands don't)
    default_dtype_bytes: int = 4
    #: max call depth interprocedural passes follow from their origin file
    max_call_depth: int = 4


@dataclasses.dataclass(frozen=True, order=True)
class Finding:
    path: str      # as given to the linter (repo-relative for repo scans)
    line: int
    rule: str
    message: str

    @property
    def key(self) -> str:
        return f"{self.path}:{self.line} {self.rule} {self.message}"

    def github(self) -> str:
        """GitHub workflow-command annotation (inline on PR diffs)."""
        return (f"::error file={self.path},line={self.line},"
                f"title=jaxlint {self.rule}::{self.message}")


@dataclasses.dataclass(frozen=True)
class Rule:
    name: str
    summary: str
    check: Callable
    #: optional whole-program pass ``(project, targets) -> Iterable[Finding]``
    project_check: Callable | None = None
    #: True when the project pass *replaces* the per-file check in project
    #: mode (the per-file check is the degraded single-file approximation)
    project_replaces_file: bool = False


RULES: dict[str, Rule] = {}

#: reserved name for pragma-syntax findings (not a registered rule: it has
#: no check function and can never be suppressed)
PRAGMA_RULE = "PRAGMA"


def register(name: str, summary: str):
    """Class/function decorator adding a rule to the registry.

    Adding a rule == writing one ``check(ctx)`` generator, registering it
    here, and dropping a positive + negative fixture pair under
    ``tests/fixtures/jaxlint/`` (test_jaxlint enforces the pairing).
    Optionally attach a whole-program pass with :func:`register_project`.
    """
    if name != name.upper() or name == PRAGMA_RULE:
        raise ValueError(f"rule names are UPPERCASE and != PRAGMA: {name!r}")

    def deco(fn):
        if name in RULES:
            raise ValueError(f"duplicate rule {name}")
        RULES[name] = Rule(name=name, summary=summary, check=fn)
        return fn

    return deco


def register_project(name: str, replaces_file: bool = False):
    """Attach a project pass to an already-registered rule.

    ``replaces_file=True`` means the per-file check is skipped in project
    mode (e.g. SHARD's module-string-match is superseded by call-chain
    reachability); default is *extends* (the pass only adds the
    cross-module findings the per-file check cannot see).
    """
    def deco(fn):
        if name not in RULES:
            raise ValueError(f"project pass for unregistered rule {name}")
        RULES[name] = dataclasses.replace(
            RULES[name], project_check=fn, project_replaces_file=replaces_file)
        return fn

    return deco


def available_rules() -> dict[str, str]:
    _load_rules()
    return {r.name: r.summary for r in RULES.values()}


class FileContext:
    """One parsed file + the shared maps rules keep re-deriving."""

    def __init__(self, path: str, source: str, tree: ast.Module,
                 config: LintConfig):
        self.path = path
        #: path rules match against (repo prefix ``src/`` stripped)
        self.module_path = path[4:] if path.startswith("src/") else path
        self.source = source
        self.tree = tree
        self.config = config
        self._qualnames = None
        self._parents = None
        self._constants = None

    @property
    def qualnames(self) -> dict:
        if self._qualnames is None:
            from repro.tools.jaxlint.astutil import qualname_map
            self._qualnames = qualname_map(self.tree)
        return self._qualnames

    @property
    def parents(self) -> dict:
        if self._parents is None:
            from repro.tools.jaxlint.astutil import parent_map
            self._parents = parent_map(self.tree)
        return self._parents

    @property
    def int_constants(self) -> dict[str, int]:
        if self._constants is None:
            from repro.tools.jaxlint.astutil import module_int_constants
            self._constants = module_int_constants(self.tree)
        return self._constants

    def enclosing_function(self, node: ast.AST):
        cur = self.parents.get(node)
        while cur is not None:
            if isinstance(cur, (ast.FunctionDef, ast.AsyncFunctionDef)):
                return cur
            cur = self.parents.get(cur)
        return None

    def qualname_of(self, node: ast.AST) -> str:
        """Qualname of the function enclosing ``node`` ('' at module level)."""
        fn = node if isinstance(node, (ast.FunctionDef,
                                       ast.AsyncFunctionDef)) \
            else self.enclosing_function(node)
        return self.qualnames.get(fn, "") if fn is not None else ""

    def finding(self, node_or_line, rule: str, message: str) -> Finding:
        line = node_or_line if isinstance(node_or_line, int) \
            else node_or_line.lineno
        return Finding(path=self.path, line=line, rule=rule, message=message)


_PRAGMA_RE = re.compile(
    r"#\s*jaxlint:\s*disable=([A-Za-z0-9_,\s]+?)(?:\s*--\s*([^#]*\S))?\s*"
    r"(?=#|$)")


def parse_pragmas(source: str, path: str
                  ) -> tuple[dict[int, set], list[Finding]]:
    """(line -> suppressed rule names, pragma-syntax findings).

    A pragma only suppresses when it names known rules AND carries a
    ``-- reason``; offenders become PRAGMA findings instead.  One pragma
    may name several rules (``disable=A,B -- reason``) and one line may
    carry several pragmas.
    """
    _load_rules()
    suppress: dict[int, set] = {}
    problems: list[Finding] = []
    for i, text in enumerate(source.splitlines(), start=1):
        for m in _PRAGMA_RE.finditer(text):
            names = {n.strip().upper()
                     for n in m.group(1).split(",") if n.strip()}
            reason = m.group(2)
            unknown = sorted(n for n in names if n not in RULES)
            if unknown:
                problems.append(Finding(
                    path, i, PRAGMA_RULE,
                    f"pragma names unknown rule(s) {', '.join(unknown)} "
                    f"(known: {', '.join(sorted(RULES))})"))
            if not reason:
                problems.append(Finding(
                    path, i, PRAGMA_RULE,
                    "pragma carries no reason — write `# jaxlint: "
                    "disable=RULE -- why this line is exempt`"))
                continue  # reasonless pragmas are inert
            suppress.setdefault(i, set()).update(names - set(unknown))
    return suppress, problems


def _load_rules() -> None:
    # rule modules self-register on import; deferred to avoid a cycle
    # (rules import Finding/register from here)
    from repro.tools.jaxlint import rules  # noqa: F401


def collect_findings(source: str, path: str,
                     config: LintConfig | None = None) -> list[Finding]:
    """Raw rule findings for one source blob — pragmas NOT applied."""
    _load_rules()
    config = config or LintConfig()
    try:
        tree = ast.parse(source, filename=path)
    except SyntaxError as e:
        return [Finding(path, e.lineno or 1, "SYNTAX",
                        f"syntax error prevents linting ({e.msg})")]
    ctx = FileContext(path, source, tree, config)
    out: list[Finding] = []
    for rule in RULES.values():
        out.extend(rule.check(ctx))
    return out


def lint_source(source: str, path: str,
                config: LintConfig | None = None) -> list[Finding]:
    """Unsuppressed findings for one file in isolation (no project graph —
    the v1 per-file view; cross-module contracts are invisible here)."""
    raw = collect_findings(source, path, config)
    suppress, problems = parse_pragmas(source, path)
    kept = [f for f in raw if f.rule not in suppress.get(f.line, set())]
    return sorted(kept + problems)


# --- whole-program runner --------------------------------------------------

@dataclasses.dataclass
class LintStats:
    total: int = 0          # files in the scan
    analyzed: int = 0       # files actually re-analyzed this run
    reused: int = 0         # files served from the incremental cache
    seconds: float = 0.0

    def line(self) -> str:
        return (f"jaxlint: analyzed {self.analyzed}/{self.total} files "
                f"({self.reused} from cache) in {self.seconds:.2f}s")


@dataclasses.dataclass
class LintResult:
    findings: list
    stats: LintStats


def _file_raw_findings(path: str, source: str, config: LintConfig,
                       in_project: bool) -> list[Finding]:
    """Per-file rule findings (pragmas not applied).  ``in_project`` skips
    rules whose project pass replaces the per-file check."""
    _load_rules()
    try:
        tree = ast.parse(source, filename=path)
    except SyntaxError as e:
        return [Finding(path, e.lineno or 1, "SYNTAX",
                        f"syntax error prevents linting ({e.msg})")]
    ctx = FileContext(path, source, tree, config)
    out: list[Finding] = []
    for rule in RULES.values():
        if in_project and rule.project_replaces_file \
                and rule.project_check is not None:
            continue
        out.extend(rule.check(ctx))
    return out


def _analyze_batch(args):
    """Worker entry for ``--jobs``: analyze a batch of files."""
    items, config = args
    return [f for path, source in items
            for f in _file_raw_findings(path, source, config, True)]


def _parallel_file_findings(items: list, config: LintConfig,
                            jobs: int) -> list[Finding]:
    if jobs <= 1 or len(items) < 2:
        return [f for path, source in items
                for f in _file_raw_findings(path, source, config, True)]
    try:
        import concurrent.futures as cf
        batches = [items[i::jobs] for i in range(jobs)]
        batches = [b for b in batches if b]
        with cf.ProcessPoolExecutor(max_workers=len(batches)) as pool:
            chunks = list(pool.map(_analyze_batch,
                                   [(b, config) for b in batches]))
        return [f for chunk in chunks for f in chunk]
    except Exception:  # sandboxed rigs without working multiprocessing
        return [f for path, source in items
                for f in _file_raw_findings(path, source, config, True)]


def lint_project(files: dict, config: LintConfig | None = None,
                 cache_path=None, jobs: int = 1) -> LintResult:
    """Whole-program lint of ``{path: source}``.

    Per-file rules + project passes, pragma application per file, and —
    with ``cache_path`` — content-hash incremental reuse: a file is
    re-analyzed only when its own content or a file in its import closure
    changed (project-pass findings are attributed to the file whose
    analysis produced them, so cached per-file results stay valid).
    """
    from repro.tools.jaxlint import cache as cachemod
    from repro.tools.jaxlint.projectgraph import Project

    _load_rules()
    config = config or LintConfig()
    t0 = time.perf_counter()
    stats = LintStats(total=len(files))

    contexts: dict = {}
    syntax: dict[str, list[Finding]] = {}
    for path, source in files.items():
        try:
            tree = ast.parse(source, filename=path)
        except SyntaxError as e:
            syntax[path] = [Finding(path, e.lineno or 1, "SYNTAX",
                                    f"syntax error prevents linting "
                                    f"({e.msg})")]
            continue
        contexts[path] = FileContext(path, source, tree, config)
    project = Project(contexts, config)

    deps = {path: project.deps(path) for path in contexts}
    hashes = {path: cachemod.content_hash(src) for path, src in files.items()}
    cached = cachemod.load(cache_path, config) if cache_path else None
    dirty, reused = cachemod.plan(cached, hashes, deps)
    stats.analyzed = len(dirty)
    stats.reused = len(reused)

    per_path: dict[str, list[Finding]] = dict(reused)
    dirty_items = [(p, files[p]) for p in files if p in dirty]
    raw = _parallel_file_findings(
        [(p, s) for p, s in dirty_items if p in contexts], config, jobs)
    for path in dirty:
        raw.extend(syntax.get(path, ()))
    for rule in RULES.values():
        if rule.project_check is not None:
            for f in rule.project_check(project, dirty):
                # attribution discipline: project passes may only report
                # into files being analyzed this run (see module docstring)
                if f.path in dirty:
                    raw.append(f)

    by_path: dict[str, list[Finding]] = {p: [] for p in dirty}
    for f in raw:
        by_path.setdefault(f.path, []).append(f)
    for path, flist in by_path.items():
        source = files.get(path, "")
        suppress, problems = parse_pragmas(source, path)
        kept = [f for f in set(flist)
                if f.rule not in suppress.get(f.line, set())]
        per_path[path] = sorted(kept + problems)

    if cache_path:
        cachemod.save(cache_path, config, hashes, deps, per_path)

    stats.seconds = time.perf_counter() - t0
    findings = sorted(f for flist in per_path.values() for f in flist)
    return LintResult(findings=findings, stats=stats)


def iter_repo_files(repo_root: pathlib.Path) -> Iterable[pathlib.Path]:
    """Python files a repo scan lints: ``src/`` plus ``EXTRA_SCAN_DIRS``."""
    repo_root = pathlib.Path(repo_root)
    for top in ("src", *EXTRA_SCAN_DIRS):
        base = repo_root / top
        if base.is_dir():
            yield from sorted(base.rglob("*.py"))


def repo_files(repo_root) -> dict[str, str]:
    repo_root = pathlib.Path(repo_root)
    return {py.relative_to(repo_root).as_posix(): py.read_text()
            for py in iter_repo_files(repo_root)}


def lint_repo(repo_root, config: LintConfig | None = None,
              cache_path=None, jobs: int = 1) -> list[Finding]:
    """Whole-program lint of a repo checkout (see :func:`lint_project`)."""
    return lint_project(repo_files(repo_root), config,
                        cache_path=cache_path, jobs=jobs).findings


def main(argv=None, repo_root: pathlib.Path | None = None) -> int:
    if repo_root is None:
        repo_root = pathlib.Path(__file__).resolve().parents[4]
    ap = argparse.ArgumentParser(
        prog="jaxlint",
        description="static analysis of the repo's jit/sharding/Pallas "
                    "performance contracts")
    ap.add_argument("--report", choices=("dead-exports",),
                    help="emit a report instead of linting (with "
                    "--allowlist, dead-exports becomes a CI gate)")
    ap.add_argument("--allowlist", metavar="FILE",
                    help="dead-exports allowlist file: gate mode — exit 1 "
                    "on dead exports missing from the file and on stale "
                    "entries")
    ap.add_argument("--format", choices=("text", "github", "sarif"),
                    default="text", dest="fmt",
                    help="finding output format (sarif prints a SARIF "
                    "2.1.0 run to stdout; the timing line goes to stderr)")
    ap.add_argument("--github", action="store_true",
                    help="alias for --format github")
    ap.add_argument("--cache", metavar="FILE",
                    help="incremental cache (e.g. .jaxlint-cache.json): "
                    "only files whose content hash or import closure "
                    "changed are re-analyzed")
    ap.add_argument("--jobs", type=int, default=1, metavar="N",
                    help="analyze files in N parallel processes")
    ap.add_argument("--list-rules", action="store_true")
    args = ap.parse_args(argv)
    fmt = "github" if args.github else args.fmt

    if args.list_rules:
        for name, summary in sorted(available_rules().items()):
            print(f"{name:13s} {summary}")
        return 0

    if args.report == "dead-exports":
        from repro.tools.jaxlint.deadexports import (dead_exports_gate,
                                                     dead_exports_report)
        if args.allowlist:
            lines, code = dead_exports_gate(repo_root, args.allowlist)
            for line in lines:
                print(line)
            return code
        for line in dead_exports_report(repo_root):
            print(line)
        return 0

    result = lint_project(repo_files(repo_root),
                          cache_path=args.cache, jobs=args.jobs)
    findings = result.findings
    print(result.stats.line(), file=sys.stderr)
    if fmt == "sarif":
        import json

        from repro.tools.jaxlint.sarif import sarif_report
        print(json.dumps(sarif_report(findings), indent=2))
        return 1 if findings else 0
    if findings:
        print(f"jaxlint: {len(findings)} unsuppressed finding(s):")
        for f in findings:
            print(f.github() if fmt == "github" else f"  {f.key}")
        return 1
    print(f"jaxlint: clean ({result.stats.total} files, "
          f"{len(available_rules())} rules)")
    return 0
