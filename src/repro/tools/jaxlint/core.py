"""jaxlint core: findings, rule registry, pragma handling, and the runner.

A *rule* is a function ``check(ctx) -> Iterable[Finding]`` registered under
an UPPERCASE name via :func:`register`; ``ctx`` is a :class:`FileContext`
carrying the parsed tree, the config, and shared maps (qualnames, parents,
module int constants).  The runner parses each file once, runs every rule,
then applies per-line pragmas:

    x = np.asarray(y)  # jaxlint: disable=HOSTSYNC -- sanctioned sync point

A pragma suppresses the named rule(s) on its own line **only when it
carries a trailing ``-- reason``** — a bare ``disable=RULE`` is inert and
itself reported as a PRAGMA finding, as is a pragma naming an unknown
rule.  PRAGMA findings cannot be suppressed.
"""

from __future__ import annotations

import argparse
import ast
import dataclasses
import pathlib
import re
from typing import Callable, Iterable

#: hot-loop modules: HOSTSYNC applies only here (module-relative paths)
HOT_LOOP_MODULES = (
    "repro/ft/runner.py",
    "repro/serve/executor.py",
    "repro/serve/decode.py",
    "repro/train/step.py",
)

#: sanctioned sync points per hot-loop module: qualname prefixes where a
#: host sync is the *designed* behaviour (the one-fetch-per-chunk retire,
#: the one-sync-per-wave waits).  Everything else needs a fix or a pragma.
SYNC_ALLOWLIST = {
    "repro/ft/runner.py": ("_chunked_loop.retire",),
    "repro/serve/executor.py": ("InflightWave.wait", "InflightWave.wait_tiles"),
}

#: parameter names that mark a public entry point as batch-bearing (SHARD)
BATCH_PARAM_NAMES = ("batch", "batches", "tokens", "features",
                     "features_list", "fingerprints", "voxels")

#: dtype attribute name -> bytes, for the PALLASTILE VMEM estimate
DTYPE_BYTES = {"float64": 8, "int64": 8, "float32": 4, "int32": 4,
               "uint32": 4, "bfloat16": 2, "float16": 2, "int16": 2,
               "int8": 1, "uint8": 1, "bool_": 1}


@dataclasses.dataclass(frozen=True)
class LintConfig:
    hot_loop_modules: tuple = HOT_LOOP_MODULES
    sync_allowlist: dict = dataclasses.field(
        default_factory=lambda: dict(SYNC_ALLOWLIST))
    batch_param_names: tuple = BATCH_PARAM_NAMES
    #: modules whose public entry points the SHARD rule audits
    shard_module_prefixes: tuple = ("repro/serve/", "repro/train/")
    #: files the PALLASTILE rule audits (str.endswith takes the tuple:
    #: per-layer kernels live in kernel.py, whole-network ones in fused.py)
    kernel_path_prefix: str = "repro/kernels/"
    kernel_file_suffix: tuple = ("kernel.py", "fused.py")
    #: TPU tiling contract: last dim % lane, second-to-last % sublane
    lane: int = 128
    sublane: int = 8
    #: per-pallas_call VMEM budget (~16 MB/core on current TPUs); the
    #: estimate is a lower bound (unresolvable dims contribute nothing)
    vmem_cap_bytes: int = 16 * 1024 * 1024
    #: bytes assumed for BlockSpec blocks whose dtype is not statically
    #: visible (scratch pltpu.VMEM(...) carries its dtype; operands don't)
    default_dtype_bytes: int = 4


@dataclasses.dataclass(frozen=True, order=True)
class Finding:
    path: str      # as given to the linter (repo-relative for repo scans)
    line: int
    rule: str
    message: str

    @property
    def key(self) -> str:
        return f"{self.path}:{self.line} {self.rule} {self.message}"

    def github(self) -> str:
        """GitHub workflow-command annotation (inline on PR diffs)."""
        return (f"::error file={self.path},line={self.line},"
                f"title=jaxlint {self.rule}::{self.message}")


@dataclasses.dataclass(frozen=True)
class Rule:
    name: str
    summary: str
    check: Callable


RULES: dict[str, Rule] = {}

#: reserved name for pragma-syntax findings (not a registered rule: it has
#: no check function and can never be suppressed)
PRAGMA_RULE = "PRAGMA"


def register(name: str, summary: str):
    """Class/function decorator adding a rule to the registry.

    Adding a rule == writing one ``check(ctx)`` generator, registering it
    here, and dropping a positive + negative fixture pair under
    ``tests/fixtures/jaxlint/`` (test_jaxlint enforces the pairing).
    """
    if name != name.upper() or name == PRAGMA_RULE:
        raise ValueError(f"rule names are UPPERCASE and != PRAGMA: {name!r}")

    def deco(fn):
        if name in RULES:
            raise ValueError(f"duplicate rule {name}")
        RULES[name] = Rule(name=name, summary=summary, check=fn)
        return fn

    return deco


def available_rules() -> dict[str, str]:
    _load_rules()
    return {r.name: r.summary for r in RULES.values()}


class FileContext:
    """One parsed file + the shared maps rules keep re-deriving."""

    def __init__(self, path: str, source: str, tree: ast.Module,
                 config: LintConfig):
        self.path = path
        #: path rules match against (repo prefix ``src/`` stripped)
        self.module_path = path[4:] if path.startswith("src/") else path
        self.source = source
        self.tree = tree
        self.config = config
        self._qualnames = None
        self._parents = None
        self._constants = None

    @property
    def qualnames(self) -> dict:
        if self._qualnames is None:
            from repro.tools.jaxlint.astutil import qualname_map
            self._qualnames = qualname_map(self.tree)
        return self._qualnames

    @property
    def parents(self) -> dict:
        if self._parents is None:
            from repro.tools.jaxlint.astutil import parent_map
            self._parents = parent_map(self.tree)
        return self._parents

    @property
    def int_constants(self) -> dict[str, int]:
        if self._constants is None:
            from repro.tools.jaxlint.astutil import module_int_constants
            self._constants = module_int_constants(self.tree)
        return self._constants

    def enclosing_function(self, node: ast.AST):
        cur = self.parents.get(node)
        while cur is not None:
            if isinstance(cur, (ast.FunctionDef, ast.AsyncFunctionDef)):
                return cur
            cur = self.parents.get(cur)
        return None

    def qualname_of(self, node: ast.AST) -> str:
        """Qualname of the function enclosing ``node`` ('' at module level)."""
        fn = node if isinstance(node, (ast.FunctionDef,
                                       ast.AsyncFunctionDef)) \
            else self.enclosing_function(node)
        return self.qualnames.get(fn, "") if fn is not None else ""

    def finding(self, node_or_line, rule: str, message: str) -> Finding:
        line = node_or_line if isinstance(node_or_line, int) \
            else node_or_line.lineno
        return Finding(path=self.path, line=line, rule=rule, message=message)


_PRAGMA_RE = re.compile(
    r"#\s*jaxlint:\s*disable=([A-Za-z0-9_,\s]+?)(?:\s*--\s*(\S.*))?\s*$")


def parse_pragmas(source: str, path: str
                  ) -> tuple[dict[int, set], list[Finding]]:
    """(line -> suppressed rule names, pragma-syntax findings).

    A pragma only suppresses when it names known rules AND carries a
    ``-- reason``; offenders become PRAGMA findings instead.
    """
    _load_rules()
    suppress: dict[int, set] = {}
    problems: list[Finding] = []
    for i, text in enumerate(source.splitlines(), start=1):
        m = _PRAGMA_RE.search(text)
        if not m:
            continue
        names = {n.strip().upper() for n in m.group(1).split(",") if n.strip()}
        reason = m.group(2)
        unknown = sorted(n for n in names if n not in RULES)
        if unknown:
            problems.append(Finding(
                path, i, PRAGMA_RULE,
                f"pragma names unknown rule(s) {', '.join(unknown)} "
                f"(known: {', '.join(sorted(RULES))})"))
        if not reason:
            problems.append(Finding(
                path, i, PRAGMA_RULE,
                "pragma carries no reason — write `# jaxlint: "
                "disable=RULE -- why this line is exempt`"))
            continue  # reasonless pragmas are inert
        suppress.setdefault(i, set()).update(names - set(unknown))
    return suppress, problems


def _load_rules() -> None:
    # rule modules self-register on import; deferred to avoid a cycle
    # (rules import Finding/register from here)
    from repro.tools.jaxlint import rules  # noqa: F401


def collect_findings(source: str, path: str,
                     config: LintConfig | None = None) -> list[Finding]:
    """Raw rule findings for one source blob — pragmas NOT applied."""
    _load_rules()
    config = config or LintConfig()
    try:
        tree = ast.parse(source, filename=path)
    except SyntaxError as e:
        return [Finding(path, e.lineno or 1, "SYNTAX",
                        f"syntax error prevents linting ({e.msg})")]
    ctx = FileContext(path, source, tree, config)
    out: list[Finding] = []
    for rule in RULES.values():
        out.extend(rule.check(ctx))
    return out


def lint_source(source: str, path: str,
                config: LintConfig | None = None) -> list[Finding]:
    """Unsuppressed findings (rule findings minus reasoned pragmas, plus
    pragma-syntax findings)."""
    raw = collect_findings(source, path, config)
    suppress, problems = parse_pragmas(source, path)
    kept = [f for f in raw if f.rule not in suppress.get(f.line, set())]
    return sorted(kept + problems)


def iter_repo_files(repo_root: pathlib.Path) -> Iterable[pathlib.Path]:
    src = pathlib.Path(repo_root) / "src"
    if src.is_dir():
        yield from sorted(src.rglob("*.py"))


def lint_repo(repo_root, config: LintConfig | None = None) -> list[Finding]:
    """Lint every python file under ``<repo_root>/src``."""
    repo_root = pathlib.Path(repo_root)
    findings: list[Finding] = []
    for py in iter_repo_files(repo_root):
        rel = py.relative_to(repo_root).as_posix()
        findings.extend(lint_source(py.read_text(), rel, config))
    return sorted(findings)


def main(argv=None, repo_root: pathlib.Path | None = None) -> int:
    if repo_root is None:
        repo_root = pathlib.Path(__file__).resolve().parents[4]
    ap = argparse.ArgumentParser(
        prog="jaxlint",
        description="static analysis of the repo's jit/sharding/Pallas "
                    "performance contracts")
    ap.add_argument("--report", choices=("dead-exports",),
                    help="emit an informational report instead of linting")
    ap.add_argument("--github", action="store_true",
                    help="print findings as GitHub ::error annotations")
    ap.add_argument("--list-rules", action="store_true")
    args = ap.parse_args(argv)

    if args.list_rules:
        for name, summary in sorted(available_rules().items()):
            print(f"{name:13s} {summary}")
        return 0

    if args.report == "dead-exports":
        from repro.tools.jaxlint.deadexports import dead_exports_report
        for line in dead_exports_report(repo_root):
            print(line)
        return 0

    findings = lint_repo(repo_root)
    if findings:
        print(f"jaxlint: {len(findings)} unsuppressed finding(s):")
        for f in findings:
            print(f.github() if args.github else f"  {f.key}")
        return 1
    n_files = sum(1 for _ in iter_repo_files(repo_root))
    print(f"jaxlint: clean ({n_files} files, {len(available_rules())} rules)")
    return 0
