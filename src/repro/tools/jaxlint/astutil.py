"""Shared AST plumbing for the jaxlint rules.

Everything here is pure ``ast`` bookkeeping: dotted-name rendering,
``functools.partial`` unwrapping, literal extraction, qualified-name /
parent maps, and the traced-function discovery that TRACERBRANCH and
DONATE share (which FunctionDefs end up under a ``jax.jit`` or
``pl.pallas_call`` trace, and which of their parameters are traced values
vs static arguments).  No code is executed and no jax import is needed —
the linter must run in the dependency-free CI lint job.
"""

from __future__ import annotations

import ast
from typing import Iterable


def dotted(node: ast.AST) -> str | None:
    """Render ``a.b.c`` for Name/Attribute chains; None for anything else."""
    if isinstance(node, ast.Name):
        return node.id
    if isinstance(node, ast.Attribute):
        base = dotted(node.value)
        return f"{base}.{node.attr}" if base else None
    return None


def is_jit_expr(node: ast.AST) -> bool:
    """True for ``jit`` / ``jax.jit`` (any prefix ending in ``.jit``)."""
    d = dotted(node)
    return d == "jit" or (d is not None and d.endswith(".jit"))


def is_partial_expr(node: ast.AST) -> bool:
    d = dotted(node)
    return d == "partial" or (d is not None and d.endswith(".partial"))


def unwrap_partial(node: ast.AST) -> tuple[ast.AST | None, list]:
    """``functools.partial(f, ...)`` -> ``(f, keywords)``; else (None, [])."""
    if (isinstance(node, ast.Call) and is_partial_expr(node.func)
            and node.args):
        return node.args[0], node.keywords
    return None, []


def literal_strings(node: ast.AST | None) -> list[str]:
    """String literals out of ``"a"`` / ``("a", "b")`` / ``["a"]``."""
    if node is None:
        return []
    if isinstance(node, ast.Constant) and isinstance(node.value, str):
        return [node.value]
    if isinstance(node, (ast.Tuple, ast.List)):
        return [e.value for e in node.elts
                if isinstance(e, ast.Constant) and isinstance(e.value, str)]
    return []


def literal_ints(node: ast.AST | None) -> list[int]:
    """Int literals out of ``0`` / ``(0, 1)`` / ``[0]``; for conditional
    expressions (``(0,) if flag else ()``) the union of both branches —
    a "may donate / may be static" over-approximation."""
    if node is None:
        return []
    if isinstance(node, ast.Constant) and isinstance(node.value, int) \
            and not isinstance(node.value, bool):
        return [node.value]
    if isinstance(node, (ast.Tuple, ast.List)):
        out = []
        for e in node.elts:
            out.extend(literal_ints(e))
        return out
    if isinstance(node, ast.IfExp):
        return literal_ints(node.body) + literal_ints(node.orelse)
    return []


def kw(keywords: Iterable, name: str) -> ast.AST | None:
    for k in keywords:
        if k.arg == name:
            return k.value
    return None


def parent_map(tree: ast.AST) -> dict:
    parents: dict = {}
    for node in ast.walk(tree):
        for child in ast.iter_child_nodes(node):
            parents[child] = node
    return parents


def qualname_map(tree: ast.AST) -> dict:
    """FunctionDef/ClassDef node -> dotted qualname (``Cls.meth``,
    ``outer.inner`` — no ``<locals>`` noise)."""
    out: dict = {}

    def visit(node, prefix):
        for child in ast.iter_child_nodes(node):
            if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef,
                                  ast.ClassDef)):
                qual = f"{prefix}.{child.name}" if prefix else child.name
                out[child] = qual
                visit(child, qual)
            else:
                visit(child, prefix)

    visit(tree, "")
    return out


def positional_params(fn: ast.AST) -> list[str]:
    a = fn.args
    return [p.arg for p in (*a.posonlyargs, *a.args)]


def all_params(fn: ast.AST) -> list[str]:
    a = fn.args
    names = positional_params(fn) + [p.arg for p in a.kwonlyargs]
    if a.vararg:
        names.append(a.vararg.arg)
    if a.kwarg:
        names.append(a.kwarg.arg)
    return names


def int_defaults(fn: ast.AST) -> dict[str, int]:
    """Param name -> int literal default, for positional and kw-only args."""
    a = fn.args
    env: dict[str, int] = {}
    pos = [*a.posonlyargs, *a.args]
    for p, d in zip(pos[len(pos) - len(a.defaults):], a.defaults):
        if (isinstance(d, ast.Constant) and isinstance(d.value, int)
                and not isinstance(d.value, bool)):
            env[p.arg] = d.value
    for p, d in zip(a.kwonlyargs, a.kw_defaults):
        if (d is not None and isinstance(d, ast.Constant)
                and isinstance(d.value, int)
                and not isinstance(d.value, bool)):
            env[p.arg] = d.value
    return env


def module_int_constants(tree: ast.Module) -> dict[str, int]:
    """Top-level ``NAME = <int>`` assignments (e.g. ``PAD = 128``)."""
    env: dict[str, int] = {}
    for stmt in tree.body:
        if isinstance(stmt, ast.Assign) and len(stmt.targets) == 1 \
                and isinstance(stmt.targets[0], ast.Name):
            vals = literal_ints(stmt.value)
            if len(vals) == 1 and isinstance(stmt.value, ast.Constant):
                env[stmt.targets[0].id] = vals[0]
    return env


def _functions_by_name(tree: ast.AST) -> dict[str, list]:
    by_name: dict[str, list] = {}
    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            by_name.setdefault(node.name, []).append(node)
    return by_name


def _partial_aliases(tree: ast.AST) -> dict[str, str]:
    """``kern = functools.partial(_kernel, ...)`` -> {"kern": "_kernel"}."""
    out: dict[str, str] = {}
    for node in ast.walk(tree):
        if isinstance(node, ast.Assign) and len(node.targets) == 1 \
                and isinstance(node.targets[0], ast.Name):
            inner, _ = unwrap_partial(node.value)
            if isinstance(inner, ast.Name):
                out[node.targets[0].id] = inner.id
    return out


def _jit_taint(fn, static_names, static_nums) -> set[str]:
    pos = positional_params(fn)
    tainted = set(pos) | {p.arg for p in fn.args.kwonlyargs}
    tainted -= set(static_names)
    for i in static_nums:
        if 0 <= i < len(pos):
            tainted.discard(pos[i])
    tainted.discard("self")
    return tainted


def traced_functions(tree: ast.AST) -> dict:
    """FunctionDef -> set of traced (tainted) parameter names.

    A function counts as traced when it is (a) decorated with ``jax.jit`` /
    ``functools.partial(jax.jit, ...)``, (b) named as the first argument of
    a ``jit(...)`` call anywhere in the module, or (c) the kernel of a
    ``pl.pallas_call`` (directly, through ``functools.partial``, or through
    a one-hop local ``kern = partial(_kernel, ...)`` alias).  Parameters
    named by ``static_argnames``/``static_argnums`` are not traced; for
    Pallas kernels only the positional Ref parameters are traced
    (keyword-only params are bound statically via ``functools.partial``).

    Resolution is name-based and module-local: a function jitted from
    another module is invisible here (the jit site is linted in *its*
    module), which keeps the pass O(file) and false-positive-averse.
    """
    by_name = _functions_by_name(tree)
    aliases = _partial_aliases(tree)
    traced: dict = {}

    def mark(fn, tainted):
        traced[fn] = traced.get(fn, set()) | tainted

    def mark_jit(fn, keywords):
        static_names = literal_strings(kw(keywords, "static_argnames"))
        static_nums = literal_ints(kw(keywords, "static_argnums"))
        mark(fn, _jit_taint(fn, static_names, static_nums))

    def resolve(node) -> list:
        """Candidate FunctionDefs for a callable expression."""
        inner, _ = unwrap_partial(node)
        if inner is not None:
            node = inner
        if isinstance(node, ast.Name):
            name = aliases.get(node.id, node.id)
            return by_name.get(name, [])
        return []

    for fns in by_name.values():
        for fn in fns:
            for dec in fn.decorator_list:
                if is_jit_expr(dec):                      # @jax.jit
                    mark_jit(fn, [])
                elif isinstance(dec, ast.Call):
                    inner, kws = unwrap_partial(dec)
                    if inner is not None and is_jit_expr(inner):
                        mark_jit(fn, kws)                 # @partial(jax.jit)
                    elif is_jit_expr(dec.func):
                        mark_jit(fn, dec.keywords)        # @jax.jit(...)

    for node in ast.walk(tree):
        if not isinstance(node, ast.Call) or not node.args:
            continue
        if is_jit_expr(node.func):                        # jax.jit(f, ...)
            for fn in resolve(node.args[0]):
                mark_jit(fn, node.keywords)
        d = dotted(node.func)
        if d is not None and d.endswith("pallas_call"):   # pl.pallas_call(k)
            for fn in resolve(node.args[0]):
                mark(fn, set(positional_params(fn)))
    return traced
