"""jaxlint: static analysis of the repo's jit/sharding/Pallas contracts.

The dispatch-efficiency invariants this repo's speedups rest on — no host
syncs on the hot loop, no Python branches on tracers, no reads of donated
buffers, batch axes routed through ``dist.shard``, MXU-aligned Pallas
blocks inside the VMEM budget — were, until this checker, enforced only by
convention and hand-audit.  Each was a bug class some PR actually had to
fix by hand (per-token ``np.asarray`` in the decode loop, per-tile
``block_until_ready`` in serving, tile shapes the CPU interpreter
tolerates but Mosaic pads).  This package walks the source with stdlib
``ast`` (no code is executed, no jax import is needed — the CI lint job
runs dependency-free) and turns each class into a registered rule, the
same way ``repro.tools.import_integrity`` turned the missing-subsystem
regression into a checker.

Rules (see ``repro/tools/jaxlint/rules/``): HOSTSYNC, TRACERBRANCH,
DONATE, SHARD, PALLASTILE.  Suppress a finding in place with a reasoned
pragma on its line::

    x = np.asarray(y)  # jaxlint: disable=HOSTSYNC -- sanctioned sync point

A pragma without a ``-- reason`` is inert and itself a finding.

Run via ``scripts/check_lints.py`` (CI, ``--github`` for inline PR
annotations, ``--report dead-exports`` for the dormant-API inventory) or
``tests/test_jaxlint.py`` (tier-1: zero unsuppressed findings over src/).
"""

from repro.tools.jaxlint.core import (Finding, LintConfig, PRAGMA_RULE,  # noqa: F401
                                      RULES, available_rules,
                                      collect_findings, lint_repo,
                                      lint_source, main, parse_pragmas,
                                      register)
from repro.tools.jaxlint.deadexports import (dead_exports,  # noqa: F401
                                             dead_exports_report)
from repro.tools.jaxlint import rules  # noqa: F401  (registers the rules)
