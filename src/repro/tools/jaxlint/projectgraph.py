"""Whole-program layer for jaxlint: imports, call graph, cross-module maps.

v1 analyzed one file at a time, so every contract that *threads* values
across module boundaries was invisible: a traced step passing its loop
counter into ``data/pipeline.batch_at``, donated ``TrainState`` handed to a
helper imported from another module, a serve entry point whose sharding
actually happens two calls away.  This module builds the shared
whole-program facts once per lint run:

* **module table** — ``src/repro/a/b.py`` <-> ``repro.a.b`` (files outside
  ``src/`` — benchmarks, examples, scripts — participate as import *users*
  only; nothing imports them);
* **import table** — per file, local name -> (module, symbol) for every
  intra-repo absolute import, including aliases and module bindings;
* **function index + call resolution** — top-level defs, class methods,
  one-hop ``f = functools.partial(g, ...)`` / ``f = jax.jit(g, ...)``
  aliases; ``resolve_call`` maps a call expression to candidate
  FunctionDefs anywhere in the project (local names, ``self.meth``,
  ``alias.fn``, full dotted paths);
* **reachability** — BFS over resolved calls + nested defs, used by the
  SHARD project pass to verify the *reachable* chain hits ``dist.shard``;
* **cross-module constant/donor/sync maps** consumed by the PALLASTILE /
  DONATE / HOSTSYNC project passes.

Resolution is static and name-based; what cannot be resolved contributes
nothing (rules stay false-positive-averse, exactly like v1).  Everything
here derives from a file's own source plus its transitive *imports* —
never from its importers — which is the invariant the incremental cache
relies on: a file's findings can only change when its content or its
import closure changes (see ``cache.py``).
"""

from __future__ import annotations

import ast

from repro.tools.jaxlint.astutil import (dotted, is_jit_expr,
                                         is_partial_expr, unwrap_partial)

#: stop-gap bound on reachability BFS (defensive; real chains are short)
MAX_REACH = 400


def _module_of(path: str) -> str | None:
    """Dotted module name for files under ``src/``; None otherwise."""
    if not path.startswith("src/") or not path.endswith(".py"):
        return None
    mod = path[len("src/"):-len(".py")]
    if mod.endswith("/__init__"):
        mod = mod[: -len("/__init__")]
    return mod.replace("/", ".")


class Project:
    """Parsed files + the cross-module maps the project passes share."""

    def __init__(self, contexts: dict, config=None):
        #: path -> FileContext (insertion order = scan order)
        self.files = contexts
        self.config = config
        self.module_to_path: dict[str, str] = {}
        for path in contexts:
            mod = _module_of(path)
            if mod is not None:
                self.module_to_path[mod] = path
        #: path -> {local name: (module, symbol | None)}; symbol None means
        #: the local name is bound to the module itself
        self.imports = {p: self._parse_imports(c.tree)
                        for p, c in contexts.items()}
        #: path -> extra dotted modules bound by plain ``import a.b.c``
        self._plain = {p: self._plain_imports(c.tree)
                       for p, c in contexts.items()}
        self._defs = {p: self._index_defs(c.tree)
                      for p, c in contexts.items()}
        self._deps_cache: dict[str, set] = {}

    # -- construction ------------------------------------------------------

    def _parse_imports(self, tree) -> dict:
        out: dict = {}
        for node in ast.walk(tree):
            if isinstance(node, ast.Import):
                for a in node.names:
                    if a.asname and a.name in self.module_to_path:
                        out[a.asname] = (a.name, None)
            elif isinstance(node, ast.ImportFrom) and node.level == 0:
                mod = node.module or ""
                for a in node.names:
                    sub = f"{mod}.{a.name}"
                    if sub in self.module_to_path:
                        out[a.asname or a.name] = (sub, None)
                    elif mod in self.module_to_path:
                        out[a.asname or a.name] = (mod, a.name)
        return out

    def _plain_imports(self, tree) -> set:
        out: set = set()
        for node in ast.walk(tree):
            if isinstance(node, ast.Import):
                for a in node.names:
                    if not a.asname and a.name in self.module_to_path:
                        out.add(a.name)
        return out

    @staticmethod
    def _index_defs(tree) -> dict:
        """{"defs": name->FunctionDef, "classes": cls->{meth->FunctionDef},
        "aliases": name->name (partial/jit one-hop)}."""
        defs: dict = {}
        classes: dict = {}
        aliases: dict = {}
        for stmt in tree.body:
            if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef)):
                defs[stmt.name] = stmt
            elif isinstance(stmt, ast.ClassDef):
                classes[stmt.name] = {
                    s.name: s for s in stmt.body
                    if isinstance(s, (ast.FunctionDef, ast.AsyncFunctionDef))}
            elif isinstance(stmt, ast.Assign) and len(stmt.targets) == 1 \
                    and isinstance(stmt.targets[0], ast.Name) \
                    and isinstance(stmt.value, ast.Call):
                call = stmt.value
                inner = None
                if is_partial_expr(call.func) and call.args:
                    inner = call.args[0]
                elif is_jit_expr(call.func) and call.args:
                    inner = call.args[0]
                    if isinstance(inner, ast.Call):  # jit(partial(f, ...))
                        inner, _ = unwrap_partial(inner)
                if isinstance(inner, ast.Name):
                    aliases[stmt.targets[0].id] = inner.id
        return {"defs": defs, "classes": classes, "aliases": aliases}

    # -- resolution --------------------------------------------------------

    def _local_def(self, path: str, name: str):
        idx = self._defs.get(path)
        if idx is None:
            return None
        name = idx["aliases"].get(name, name)
        return idx["defs"].get(name)

    def resolve_dotted(self, path: str, name: str) -> list:
        """Candidate ``(def_path, FunctionDef)`` for a dotted callee name."""
        parts = name.split(".")
        if len(parts) == 1:
            fn = self._local_def(path, name)
            if fn is not None:
                return [(path, fn)]
            imp = self.imports.get(path, {}).get(name)
            if imp is not None:
                module, symbol = imp
                if symbol is not None:
                    return self._module_symbol(module, symbol)
            return []
        # alias.attr / module.sub.attr / full dotted path
        imp = self.imports.get(path, {}).get(parts[0])
        if imp is not None and imp[1] is None:
            return self._module_symbol(imp[0], ".".join(parts[1:]))
        if imp is not None and imp[1] is not None and len(parts) == 2:
            # `from pkg import mod`-style binding where pkg.mod is not a
            # file: parts[0] is a symbol, attribute access unresolvable
            return []
        # plain `import repro.a.b` usage: longest module prefix wins
        for cut in range(len(parts) - 1, 0, -1):
            module = ".".join(parts[:cut])
            if module in self.module_to_path:
                return self._module_symbol(module, ".".join(parts[cut:]))
        return []

    def _module_symbol(self, module: str, symbol: str) -> list:
        """Resolve ``symbol`` (possibly dotted through submodules) in
        ``module`` to FunctionDef candidates."""
        parts = symbol.split(".")
        # descend through real submodules first: repro.train + step.make
        while len(parts) > 1 and f"{module}.{parts[0]}" in self.module_to_path:
            module = f"{module}.{parts[0]}"
            parts = parts[1:]
        if len(parts) != 1:
            return []
        tpath = self.module_to_path.get(module)
        if tpath is None:
            return []
        fn = self._local_def(tpath, parts[0])
        return [(tpath, fn)] if fn is not None else []

    def resolve_call(self, path: str, call: ast.Call) -> list:
        """Candidate ``(def_path, FunctionDef)`` for a call expression."""
        func = call.func
        # unwrap jit(f)(...) / partial(f, ...)(...) chains one level
        if isinstance(func, ast.Call) and func.args and \
                (is_jit_expr(func.func) or is_partial_expr(func.func)):
            func = func.args[0]
        d = dotted(func)
        if d is None:
            return []
        parts = d.split(".")
        if parts[0] == "self" and len(parts) == 2:
            return self._resolve_self(path, call, parts[1])
        return self.resolve_dotted(path, d)

    def _resolve_self(self, path: str, node: ast.AST, meth: str) -> list:
        ctx = self.files.get(path)
        if ctx is None:
            return []
        cur = ctx.parents.get(node)
        while cur is not None and not isinstance(cur, ast.ClassDef):
            cur = ctx.parents.get(cur)
        if cur is None:
            return []
        fn = self._defs[path]["classes"].get(cur.name, {}).get(meth)
        return [(path, fn)] if fn is not None else []

    # -- reachability ------------------------------------------------------

    def reachable(self, path: str, fn) -> list:
        """``(path, FunctionDef)`` reachable from ``fn`` via resolved calls
        and nested defs (both included), ``fn`` itself first."""
        seen_ids: set = set()
        out: list = []
        stack = [(path, fn)]
        while stack and len(out) < MAX_REACH:
            p, f = stack.pop()
            if id(f) in seen_ids:
                continue
            seen_ids.add(id(f))
            out.append((p, f))
            for node in ast.walk(f):
                if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)) \
                        and node is not f:
                    stack.append((p, node))
                elif isinstance(node, ast.Call):
                    stack.extend(self.resolve_call(p, node))
        return out

    # -- cross-module maps for the project passes --------------------------

    def int_env(self, path: str) -> dict[str, int]:
        """Module-level int constants visible in ``path`` through imports:
        both ``NAME`` (from-imports) and ``alias.NAME`` (module bindings)."""
        env: dict[str, int] = {}
        for local, (module, symbol) in self.imports.get(path, {}).items():
            tpath = self.module_to_path.get(module)
            if tpath is None or tpath not in self.files:
                continue
            consts = self.files[tpath].int_constants
            if symbol is not None:
                if symbol in consts:
                    env[local] = consts[symbol]
            else:
                for name, val in consts.items():
                    env[f"{local}.{name}"] = val
        return env

    def imported_donors(self, path: str) -> dict[str, list[int]]:
        """Callee spellings in ``path`` that resolve to a donating jit
        defined in another module: ``{"train_step": [0], "ts.step": [0]}``."""
        from repro.tools.jaxlint.rules.donate import module_donors
        out: dict[str, list[int]] = {}
        for local, (module, symbol) in self.imports.get(path, {}).items():
            tpath = self.module_to_path.get(module)
            if tpath is None or tpath not in self.files or tpath == path:
                continue
            donors = module_donors(self.files[tpath].tree)
            if symbol is not None:
                if symbol in donors:
                    out[local] = donors[symbol]
            else:
                for name, pos in donors.items():
                    out[f"{local}.{name}"] = pos
        return out

    def deps(self, path: str) -> set:
        """Project files this file's analysis may read (direct imports,
        package bindings expanded) — the cache-invalidation edge set."""
        if path in self._deps_cache:
            return self._deps_cache[path]
        out: set = set()
        for module, _symbol in self.imports.get(path, {}).values():
            out |= self._expand_module(module)
        for module in self._plain.get(path, ()):
            out |= self._expand_module(module)
        out.discard(path)
        self._deps_cache[path] = out
        return out

    def _expand_module(self, module: str) -> set:
        paths: set = set()
        tpath = self.module_to_path.get(module)
        if tpath is not None:
            paths.add(tpath)
            if tpath.endswith("__init__.py"):
                prefix = module + "."
                paths |= {p for m, p in self.module_to_path.items()
                          if m.startswith(prefix)}
        return paths

    def import_closure(self, path: str) -> set:
        """Transitive ``deps`` closure (excluding ``path`` itself)."""
        seen: set = set()
        stack = [path]
        while stack:
            for dep in self.deps(stack.pop()):
                if dep not in seen:
                    seen.add(dep)
                    stack.append(dep)
        seen.discard(path)
        return seen
