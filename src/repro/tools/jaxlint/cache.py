"""Content-hash incremental cache for whole-program lint runs.

The contract that makes caching sound is the project passes' attribution
discipline (see ``core`` docstring): every finding is attributed to the
file whose analysis produced it — the caller for cross-module taint, the
entry point's file for reachability — and that analysis reads only the
file itself plus its *import closure*.  A file's findings are therefore a
pure function of (its content, the contents of its transitive imports,
the analyzer config/rule set), and the invalidation rule is:

    dirty(f)  =  hash(f) changed
              or f is new / a cached dep of f was deleted
              or any file in f's current import closure is dirty

Graph edges invalidate dependents: editing ``data/pipeline.py`` re-lints
``train/step.py`` (which imports it) but not ``serve/queue.py``.  The
cache file (``--cache .jaxlint-cache.json``) stores per file: content
hash, direct intra-project deps, and the *post-pragma* findings (pragmas
are file content, so the hash covers them).  A config or rule-set change
flips the global fingerprint and invalidates everything.
"""

from __future__ import annotations

import hashlib
import json
import pathlib

VERSION = 2


def content_hash(source: str) -> str:
    return hashlib.sha256(source.encode("utf-8", "replace")).hexdigest()


def _fingerprint(config) -> str:
    from repro.tools.jaxlint.core import RULES
    blob = f"v{VERSION}|{sorted(RULES)}|{config!r}"
    return hashlib.sha256(blob.encode()).hexdigest()


def load(cache_path, config) -> dict | None:
    """Parsed cache data, or None when absent/invalid/stale-fingerprint."""
    try:
        data = json.loads(pathlib.Path(cache_path).read_text())
    except (OSError, ValueError):
        return None
    if not isinstance(data, dict) or data.get("version") != VERSION \
            or data.get("fingerprint") != _fingerprint(config) \
            or not isinstance(data.get("files"), dict):
        return None
    return data


def plan(cached: dict | None, hashes: dict, deps: dict
         ) -> tuple[set, dict]:
    """(dirty paths to re-analyze, {clean path: cached findings}).

    ``hashes`` is the current content hash per file; ``deps`` the current
    direct intra-project import edges.
    """
    from repro.tools.jaxlint.core import Finding

    if cached is None:
        return set(hashes), {}
    cfiles = cached["files"]
    changed = set()
    for path, h in hashes.items():
        entry = cfiles.get(path)
        if entry is None or entry.get("hash") != h:
            changed.add(path)
        elif any(d not in hashes for d in entry.get("deps", ())):
            changed.add(path)  # a dependency was deleted or moved

    # propagate along reverse import edges: dependents of changed files
    rev: dict[str, set] = {}
    for path, ds in deps.items():
        for d in ds:
            rev.setdefault(d, set()).add(path)
    dirty = set(changed)
    stack = list(changed)
    while stack:
        for dep in rev.get(stack.pop(), ()):
            if dep not in dirty:
                dirty.add(dep)
                stack.append(dep)

    reused = {
        path: [Finding(path, line, rule, message)
               for line, rule, message in cfiles[path].get("findings", ())]
        for path in hashes
        if path not in dirty
    }
    return dirty, reused


def save(cache_path, config, hashes: dict, deps: dict,
         per_path: dict) -> None:
    """Persist the run (best-effort: an unwritable cache never fails a
    lint)."""
    data = {
        "version": VERSION,
        "fingerprint": _fingerprint(config),
        "files": {
            path: {
                "hash": h,
                "deps": sorted(deps.get(path, ())),
                "findings": [[f.line, f.rule, f.message]
                             for f in per_path.get(path, ())],
            }
            for path, h in hashes.items()
        },
    }
    try:
        pathlib.Path(cache_path).write_text(json.dumps(data, indent=1))
    except OSError:
        pass
