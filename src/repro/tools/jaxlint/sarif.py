"""SARIF 2.1.0 serialization of jaxlint findings.

SARIF (Static Analysis Results Interchange Format) is the schema GitHub
code scanning and most SA dashboards ingest; emitting it makes jaxlint
findings first-class CI artifacts instead of log lines.  One run, one
tool (``jaxlint``), one result per finding; rule metadata comes from the
registry so the ``ruleIndex`` cross-references resolve.  The synthetic
PRAGMA / SYNTAX rules are appended so their findings validate too.
"""

from __future__ import annotations

SARIF_SCHEMA = ("https://raw.githubusercontent.com/oasis-tcs/sarif-spec/"
                "master/Schemata/sarif-schema-2.1.0.json")
SARIF_VERSION = "2.1.0"


def sarif_report(findings, rules: dict | None = None) -> dict:
    """SARIF run dict for ``findings`` (rule name -> summary in ``rules``;
    defaults to the live registry)."""
    if rules is None:
        from repro.tools.jaxlint.core import available_rules
        rules = available_rules()
    rules = dict(rules)
    rules.setdefault("PRAGMA", "malformed suppression pragma "
                               "(reasonless or unknown rule)")
    rules.setdefault("SYNTAX", "syntax error prevents linting")
    rule_ids = sorted(rules)
    index = {rid: i for i, rid in enumerate(rule_ids)}
    return {
        "$schema": SARIF_SCHEMA,
        "version": SARIF_VERSION,
        "runs": [{
            "tool": {
                "driver": {
                    "name": "jaxlint",
                    "informationUri": "docs/jaxlint.md",
                    "rules": [{
                        "id": rid,
                        "shortDescription": {"text": rules[rid]},
                    } for rid in rule_ids],
                },
            },
            "results": [{
                "ruleId": f.rule,
                "ruleIndex": index.get(f.rule, -1),
                "level": "error",
                "message": {"text": f.message},
                "locations": [{
                    "physicalLocation": {
                        "artifactLocation": {"uri": f.path},
                        "region": {"startLine": f.line},
                    },
                }],
            } for f in findings],
        }],
    }
