"""Repo tooling that is not part of the training/serving stack (static
checks, CI helpers).  Kept under ``repro`` so tier-1 tests can import it
without path games."""
