"""Distribution layer: logical-axis sharding rules and ambient constraints.

See ``repro.dist.sharding`` for the full contract.  Everything public is
re-exported here.
"""

from repro.dist.sharding import (AxisRules, MULTI_POD_RULES,
                                 SINGLE_POD_RULES, axes_to_spec,
                                 current_rules, is_axes, make_compat_mesh,
                                 param_shardings, shard, use_rules,
                                 with_overrides)

__all__ = [
    "AxisRules",
    "MULTI_POD_RULES",
    "SINGLE_POD_RULES",
    "axes_to_spec",
    "current_rules",
    "is_axes",
    "make_compat_mesh",
    "param_shardings",
    "shard",
    "use_rules",
    "with_overrides",
]
