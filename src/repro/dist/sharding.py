"""Logical-axis distribution layer: rules, ambient context, constraints.

Contract (the one every model/launch module codes against)
----------------------------------------------------------
Model code never names physical mesh axes.  It names *logical* axes —
``"batch"``, ``"fsdp"``, ``"tp"``, ``"layers"``, ``"act_seq"``,
``"cache_seq"`` — and an :class:`AxisRules` maps each logical name to a
physical mesh axis (a ``str``), a tuple of mesh axes (sharded over their
product, e.g. multi-pod batch over ``("pod", "data")``), or ``None``
(replicated).  Logical names absent from the mapping resolve to ``None``,
so model code may annotate axes that only some topologies shard (e.g.
``"cache_seq"``) without every rule set having to enumerate them.

The pieces:

- :data:`SINGLE_POD_RULES` / :data:`MULTI_POD_RULES` — the production
  mappings (see ``launch/mesh.py`` for the physical topologies).
- :func:`axes_to_spec` — logical-axes tuple -> ``PartitionSpec``.
- :func:`is_axes` — pytree leaf predicate for logical-axes tuples, so an
  axes pytree mirrors its param pytree (NamedTuples stay containers).
- :func:`use_rules` — context manager installing *ambient* rules; nestable,
  the innermost wins, exceptions restore the outer rules.
- :func:`shard` — ``with_sharding_constraint`` under the ambient rules.
  **Single-device degrade:** with no ambient rules, mesh-less rules, a
  one-device mesh, or a fully-replicated resulting spec, it returns its
  input untouched — which is why unit tests and CPU smoke runs execute the
  exact same model code with zero mesh setup.
- :func:`param_shardings` — axes pytree -> ``NamedSharding`` pytree for
  ``jit`` in/out shardings, checkpoint restore, and elastic resharding.

``make_compat_mesh`` papers over the ``jax.make_mesh`` signature change
(``axis_types=AxisType.Auto`` is mandatory for auto-sharding on newer jax,
nonexistent on 0.4.x); all mesh construction in this repo routes through it.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Mapping, Sequence

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec

# A logical axis maps to: one mesh axis, several (sharded over their
# product), or None (replicated).
MeshAxes = Any  # str | tuple[str, ...] | None


@dataclasses.dataclass(frozen=True)
class AxisRules:
    """A logical->physical axis mapping, optionally bound to a mesh.

    ``mesh=None`` rule sets are pure mappings (the module-level constants):
    usable with :func:`axes_to_spec` but not placeable.  Binding happens in
    ``launch/mesh.py::rules_for`` which re-wraps the mapping with the live
    mesh.  Instances are frozen; derive variants with :func:`with_overrides`.
    """

    rules: Mapping[str, MeshAxes]
    mesh: Mesh | None = None


SINGLE_POD_RULES = AxisRules(rules={
    "batch": "data",      # data parallelism
    "fsdp": "data",       # ZeRO-3 style param/optimizer sharding, same axis
    "tp": "model",        # tensor parallelism (heads / ff / vocab)
    "layers": None,       # scanned layer stacks stay replicated over L
    "act_seq": None,      # sequence stays local unless sequence_parallel
})

# Multi-pod: the batch additionally shards over the DCN-crossing "pod" axis
# (gradient all-reduce is the only cross-pod collective); everything else is
# identical to single-pod.
MULTI_POD_RULES = AxisRules(rules={
    **SINGLE_POD_RULES.rules,
    "batch": ("pod", "data"),
})


def is_axes(obj) -> bool:
    """Leaf predicate for logical-axes pytrees.

    True exactly for *plain* tuples whose members are all ``str`` or ``None``
    — including the empty tuple ``()`` (a scalar's axes).  NamedTuples are
    pytree containers holding axes tuples, so they must NOT be leaves; the
    ``type(obj) is tuple`` check (not ``isinstance``) excludes them, and any
    non-str member (dicts, ints, nested tuples) disqualifies the tuple.
    """
    return type(obj) is tuple and all(
        a is None or isinstance(a, str) for a in obj)


def axes_to_spec(axes: Sequence[str | None], rules: AxisRules) -> PartitionSpec:
    """Map a logical-axes tuple through ``rules`` to a ``PartitionSpec``.

    ``None`` entries and logical names absent from the mapping both resolve
    to ``None`` (replicated) — see the module docstring for why absence is
    deliberately legal.
    """
    return PartitionSpec(
        *(None if a is None else rules.rules.get(a) for a in axes))


def with_overrides(rules: AxisRules, **overrides: MeshAxes) -> AxisRules:
    """A new AxisRules with some logical axes remapped; the input is not
    mutated (rule sets are shared module-level constants)."""
    return AxisRules(rules={**rules.rules, **overrides}, mesh=rules.mesh)


# --------------------------------------------------------------------------
# ambient rules
# --------------------------------------------------------------------------

# A stack, not a slot: lowering one cell may nest rule scopes (e.g. decode
# artifacts overriding weight sharding inside the cell-wide scope).  Tracing
# happens on the caller's thread, so a module-level stack suffices.
_AMBIENT: list[AxisRules] = []


def current_rules() -> AxisRules | None:
    """The innermost ambient rules, or None outside any ``use_rules`` scope."""
    return _AMBIENT[-1] if _AMBIENT else None


class use_rules:
    """Context manager installing ``rules`` as the ambient rule set.

    Re-entrant and nestable: each ``__enter__`` pushes, each ``__exit__``
    pops exactly one frame (also on exceptions), so nested scopes restore
    the outer rules.  The instance may be constructed eagerly and entered
    later (``launch/train.py`` builds the context before the run loop).
    """

    def __init__(self, rules: AxisRules):
        self._rules = rules

    def __enter__(self) -> AxisRules:
        _AMBIENT.append(self._rules)
        return self._rules

    def __exit__(self, exc_type, exc, tb) -> bool:
        _AMBIENT.pop()
        return False


def shard(x, *logical_axes: str | None):
    """Constrain ``x`` to the sharding its logical axes imply ambiently.

    Identity when there is nothing to constrain against: no ambient rules,
    rules without a mesh, a single-device mesh, or a spec that came out
    fully replicated.  Skipping the fully-replicated constraint (rather than
    emitting a trivial one) keeps auto-sharding free to propagate through
    annotated-but-unsharded intermediates.
    """
    rules = current_rules()
    if rules is None or rules.mesh is None or rules.mesh.size <= 1:
        return x
    spec = axes_to_spec(logical_axes, rules)
    if all(entry is None for entry in spec):
        return x
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(rules.mesh, spec))


def param_shardings(axes_tree, rules: AxisRules):
    """Map an axes pytree to a ``NamedSharding`` pytree (leaf-for-leaf).

    Leaves are located with :func:`is_axes`, so the axes pytree must mirror
    the param pytree container-for-container with plain axes tuples at the
    leaves (this is what every ``*_param_axes`` / ``*_cache_axes`` returns).
    """
    if rules.mesh is None:
        raise ValueError(
            "param_shardings needs mesh-bound rules; wrap the mapping via "
            "launch.mesh.rules_for(mesh, ...) first")

    def one(axes):
        if not is_axes(axes):
            raise TypeError(
                f"axes tree leaf {axes!r} is not a logical-axes tuple")
        return NamedSharding(rules.mesh, axes_to_spec(axes, rules))

    return jax.tree.map(one, axes_tree, is_leaf=is_axes)


# --------------------------------------------------------------------------
# mesh construction compat
# --------------------------------------------------------------------------

def make_compat_mesh(axis_shapes: Sequence[int], axis_names: Sequence[str],
                     *, devices=None) -> Mesh:
    """``jax.make_mesh`` across jax versions.

    Newer jax (>= 0.5, explicit-sharding era) requires
    ``axis_types=(AxisType.Auto, ...)`` for the GSPMD auto-sharding this
    layer relies on; jax 0.4.x has neither ``AxisType`` nor the kwarg and
    is Auto-only.  Every mesh in the repo (production, dry-run, tests)
    comes from here so the divergence lives in one place.
    """
    try:
        from jax.sharding import AxisType
    except ImportError:
        return jax.make_mesh(axis_shapes, axis_names, devices=devices)
    return jax.make_mesh(axis_shapes, axis_names,
                         axis_types=(AxisType.Auto,) * len(axis_names),
                         devices=devices)
