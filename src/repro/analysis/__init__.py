from repro.analysis.hlo_cost import analyze_hlo
from repro.analysis.roofline import (TPU_V5E, model_flops_decode,
                                     model_flops_train, roofline_terms)
