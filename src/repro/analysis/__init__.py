from repro.analysis.roofline import (collective_bytes_from_hlo, roofline_terms,
                                     TPU_V5E)
