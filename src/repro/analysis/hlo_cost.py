"""Trip-count-aware cost model over compiled (post-SPMD) HLO text.

``compiled.cost_analysis()`` counts every ``while`` body ONCE — a scanned
layer stack (or q-chunk attention loop) is undercounted by its trip count.
This module parses the optimized HLO and computes, bottom-up through
fusions / to_apply / while bodies:

    flops            2 * prod(out dims) * prod(contracting dims) per dot,
                     multiplied through while trip counts
    hbm_bytes        operand+output bytes of *top-level* ops only (fusion
                     internals never touch HBM) — an HBM-traffic proxy far
                     closer to a TPU than cost_analysis' "bytes accessed"
    collective_bytes per-kind operand bytes of collectives, trip-multiplied

Trip counts come from the while op's ``backend_config known_trip_count``
(XLA annotates it), falling back to the condition's comparison constant.
Shapes in post-partitioning HLO are per-device => all numbers per-device.
"""

from __future__ import annotations

import dataclasses
import re
from collections import defaultdict

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "token": 0,
    "u1": 1, "s1": 1,
}

_RESULT_RE = re.compile(
    r"^(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*\(?\s*(pred|token|[a-z]+[0-9]+"
    r"(?:e[0-9]+m[0-9]+(?:fn)?)?)\[([0-9,]*)\]")
_SHAPE_RE = re.compile(
    r"\b(pred|token|[a-z]+[0-9]+(?:e[0-9]+m[0-9]+(?:fn)?)?)\[([0-9,]*)\]")
_OPND_RE = re.compile(r"%([\w\.\-]+)")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")


def _prod(dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n


def _nbytes(dtype: str, dims: str) -> int:
    return _prod(dims) * _DTYPE_BYTES.get(dtype, 4)


@dataclasses.dataclass
class _Cost:
    flops: float = 0.0
    flops_int8: float = 0.0  # subset of flops running on the int8 MXU path
    hbm_bytes: float = 0.0
    coll: dict = dataclasses.field(default_factory=lambda: defaultdict(float))
    hbm_by_kind: dict = dataclasses.field(default_factory=lambda: defaultdict(float))

    def add(self, other, k: float = 1.0, bytes_too: bool = True):
        self.flops += other.flops * k
        self.flops_int8 += other.flops_int8 * k
        if bytes_too:
            self.hbm_bytes += other.hbm_bytes * k
            for key, v in other.hbm_by_kind.items():
                self.hbm_by_kind[key] += v * k
        for key, v in other.coll.items():
            self.coll[key] += v * k


def _split(hlo: str):
    """-> (comps: name -> [op lines], shapes: name -> {op: (dtype, dims)})."""
    comps: dict[str, list[str]] = {}
    shapes: dict[str, dict[str, tuple]] = {}
    cur = None
    for raw in hlo.splitlines():
        line = raw.rstrip()
        s = line.strip()
        if not s or s.startswith("//") or s.startswith(("HloModule", "FileNames",
                                                        "FunctionNames",
                                                        "FileLocations",
                                                        "StackFrames")):
            continue
        if cur is None:
            m = re.match(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(", line)
            if m and line.endswith("{") and "->" in line:
                cur = m.group(1)
                comps[cur] = []
                shapes[cur] = {}
            continue
        if s == "}":
            cur = None
            continue
        comps[cur].append(s)
        rm = _RESULT_RE.match(s)
        if rm:
            shapes[cur][rm.group(1)] = (rm.group(2), rm.group(3))
        # tuple-typed results: record first element shape only (good enough)
    return comps, shapes


def _operand_shapes(rhs: str, local: dict, n: int | None = None):
    """Shapes of %ref operands inside the op's argument parens."""
    if "(" not in rhs:
        return []
    call = rhs[rhs.index("("):]
    # cut at parens close: operands live before attribute list
    depth = 0
    end = len(call)
    for i, ch in enumerate(call):
        if ch == "(":
            depth += 1
        elif ch == ")":
            depth -= 1
            if depth == 0:
                end = i
                break
    args = call[1:end]
    out = []
    for m in _OPND_RE.finditer(args):
        nm = m.group(1)
        if nm in local:
            out.append(local[nm])
        if n and len(out) >= n:
            break
    # also inline shapes (rare in optimized HLO but possible)
    if not out:
        out = [(dt, dims) for dt, dims in _SHAPE_RE.findall(args)]
    return out


def _op_kind(rhs: str) -> str:
    """The HLO opcode: first token after the result type (which may be a
    tuple like ``(s32[], /*index=5*/f32[8,8]{1,0})``)."""
    m = re.match(r"^(?:\([^()]*\)|\S+)\s+([\w\-]+)\(", rhs)
    return m.group(1) if m else ""


_NO_HBM = {"parameter", "constant", "get-tuple-element", "tuple", "bitcast",
           "bitcast-convert", "after-all", "partition-id", "replica-id",
           "iota", "broadcast", "while", "conditional", "call"}

# --- effective HBM traffic per op (TPU fusion-aware proxy) -----------------
# Pure elementwise ops fuse into producers/consumers under XLA-TPU => 0.
# Data-movement ops touch only the moved region (a fused dynamic-slice reads
# the slice, not its operand buffer; a DUS writes the update region, not the
# accumulator).  Dots/reduces stream their operands.  Documented proxy —
# see EXPERIMENTS.md §Roofline methodology.

_STREAM_OPS = {"dot", "convolution", "reduce", "reduce-window",
               "select-and-scatter", "custom-call", "cholesky",
               "triangular-solve", "all-gather", "all-reduce",
               "reduce-scatter", "all-to-all", "collective-permute",
               "all-gather-start", "all-reduce-start", "send", "recv"}
_MOVE2X_OPS = {"slice", "copy", "copy-start", "transpose", "reverse",
               "concatenate", "pad", "gather", "scatter", "sort",
               "rng", "rng-bit-generator"}


def _op_traffic(kind: str, line: str, rhs: str, local: dict) -> float:
    rm = _RESULT_RE.match(line)
    out_b = _nbytes(rm.group(2), rm.group(3)) if rm else 0.0
    if kind in _STREAM_OPS:
        return out_b + sum(_nbytes(dt, d) for dt, d in _operand_shapes(rhs, local))
    if kind in _MOVE2X_OPS:
        return 2.0 * out_b
    if kind == "dynamic-slice":
        return out_b  # reads just the slice (fused), writes fuse onward
    if kind == "dynamic-update-slice":
        ops = _operand_shapes(rhs, local)
        upd = _nbytes(*ops[1]) if len(ops) > 1 else out_b
        return 2.0 * upd  # read update + write region; accumulator aliased
    return 0.0  # elementwise & friends: fused on TPU


def analyze_hlo(hlo: str) -> dict:
    comps, shapes = _split(hlo)
    memo: dict[str, _Cost] = {}
    warnings: list[str] = []

    def cost_of(name: str) -> _Cost:
        if name in memo:
            return memo[name]
        memo[name] = _Cost()  # cycle guard
        local = shapes.get(name, {})
        total = _Cost()
        for line in comps.get(name, ()):
            if " = " not in line:
                continue
            lhs, rhs = line.split(" = ", 1)
            kind = _op_kind(rhs)
            c = _Cost()
            # ---- flops
            if kind in ("dot", "convolution"):
                rm = _RESULT_RE.match(line)
                out_elems = _prod(rm.group(3)) if rm else 0
                opnds = _operand_shapes(rhs, local, n=2)
                contract = 1
                m = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", rhs)
                if m and opnds:
                    ldims = opnds[0][1].split(",") if opnds[0][1] else []
                    for ci in m.group(1).split(","):
                        if ci and int(ci) < len(ldims):
                            contract *= int(ldims[int(ci)])
                elif kind == "convolution" and len(opnds) == 2:
                    contract = max(_prod(opnds[1][1]) // max(out_elems, 1), 1)
                f = 2.0 * out_elems * contract
                c.flops += f
                if opnds and opnds[0][0] in ("s8", "u8", "s4", "u4"):
                    c.flops_int8 += f  # int8 MXU path (2x bf16 rate)
            # ---- collectives
            base_kind = kind.replace("-start", "").replace("-done", "")
            if base_kind in _COLLECTIVES and not kind.endswith("-done"):
                opnds = _operand_shapes(rhs, local)
                c.coll[base_kind] += sum(_nbytes(dt, d) for dt, d in opnds)
            # ---- hbm bytes: per-op effective traffic (TPU fusion proxy)
            if kind not in _NO_HBM and kind != "fusion":
                t = _op_traffic(kind, line, rhs, local)
                if t:
                    c.hbm_bytes += t
                    c.hbm_by_kind[kind] += t
            # ---- control flow / called computations
            if kind == "while":
                body = re.search(r"body=%?([\w\.\-]+)", rhs)
                cond = re.search(r"condition=%?([\w\.\-]+)", rhs)
                trip = 1
                tm = _TRIP_RE.search(rhs)
                if tm:
                    trip = int(tm.group(1))
                elif cond and cond.group(1) in comps:
                    consts = [int(x) for ln in comps[cond.group(1)]
                              for x in re.findall(r"constant\((\d+)\)", ln)]
                    trip = max(consts) if consts else 1
                    warnings.append(f"while {lhs.strip()}: trip from cond={trip}")
                if body:
                    c.add(cost_of(body.group(1)), k=trip, bytes_too=True)
            elif kind == "fusion":
                # flops + effective traffic of the ops inside the fusion
                m = re.search(r"calls=%?([\w\.\-]+)", rhs)
                if m:
                    c.add(cost_of(m.group(1)), bytes_too=True)
            elif kind == "conditional":
                for m in re.finditer(r"branch_computations=\{([^}]*)\}", rhs):
                    names = [x.strip().lstrip("%") for x in m.group(1).split(",")]
                    if names:  # count the most expensive branch
                        branch_costs = [cost_of(n) for n in names]
                        c.add(max(branch_costs, key=lambda b: b.flops))
            elif kind in ("call", "async-start"):
                m = re.search(r"(?:to_apply|calls)=%?([\w\.\-]+)", rhs)
                if m:
                    c.add(cost_of(m.group(1)))
            # reduce/map/sort to_apply bodies: elementwise, negligible flops
            total.add(c)
        memo[name] = total
        return total

    entry = None
    for line in hlo.splitlines():
        if line.startswith("ENTRY"):
            m = re.match(r"ENTRY\s+%?([\w\.\-]+)", line)
            if m:
                entry = m.group(1)
            break
    if entry is None or entry not in comps:
        entry = max(comps, key=lambda k: len(comps[k]))
    c = cost_of(entry)
    return {
        "flops": c.flops,
        "flops_int8": c.flops_int8,
        "hbm_bytes": c.hbm_bytes,
        "hbm_by_kind": dict(sorted(c.hbm_by_kind.items(),
                                   key=lambda kv: -kv[1])),
        "collectives": {**dict(c.coll), "total": sum(c.coll.values())},
        "entry": entry,
        "n_computations": len(comps),
        "warnings": warnings[:5],
    }
