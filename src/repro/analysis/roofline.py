"""Roofline-term extraction from dry-run artifacts (DESIGN/EXPERIMENTS §Roofline).

    compute term    = HLO_FLOPs / (chips * peak_FLOP/s)
    memory term     = HLO_bytes / (chips * HBM_bw)
    collective term = collective_bytes / (chips * link_bw)

FLOPs/bytes come from ``compiled.cost_analysis()``; collective bytes are NOT
in cost_analysis, so we parse the post-SPMD-partitioning HLO text (shapes are
per-device there) and sum operand sizes of every all-gather / all-reduce /
reduce-scatter / all-to-all / collective-permute.

Hardware constants (TPU v5e): 197 TFLOP/s bf16 (394 TOPS int8), 819 GB/s HBM,
~50 GB/s/link ICI.
"""

from __future__ import annotations

import re
from collections import defaultdict

TPU_V5E = {
    "peak_bf16_flops": 197e12,
    "peak_int8_ops": 394e12,
    "hbm_gbps": 819e9,
    "ici_link_gbps": 50e9,
    "hbm_bytes": 16 * 2 ** 30,
}

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

# e.g.  bf16[16,4096,128]{2,1,0}   or  f32[] ()
_SHAPE_RE = re.compile(r"\b([a-z]+[0-9]+(?:e[0-9]+m[0-9]+(?:fn)?)?|pred)\[([0-9,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def collective_bytes_from_hlo(hlo_text: str) -> dict:
    """Sum operand bytes per collective kind from (post-partitioning) HLO.

    Returns {"all-reduce": bytes, ..., "total": bytes, "count": n_ops}.
    Operand shapes are taken from inside the op's argument parens; shapes in
    partitioned HLO are per-device, so totals are per-device wire bytes.
    """
    out: dict = defaultdict(int)
    count = 0
    for line in hlo_text.splitlines():
        s = line.strip()
        if s.startswith("//") or " = " not in s:
            continue
        rhs = s.split(" = ", 1)[1]
        kind = None
        for c in COLLECTIVES:
            # match the op name at the start of the op call, e.g.
            # "bf16[...] all-gather(bf16[...] %x), replica_groups=..."
            if re.search(rf"\]\S*\s+{c}(-start|-done)?\(", rhs) or \
               rhs.startswith(f"{c}("):
                kind = c
                break
        if kind is None:
            continue
        if "-done(" in rhs:
            continue  # the -start op already carries the operands
        count += 1
        # operands = shapes inside the outermost parens of the call
        call = rhs[rhs.index("("):]
        for m in _SHAPE_RE.finditer(call):
            out[kind] += _shape_bytes(m.group(1), m.group(2))
    out["total"] = sum(out[c] for c in COLLECTIVES if c in out)
    out["count"] = count
    return dict(out)


def roofline_terms(*, flops_per_device: float, bytes_per_device: float,
                   collective_bytes_per_device: float, chips: int,
                   model_flops_total: float = 0.0,
                   int8_fraction: float = 0.0) -> dict:
    """All terms in seconds (per step, per device — the SPMD steady state).

    ``int8_fraction``: fraction of FLOPs executing on the int8 MXU path
    (2x rate) when the paper's QAT technique is active — each fraction runs
    at its own peak, so the times add.
    """
    t_compute = (flops_per_device * (1 - int8_fraction)
                 / TPU_V5E["peak_bf16_flops"]
                 + flops_per_device * int8_fraction
                 / TPU_V5E["peak_int8_ops"])
    t_memory = bytes_per_device / TPU_V5E["hbm_gbps"]
    t_coll = collective_bytes_per_device / TPU_V5E["ici_link_gbps"]
    terms = {"t_compute_s": t_compute, "t_memory_s": t_memory,
             "t_collective_s": t_coll}
    dominant = max(terms, key=terms.get)
    t_bound = terms[dominant]
    useful = (model_flops_total / chips / max(flops_per_device, 1.0)
              if model_flops_total else None)
    return {
        **terms,
        "dominant": dominant.replace("t_", "").replace("_s", ""),
        "t_bound_s": t_bound,
        # fraction of the roofline achieved if perfectly overlapped:
        "roofline_fraction": t_compute / max(t_bound, 1e-30),
        "useful_flops_ratio": useful,
        "chips": chips,
    }


def model_flops_train(n_params_active: int, n_tokens: int) -> float:
    """MODEL_FLOPS = 6 * N * D (dense) / 6 * N_active * D (MoE)."""
    return 6.0 * n_params_active * n_tokens


def model_flops_decode(n_params_active: int, n_tokens: int,
                       kv_bytes_touched: float = 0.0) -> float:
    """Decode is 2*N per token (fwd only) + attention reads; we report the
    matmul part for the useful-ratio metric."""
    return 2.0 * n_params_active * n_tokens
