"""Roofline-term arithmetic over HLO cost numbers (DESIGN/EXPERIMENTS §Roofline).

    compute term    = HLO_FLOPs / (chips * peak_FLOP/s)
    memory term     = HLO_bytes / (chips * HBM_bw)
    collective term = collective_bytes / (chips * link_bw)

FLOPs, HBM-proxy bytes and collective wire bytes all come from the
trip-count-aware HLO analyzer (``repro.analysis.hlo_cost.analyze_hlo``) over
the post-SPMD-partitioning module text (shapes are per-device there); this
module turns them into time terms and the dominant bound.  Call sites:
``launch.dryrun`` (the LM-zoo roofline records) and
``benchmarks.serve_autotune`` (the int8 serving block-shape pass).

Hardware constants (TPU v5e): 197 TFLOP/s bf16 (394 TOPS int8), 819 GB/s HBM,
~50 GB/s/link ICI.
"""

from __future__ import annotations

TPU_V5E = {
    "peak_bf16_flops": 197e12,
    "peak_int8_ops": 394e12,
    "hbm_gbps": 819e9,
    "ici_link_gbps": 50e9,
    "hbm_bytes": 16 * 2 ** 30,
}


def roofline_terms(*, flops_per_device: float, bytes_per_device: float,
                   collective_bytes_per_device: float, chips: int,
                   model_flops_total: float = 0.0,
                   int8_fraction: float = 0.0) -> dict:
    """All terms in seconds (per step, per device — the SPMD steady state).

    ``int8_fraction``: fraction of FLOPs executing on the int8 MXU path
    (2x rate) when the paper's QAT technique is active — each fraction runs
    at its own peak, so the times add.
    """
    t_compute = (flops_per_device * (1 - int8_fraction)
                 / TPU_V5E["peak_bf16_flops"]
                 + flops_per_device * int8_fraction
                 / TPU_V5E["peak_int8_ops"])
    t_memory = bytes_per_device / TPU_V5E["hbm_gbps"]
    t_coll = collective_bytes_per_device / TPU_V5E["ici_link_gbps"]
    terms = {"t_compute_s": t_compute, "t_memory_s": t_memory,
             "t_collective_s": t_coll}
    dominant = max(terms, key=terms.get)
    t_bound = terms[dominant]
    useful = (model_flops_total / chips / max(flops_per_device, 1.0)
              if model_flops_total else None)
    return {
        **terms,
        "dominant": dominant.replace("t_", "").replace("_s", ""),
        "t_bound_s": t_bound,
        # fraction of the roofline achieved if perfectly overlapped:
        "roofline_fraction": t_compute / max(t_bound, 1e-30),
        "useful_flops_ratio": useful,
        "chips": chips,
    }


def model_flops_train(n_params_active: int, n_tokens: int) -> float:
    """MODEL_FLOPS = 6 * N * D (dense) / 6 * N_active * D (MoE)."""
    return 6.0 * n_params_active * n_tokens


def model_flops_decode(n_params_active: int, n_tokens: int,
                       kv_bytes_touched: float = 0.0) -> float:
    """Decode is 2*N per token (fwd only) + attention reads; we report the
    matmul part for the useful-ratio metric."""
    return 2.0 * n_params_active * n_tokens
