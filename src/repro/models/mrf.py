"""The MRF reconstruction MLPs as ordinary registry architectures.

The DRONE/Barbieri lineage treats the reconstruction net as a plain trainable
model; this adapter does the same for our stack: ``build_mrf`` wraps
``core/mrf_net`` into the ``ModelFns`` shape so ``--arch mrf-fpga`` flows
through the exact launcher -> engine -> ft.runner path the LM zoo uses.

Batches are ``{"x": (B, 2F), "y": (B, 2)}`` dicts from
``data/pipeline.make_batch_factory``.  Activations are annotated with the
``batch`` logical axis via ``repro.dist.sharding.shard``, so the same loss
runs mesh-less on CPU (shard degrades to identity) and data-parallel on a
mesh.  The net is tiny (<30k params) so params stay replicated (all-``None``
axes) — sharding them would cost more in collectives than it saves.
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core import mrf_net, qat
from repro.dist.sharding import shard
from repro.models.lm import ModelFns


def mrf_sizes(cfg: ModelConfig) -> tuple:
    return mrf_net.layer_sizes(cfg.mrf_n_frames, cfg.mrf_hidden)


def mrf_param_axes(cfg: ModelConfig):
    sizes = mrf_sizes(cfg)
    return [{"w": (None, None), "b": (None,)} for _ in range(len(sizes) - 1)]


def float_loss(params, batch):
    x = shard(batch["x"], "batch", None)
    y = shard(batch["y"], "batch", None)
    pred = mrf_net.forward(params, x)
    return jnp.mean(jnp.square(pred - y))


def qat_loss(params, qstate, batch):
    """Aux-carrying QAT loss (``aux_loss=True`` contract of make_train_step):
    fake-quant forward updates the activation observers functionally."""
    x = shard(batch["x"], "batch", None)
    y = shard(batch["y"], "batch", None)
    pred, new_qstate = qat.forward_qat(params, qstate, x, train=True)
    return jnp.mean(jnp.square(pred - y)), new_qstate


def init_qat_aux(params):
    return qat.init_qat_state(len(params))


def build_mrf(cfg: ModelConfig, tp: int = 1) -> ModelFns:
    sizes = mrf_sizes(cfg)

    def init(key):
        return mrf_net.init_params(key, sizes)

    def predict(params, batch):
        """No KV cache for a feed-forward net: "prefill" is just inference."""
        return None, mrf_net.forward(params, shard(batch["x"], "batch", None))

    def no_cache(*_a, **_k):
        raise NotImplementedError(
            f"{cfg.name} is a feed-forward reconstruction net: no "
            "decode/cache path (use prefill for inference)")

    return ModelFns(cfg=cfg, tp=tp, init=init,
                    param_axes=lambda: mrf_param_axes(cfg),
                    loss=float_loss, prefill=predict, decode=no_cache,
                    init_cache=no_cache)
