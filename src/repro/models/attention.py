"""GQA attention: chunked-causal training/prefill path and split-KV decode.

Training/prefill: online q-chunked attention — scores are materialised one
query chunk at a time (memory O(chunk * S) instead of O(S^2)), full softmax
per row.  Causal, sliding-window, and cross (unmasked) variants share one
code path via the mask rule.

Decode: the KV cache is *sequence-sharded* over the ``model`` mesh axis
("cache_seq" logical axis).  Scores/softmax/AV are expressed as plain einsums
with sharding constraints; GSPMD turns the softmax max/sum and the AV
contraction into the flash-decoding LSE-combine collectives (small
all-reduces of (B, Hq)-sized stats) — exact for any head count, no KV head
replication (DESIGN.md §5).

TP head padding happens at *param construction* (configs.base.padded_heads):
the q-head count Hq' divides tp and the KV heads are group-replicated to
Hkv' = tp when the true counts don't divide.  Padded q heads have zero
wq/wo weights so they contribute nothing (waste is charged to the
MODEL_FLOPS/HLO_FLOPS ratio in the roofline).
"""

from __future__ import annotations

import math
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.models.common import apply_rope, dense, normal_init, shard

NEG_INF = -1e30


class AttnParams(NamedTuple):
    wq: jnp.ndarray   # (d, Hq*dh)
    wk: jnp.ndarray   # (d, Hkv*dh)
    wv: jnp.ndarray   # (d, Hkv*dh)
    wo: jnp.ndarray   # (Hq*dh, d)
    bq: jnp.ndarray | None
    bk: jnp.ndarray | None
    bv: jnp.ndarray | None


def init_attn(keys, d_model, hq, hkv, dh, qkv_bias=False, true_hq=None):
    """true_hq: unpadded query-head count — padded heads get zero weights."""
    wq = normal_init(next(keys), (d_model, hq * dh))
    wo = normal_init(next(keys), (hq * dh, d_model), scale=0.02 / math.sqrt(2))
    if true_hq is not None and true_hq < hq:
        wq = wq.at[:, true_hq * dh:].set(0.0)
        wo = wo.at[true_hq * dh:, :].set(0.0)
    return AttnParams(
        wq=wq,
        wk=normal_init(next(keys), (d_model, hkv * dh)),
        wv=normal_init(next(keys), (d_model, hkv * dh)),
        wo=wo,
        bq=jnp.zeros((hq * dh,), jnp.float32) if qkv_bias else None,
        bk=jnp.zeros((hkv * dh,), jnp.float32) if qkv_bias else None,
        bv=jnp.zeros((hkv * dh,), jnp.float32) if qkv_bias else None,
    )


def attn_axes(qkv_bias=False):
    return AttnParams(
        wq=(None, "fsdp", "tp"), wk=(None, "fsdp", "tp"), wv=(None, "fsdp", "tp"),
        wo=(None, "tp", "fsdp"),
        bq=(None, "tp") if qkv_bias else None,
        bk=(None, "tp") if qkv_bias else None,
        bv=(None, "tp") if qkv_bias else None,
    )


# --------------------------------------------------------------------------
# Chunked attention core (train / prefill)
# --------------------------------------------------------------------------

def _mask(q_pos, k_pos, *, causal: bool, window):
    """(Cq, S) boolean keep-mask. ``window`` may be a traced scalar (hybrid
    models switch SWA/global per layer inside a scan); None = no window."""
    keep = jnp.ones((q_pos.shape[0], k_pos.shape[0]), bool)
    if causal:
        keep &= k_pos[None, :] <= q_pos[:, None]
    if window is not None:
        keep &= k_pos[None, :] > q_pos[:, None] - window
    return keep


def attention(q, k, v, *, causal: bool = True, window=None,
              q_chunk: int = 512, q_offset=0):
    """q: (B, Sq, Hq, dh); k, v: (B, Sk, Hkv, dh). Returns (B, Sq, Hq, dh).

    Hq must be a multiple of Hkv (GQA grouping).  Scans over query chunks so
    peak memory is O(B * Hq * q_chunk * Sk).

    With a *static* sliding window the banded path is used: each query chunk
    only sees its (window + chunk)-wide KV band instead of the full Sk —
    score-slab memory and FLOPs drop by ~Sk/(window+chunk) (§Perf lever).
    """
    b, sq, hq, dh = q.shape
    sk, hkv = k.shape[1], k.shape[2]
    if (isinstance(window, int) and window and causal and sq == sk
            and sk >= 2 * (window + q_chunk)):
        return _attention_banded(q, k, v, window=window, q_chunk=q_chunk)
    g = hq // hkv
    scale = 1.0 / math.sqrt(dh)
    nq = max(sq // q_chunk, 1)
    q_chunk = sq // nq
    assert sq % q_chunk == 0, (sq, q_chunk)

    qc = q.reshape(b, nq, q_chunk, hkv, g, dh).transpose(1, 0, 2, 3, 4, 5)
    k_pos = jnp.arange(sk)

    def one_chunk(i, q_i):
        # q_i: (B, Cq, Hkv, G, dh) — bf16 operands, f32 accumulation (MXU-
        # native); probs cast back to bf16 for the AV matmul.
        s = jnp.einsum("bqhgd,bkhd->bqhgk", q_i, k, optimize=True,
                       preferred_element_type=jnp.float32) * scale
        q_pos = q_offset + i * q_chunk + jnp.arange(q_chunk)
        keep = _mask(q_pos, k_pos, causal=causal, window=window)
        s = jnp.where(keep[None, :, None, None, :], s, NEG_INF)
        p = jax.nn.softmax(s, axis=-1).astype(v.dtype)
        return jnp.einsum("bqhgk,bkhd->bqhgd", p, v, optimize=True,
                          preferred_element_type=jnp.float32).astype(q.dtype)

    out = jax.lax.map(lambda args: one_chunk(*args), (jnp.arange(nq), qc))
    return out.transpose(1, 0, 2, 3, 4, 5).reshape(b, sq, hq, dh)


def _attention_banded(q, k, v, *, window: int, q_chunk: int):
    """Sliding-window attention over static KV bands.

    K/V are front-padded by ``window`` so query chunk i's band starts at a
    static offset i*C with static size window + C; band positions outside
    [0, Sq) or the window are masked.
    """
    b, sq, hq, dh = q.shape
    hkv = k.shape[2]
    g = hq // hkv
    scale = 1.0 / math.sqrt(dh)
    nq = sq // q_chunk
    assert sq % q_chunk == 0
    band = window + q_chunk

    kp = jnp.pad(k, ((0, 0), (window, 0), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (window, 0), (0, 0), (0, 0)))
    qc = q.reshape(b, nq, q_chunk, hkv, g, dh).transpose(1, 0, 2, 3, 4, 5)

    def one_chunk(i, q_i):
        k_b = jax.lax.dynamic_slice_in_dim(kp, i * q_chunk, band, axis=1)
        v_b = jax.lax.dynamic_slice_in_dim(vp, i * q_chunk, band, axis=1)
        s = jnp.einsum("bqhgd,bkhd->bqhgk", q_i, k_b, optimize=True,
                       preferred_element_type=jnp.float32) * scale
        q_pos = i * q_chunk + jnp.arange(q_chunk)            # global q pos
        k_pos = i * q_chunk + jnp.arange(band) - window      # global k pos
        keep = (k_pos[None, :] <= q_pos[:, None]) \
            & (k_pos[None, :] > q_pos[:, None] - window) \
            & (k_pos[None, :] >= 0)
        s = jnp.where(keep[None, :, None, None, :], s, NEG_INF)
        p = jax.nn.softmax(s, axis=-1).astype(v_b.dtype)
        return jnp.einsum("bqhgk,bkhd->bqhgd", p, v_b, optimize=True,
                          preferred_element_type=jnp.float32).astype(q.dtype)

    out = jax.lax.map(lambda args: one_chunk(*args), (jnp.arange(nq), qc))
    return out.transpose(1, 0, 2, 3, 4, 5).reshape(b, sq, hq, dh)


# --------------------------------------------------------------------------
# Full attention block (residual-stream in/out) for train & prefill
# --------------------------------------------------------------------------

def attn_block(p: AttnParams, x, *, cfg_heads, rope_theta, causal=True,
               window=None, positions=None, quant="none", return_kv=False,
               kv_source=None):
    """x: (B, S, d). cfg_heads = (hq, hkv, dh). kv_source: encoder states for
    cross-attention (defaults to x)."""
    hq, hkv, dh = cfg_heads
    b, s, _ = x.shape
    src = x if kv_source is None else kv_source
    sk = src.shape[1]
    if positions is None:
        positions = jnp.arange(s)[None, :]
    q = dense(x, p.wq, p.bq, quant=quant).reshape(b, s, hq, dh)
    k = dense(src, p.wk, p.bk, quant=quant).reshape(b, sk, hkv, dh)
    v = dense(src, p.wv, p.bv, quant=quant).reshape(b, sk, hkv, dh)
    if kv_source is None and rope_theta:  # no rope on cross-attention
        q = apply_rope(q, positions, rope_theta)
        k = apply_rope(k, jnp.arange(sk)[None, :], rope_theta)
    q = shard(q, "batch", None, "tp", None)
    k = shard(k, "batch", None, "tp", None)
    v = shard(v, "batch", None, "tp", None)
    out = attention(q, k, v, causal=causal, window=window)
    out = shard(out, "batch", None, "tp", None)
    y = dense(out.reshape(b, s, hq * dh), p.wo, quant=quant)
    if return_kv:
        return y, (k, v)
    return y


# --------------------------------------------------------------------------
# Decode: one token against a sequence-sharded KV cache
# --------------------------------------------------------------------------

def decode_attention(q1, k_cache, v_cache, cache_len, *, window: int = 0):
    """q1: (B, Hq, dh); caches: (B, S, Hkv, dh) with "cache_seq" sharded over
    the model axis.  Returns (B, Hq, dh).

    Plain einsum + softmax over the sharded S axis: GSPMD emits the
    flash-decoding style partial-softmax combine (all-reduce of max / sum /
    weighted values over the model axis).
    """
    b, hq, dh = q1.shape
    sk, hkv = k_cache.shape[1], k_cache.shape[2]
    g = hq // hkv
    scale = 1.0 / math.sqrt(dh)
    qg = q1.reshape(b, hkv, g, dh).astype(k_cache.dtype)
    s = jnp.einsum("bhgd,bkhd->bhgk", qg, k_cache, optimize=True,
                   preferred_element_type=jnp.float32) * scale
    pos = jnp.arange(sk)
    keep = pos[None, :] < cache_len  # (1, Sk)
    if window:
        keep &= pos[None, :] >= cache_len - window
    s = jnp.where(keep[:, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1).astype(v_cache.dtype)
    out = jnp.einsum("bhgk,bkhd->bhgd", p, v_cache, optimize=True,
                     preferred_element_type=jnp.float32)
    return out.reshape(b, hq, dh).astype(q1.dtype)


def cache_update(cache, new, index):
    """Write one token's K or V (B, Hkv, dh) at sequence position ``index``
    (ring-buffer modulo capacity) into a (B, S, Hkv, dh) cache."""
    capacity = cache.shape[1]
    idx = jnp.mod(index, capacity)
    return jax.lax.dynamic_update_slice(
        cache, new[:, None].astype(cache.dtype), (0, idx, 0, 0))


def decode_attn_block(p: AttnParams, x1, cache_k, cache_v, cache_len, *,
                      cfg_heads, rope_theta, window=0, quant="none",
                      cross_kv=None):
    """x1: (B, d) single-token residual. cache_*: (B, S, Hkv, dh).
    Returns (y1, new_cache_k, new_cache_v)."""
    hq, hkv, dh = cfg_heads
    b, _ = x1.shape
    q = dense(x1, p.wq, p.bq, quant=quant).reshape(b, hq, dh)
    if cross_kv is not None:
        k_cache, v_cache = cross_kv
        out = decode_attention(q, k_cache, v_cache, k_cache.shape[1])
        y = dense(out.reshape(b, hq * dh), p.wo, quant=quant)
        return y, cache_k, cache_v
    k = dense(x1, p.wk, p.bk, quant=quant).reshape(b, hkv, dh)
    v = dense(x1, p.wv, p.bv, quant=quant).reshape(b, hkv, dh)
    if rope_theta:
        pos = cache_len[None, None] if jnp.ndim(cache_len) == 0 else cache_len[:, None]
        q = apply_rope(q[:, None], pos, rope_theta)[:, 0]
        k = apply_rope(k[:, None], pos, rope_theta)[:, 0]
    cache_k = cache_update(cache_k, k, cache_len)
    cache_v = cache_update(cache_v, v, cache_len)
    cache_k = shard(cache_k, "batch", "cache_seq", None, None)
    cache_v = shard(cache_v, "batch", "cache_seq", None, None)
    out = decode_attention(q, cache_k, cache_v, cache_len + 1, window=window)
    y = dense(out.reshape(b, hq * dh), p.wo, quant=quant)
    return y, cache_k, cache_v
