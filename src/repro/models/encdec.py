"""Encoder-decoder backbone (seamless-m4t-v2 assignment).

The speech/multimodal frontend is a STUB per the assignment: ``input_specs``
provides precomputed frame embeddings (B, S_enc, d) directly (the w2v-BERT
conformer stack is out of scope); we implement the full transformer backbone:
bidirectional encoder, causal decoder with cross-attention, CE loss, prefill
(encoder pass + cross-KV build) and single-token decode against a
sequence-sharded self-attention cache + static cross cache.

Convention: S_enc = seq_len // 4 (frames are 4x shorter than text tokens for
the assigned shape cells; DESIGN.md §4).
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as attn
from repro.models.common import key_iter, normal_init, rms_norm, shard
from repro.models.lm import ModelFns, cross_entropy, _logits
from repro.models.mlp import init_mlp, mlp_axes, mlp_block

ENC_FRACTION = 4  # S_enc = seq_len // ENC_FRACTION


def enc_len_for(seq_len: int) -> int:
    return max(seq_len // ENC_FRACTION, 8)


def _enc_layer_init(cfg, keys, hq, hkv, dh):
    return {
        "ln1": jnp.ones((cfg.d_model,), jnp.float32),
        "attn": attn.init_attn(keys, cfg.d_model, hq, hkv, dh, cfg.qkv_bias,
                               true_hq=cfg.n_heads),
        "ln2": jnp.ones((cfg.d_model,), jnp.float32),
        "mlp": init_mlp(keys, cfg.d_model, cfg.d_ff, cfg.gated_mlp),
    }


def _dec_layer_init(cfg, keys, hq, hkv, dh):
    base = _enc_layer_init(cfg, keys, hq, hkv, dh)
    base["ln_cross"] = jnp.ones((cfg.d_model,), jnp.float32)
    base["cross"] = attn.init_attn(keys, cfg.d_model, hq, hkv, dh,
                                   cfg.qkv_bias, true_hq=cfg.n_heads)
    return base


def init_encdec(cfg: ModelConfig, key, tp: int = 1):
    keys = key_iter(key)
    hq, hkv = cfg.padded_heads(tp)
    dh = cfg.head_dim
    vp = cfg.padded_vocab(tp)
    enc = [_enc_layer_init(cfg, keys, hq, hkv, dh) for _ in range(cfg.n_enc_layers)]
    dec = [_dec_layer_init(cfg, keys, hq, hkv, dh) for _ in range(cfg.n_layers)]
    return {
        "enc": {"layers": jax.tree.map(lambda *x: jnp.stack(x), *enc),
                "norm": jnp.ones((cfg.d_model,), jnp.float32)},
        "dec": {"embed": normal_init(next(keys), (vp, cfg.d_model)),
                "layers": jax.tree.map(lambda *x: jnp.stack(x), *dec),
                "norm": jnp.ones((cfg.d_model,), jnp.float32)},
        "head": normal_init(next(keys), (cfg.d_model, vp)),
    }


def encdec_param_axes(cfg: ModelConfig):
    enc_layer = {"ln1": (None, None), "attn": attn.attn_axes(cfg.qkv_bias),
                 "ln2": (None, None), "mlp": mlp_axes(cfg.gated_mlp)}
    dec_layer = dict(enc_layer)
    dec_layer["ln_cross"] = (None, None)
    dec_layer["cross"] = attn.attn_axes(cfg.qkv_bias)
    return {
        "enc": {"layers": enc_layer, "norm": (None,)},
        "dec": {"embed": ("tp", "fsdp"), "layers": dec_layer, "norm": (None,)},
        "head": ("fsdp", "tp"),
    }


def _encode(cfg, tp, params, frames):
    heads = (*cfg.padded_heads(tp), cfg.head_dim)
    h = shard(frames.astype(jnp.bfloat16), "batch", "act_seq", None)

    def body(hh, lp):
        x = rms_norm(hh, lp["ln1"], cfg.norm_eps)
        hh = hh + attn.attn_block(lp["attn"], x, cfg_heads=heads,
                                  rope_theta=cfg.rope_theta, causal=False,
                                  quant=cfg.quant)
        hh = hh + mlp_block(lp["mlp"], rms_norm(hh, lp["ln2"], cfg.norm_eps),
                            quant=cfg.quant)
        return shard(hh, "batch", "act_seq", None), None

    h, _ = jax.lax.scan(jax.checkpoint(body), h, params["enc"]["layers"])
    return rms_norm(h, params["enc"]["norm"], cfg.norm_eps)


def _decode_stack(cfg, tp, params, tokens, enc_out, *, collect_kv=False):
    heads = (*cfg.padded_heads(tp), cfg.head_dim)
    h = jnp.take(params["dec"]["embed"], tokens, axis=0).astype(jnp.bfloat16)
    h = shard(h, "batch", "act_seq", None)

    def body(hh, lp):
        x = rms_norm(hh, lp["ln1"], cfg.norm_eps)
        a = attn.attn_block(lp["attn"], x, cfg_heads=heads,
                            rope_theta=cfg.rope_theta, causal=True,
                            quant=cfg.quant, return_kv=collect_kv)
        kv = None
        if collect_kv:
            a, kv = a
        hh = hh + a
        xc = rms_norm(hh, lp["ln_cross"], cfg.norm_eps)
        c = attn.attn_block(lp["cross"], xc, cfg_heads=heads,
                            rope_theta=cfg.rope_theta, causal=False,
                            quant=cfg.quant, kv_source=enc_out,
                            return_kv=collect_kv)
        ckv = None
        if collect_kv:
            c, ckv = c
        hh = hh + c
        hh = hh + mlp_block(lp["mlp"], rms_norm(hh, lp["ln2"], cfg.norm_eps),
                            quant=cfg.quant)
        return shard(hh, "batch", "act_seq", None), (kv, ckv)

    h, kvs = jax.lax.scan(jax.checkpoint(body), h, params["dec"]["layers"])
    h = rms_norm(h, params["dec"]["norm"], cfg.norm_eps)
    return h, kvs


def encdec_loss(cfg: ModelConfig, tp: int, params, batch):
    enc_out = _encode(cfg, tp, params, batch["frames"])
    h, _ = _decode_stack(cfg, tp, params, batch["tokens"], enc_out)
    logits = _logits(cfg, tp, params, h)
    return cross_entropy(logits, batch["labels"], cfg.vocab_size)


def init_encdec_cache(cfg: ModelConfig, tp: int, batch: int, seq: int):
    hq, hkv = cfg.padded_heads(tp)
    dh, L = cfg.head_dim, cfg.n_layers
    se = enc_len_for(seq)
    z = lambda s: jnp.zeros((L, batch, s, hkv, dh), jnp.bfloat16)
    return {"k": z(seq), "v": z(seq), "cross_k": z(se), "cross_v": z(se)}


def encdec_cache_axes(cfg: ModelConfig):
    ax = (None, "batch", "cache_seq", None, None)
    return {"k": ax, "v": ax, "cross_k": ax, "cross_v": ax}


def encdec_prefill(cfg: ModelConfig, tp: int, params, batch):
    """Encoder pass + cross-KV build + first decoder step over the BOS prompt.

    batch: {"frames": (B, Se, d), "tokens": (B, S)} where tokens is the
    (possibly partial) decoder prompt.
    """
    enc_out = _encode(cfg, tp, params, batch["frames"])
    h, kvs = _decode_stack(cfg, tp, params, batch["tokens"], enc_out,
                           collect_kv=True)
    (self_k, self_v), (cross_k, cross_v) = kvs
    cache = {"k": self_k.astype(jnp.bfloat16), "v": self_v.astype(jnp.bfloat16),
             "cross_k": cross_k.astype(jnp.bfloat16),
             "cross_v": cross_v.astype(jnp.bfloat16)}
    cache = {k: shard(v, "layers", "batch", "cache_seq", None, None)
             for k, v in cache.items()}
    logits = _logits(cfg, tp, params, h[:, -1, :])
    return cache, logits


def encdec_decode(cfg: ModelConfig, tp: int, params, cache, tokens1, cache_len):
    heads = (*cfg.padded_heads(tp), cfg.head_dim)
    h = jnp.take(params["dec"]["embed"], tokens1, axis=0).astype(jnp.bfloat16)
    h = shard(h, "batch", None)

    def body(hh, xs):
        lp, ck, cv, xk, xv = xs
        x = rms_norm(hh, lp["ln1"], cfg.norm_eps)
        a, nck, ncv = attn.decode_attn_block(
            lp["attn"], x, ck, cv, cache_len, cfg_heads=heads,
            rope_theta=cfg.rope_theta, quant=cfg.quant)
        hh = hh + a
        xc = rms_norm(hh, lp["ln_cross"], cfg.norm_eps)
        c, _, _ = attn.decode_attn_block(
            lp["cross"], xc, xk, xv, cache_len, cfg_heads=heads,
            rope_theta=cfg.rope_theta, quant=cfg.quant, cross_kv=(xk, xv))
        hh = hh + c
        hh = hh + mlp_block(lp["mlp"], rms_norm(hh, lp["ln2"], cfg.norm_eps),
                            quant=cfg.quant)
        return hh, {"k": nck, "v": ncv}

    h, new = jax.lax.scan(body, h, (params["dec"]["layers"], cache["k"],
                                    cache["v"], cache["cross_k"],
                                    cache["cross_v"]))
    h = rms_norm(h, params["dec"]["norm"], cfg.norm_eps)
    logits = _logits(cfg, tp, params, h)
    return logits, {**cache, "k": new["k"], "v": new["v"]}


def build_encdec(cfg: ModelConfig, tp: int = 1) -> ModelFns:
    return ModelFns(
        cfg=cfg, tp=tp,
        init=partial(init_encdec, cfg, tp=tp),
        param_axes=partial(encdec_param_axes, cfg),
        loss=partial(encdec_loss, cfg, tp),
        prefill=partial(encdec_prefill, cfg, tp),
        decode=partial(encdec_decode, cfg, tp),
        init_cache=partial(init_encdec_cache, cfg, tp),
    )
