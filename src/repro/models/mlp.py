"""Dense FFN (SwiGLU / GELU) — QAT-able via the shared dense() projection."""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.models.common import dense, normal_init


class MLPParams(NamedTuple):
    w_gate: jnp.ndarray | None  # (d, ff) — None for non-gated
    w_in: jnp.ndarray           # (d, ff)
    w_out: jnp.ndarray          # (ff, d)


def init_mlp(keys, d_model, d_ff, gated=True):
    return MLPParams(
        w_gate=normal_init(next(keys), (d_model, d_ff)) if gated else None,
        w_in=normal_init(next(keys), (d_model, d_ff)),
        w_out=normal_init(next(keys), (d_ff, d_model)),
    )


def mlp_axes(gated=True):
    return MLPParams(
        w_gate=(None, "fsdp", "tp") if gated else None,
        w_in=(None, "fsdp", "tp"),
        w_out=(None, "tp", "fsdp"),
    )


def mlp_block(p: MLPParams, x, *, quant="none"):
    h = dense(x, p.w_in, quant=quant)
    if p.w_gate is not None:
        h = jax.nn.silu(dense(x, p.w_gate, quant=quant)) * h
    else:
        h = jnp.square(jax.nn.relu(h))  # squared-ReLU (nemotron/minitron)
    return dense(h, p.w_out, quant=quant)
