"""Decoder-only LM assembly for all decoder families (dense / moe / ssm /
hybrid / vlm): init, train loss, prefill, and single-token decode.

Layer stacks are *scanned* (params stacked on a leading L axis) so the HLO
stays one block body regardless of depth — essential for 512-device dry-run
compiles — except the hybrid family, whose per-layer cache shapes are ragged
(SWA ring buffers vs full-length global layers), and which therefore uses an
unrolled python loop (32 layers, small dims).

The paper's technique enters through ``cfg.quant='qat-int8'``: every dense
projection fake-quantizes weights+activations (STE) exactly as the MRF net's
QAT (DESIGN.md §4 applicability table).
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as attn
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod
from repro.models.common import key_iter, normal_init, rms_norm, shard
from repro.models.mlp import init_mlp, mlp_axes, mlp_block

MOE_AUX_COEF = 0.01


@dataclasses.dataclass(frozen=True)
class ModelFns:
    cfg: ModelConfig
    tp: int
    init: Callable        # key -> params
    param_axes: Callable  # () -> logical-axis pytree
    loss: Callable        # (params, batch) -> scalar
    prefill: Callable     # (params, batch) -> (cache, logits_last)
    decode: Callable      # (params, cache, tokens1, cache_len) -> (logits, cache)
    init_cache: Callable  # (batch, seq) -> cache pytree (zeros)


# --------------------------------------------------------------------------
# per-layer init / axes
# --------------------------------------------------------------------------

def _layer_init(cfg: ModelConfig, keys, tp: int):
    d = cfg.d_model
    hq, hkv = cfg.padded_heads(tp)
    dh = cfg.head_dim
    layer: dict[str, Any] = {"ln1": jnp.ones((d,), jnp.float32)}
    if cfg.family in ("dense", "vlm", "moe", "hybrid"):
        layer["attn"] = attn.init_attn(keys, d, hq, hkv, dh, cfg.qkv_bias,
                                       true_hq=cfg.n_heads)
        layer["ln2"] = jnp.ones((d,), jnp.float32)
    if cfg.family in ("dense", "vlm", "hybrid"):
        layer["mlp"] = init_mlp(keys, d, cfg.d_ff, cfg.gated_mlp)
    if cfg.family == "moe":
        layer["moe"] = moe_mod.init_moe(keys, d, cfg.d_ff, cfg.n_experts,
                                        cfg.n_shared_experts, cfg.gated_mlp)
    if cfg.family in ("ssm", "hybrid"):
        nh = _ssm_heads(cfg, tp)
        layer["ssm"] = ssm_mod.init_ssm(keys, d, nh * cfg.ssm_head_dim,
                                        cfg.ssm_state, nh)
    return layer


def _layer_axes(cfg: ModelConfig):
    layer: dict[str, Any] = {"ln1": (None, None)}
    if cfg.family in ("dense", "vlm", "moe", "hybrid"):
        layer["attn"] = attn.attn_axes(cfg.qkv_bias)
        layer["ln2"] = (None, None)
    if cfg.family in ("dense", "vlm", "hybrid"):
        layer["mlp"] = mlp_axes(cfg.gated_mlp)
    if cfg.family == "moe":
        layer["moe"] = moe_mod.moe_axes(cfg.n_shared_experts, cfg.gated_mlp)
    if cfg.family in ("ssm", "hybrid"):
        layer["ssm"] = ssm_mod.ssm_axes()
    return layer


def _ssm_heads(cfg: ModelConfig, tp: int) -> int:
    nh = cfg.n_ssm_heads
    return -(-nh // tp) * tp  # pad to multiple of tp


def _global_flags(cfg: ModelConfig):
    """Hybrid: which layers use full (global) attention vs SWA.
    Config-static (numpy) so cache construction can branch on it."""
    import numpy as np
    if cfg.family != "hybrid" or not cfg.global_layer_every:
        return np.zeros((cfg.n_layers,), bool)
    idx = np.arange(cfg.n_layers)
    flags = (idx % cfg.global_layer_every) == 0
    flags[cfg.n_layers - 1] = True  # hymba: first / periodic / last
    return flags


def init_lm(cfg: ModelConfig, key, tp: int = 1):
    keys = key_iter(key)
    vp = cfg.padded_vocab(tp)
    d = cfg.d_model
    layers = [_layer_init(cfg, keys, tp) for _ in range(cfg.n_layers)]
    stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *layers)
    return {
        "embed": normal_init(next(keys), (vp, d)),
        "layers": stacked,
        "final_norm": jnp.ones((d,), jnp.float32),
        "head": normal_init(next(keys), (d, vp)),
    }


def lm_param_axes(cfg: ModelConfig):
    return {
        "embed": ("tp", "fsdp"),
        "layers": _layer_axes(cfg),
        "final_norm": (None,),
        "head": ("fsdp", "tp"),
    }


# --------------------------------------------------------------------------
# block forward (train / prefill share it)
# --------------------------------------------------------------------------

def _block(cfg: ModelConfig, tp: int, h, lp, is_global, *, return_kv: bool):
    """One residual block. h: (B, S, d)."""
    heads = (*cfg.padded_heads(tp), cfg.head_dim)
    q = cfg.quant
    kv = None
    aux = jnp.float32(0.0)
    x = rms_norm(h, lp["ln1"], cfg.norm_eps)
    if cfg.family == "ssm":
        nh = _ssm_heads(cfg, tp)
        out = ssm_mod.ssm_block(lp["ssm"], x, n_heads=nh,
                                head_dim=cfg.ssm_head_dim,
                                n_state=cfg.ssm_state, chunk=cfg.ssm_chunk,
                                quant=q, return_cache=return_kv)
        if return_kv:
            out, kv = out
        return h + out, kv, aux
    if cfg.family == "hybrid":
        # hybrid layers are python-unrolled, so the SWA window is STATIC per
        # layer -> the banded attention path applies (§Perf lever B).
        window = None if bool(is_global) else cfg.swa_window
        a_out = attn.attn_block(lp["attn"], x, cfg_heads=heads,
                                rope_theta=cfg.rope_theta, causal=True,
                                window=window, quant=q, return_kv=return_kv)
        s_out = ssm_mod.ssm_block(lp["ssm"], x, n_heads=_ssm_heads(cfg, tp),
                                  head_dim=cfg.ssm_head_dim,
                                  n_state=cfg.ssm_state, chunk=cfg.ssm_chunk,
                                  quant=q, return_cache=return_kv)
        if return_kv:
            a_out, akv = a_out
            s_out, skv = s_out
            kv = (akv, skv)
        h = h + 0.5 * (a_out + s_out)
        h = h + mlp_block(lp["mlp"], rms_norm(h, lp["ln2"], cfg.norm_eps), quant=q)
        return h, kv, aux
    # attention families
    win = cfg.swa_window if cfg.swa_window else None
    a_out = attn.attn_block(lp["attn"], x, cfg_heads=heads,
                            rope_theta=cfg.rope_theta, causal=True,
                            window=win, quant=q, return_kv=return_kv)
    if return_kv:
        a_out, kv = a_out
    if cfg.remat == "save_attn":
        from jax.ad_checkpoint import checkpoint_name
        a_out = checkpoint_name(a_out, "attn_out")
    if cfg.parallel_block:
        # PaLM/GPT-J style: attn ∥ ffn share the block input -> the two
        # TP partial-sums add BEFORE one all-reduce (halves wire bytes).
        if cfg.family == "moe":
            y, aux = moe_mod.moe_block(lp["moe"], x, top_k=cfg.top_k,
                                       capacity_factor=cfg.capacity_factor,
                                       quant=q)
        else:
            y = mlp_block(lp["mlp"], x, quant=q)
        return h + a_out + y, kv, aux
    h = h + a_out
    x2 = rms_norm(h, lp["ln2"], cfg.norm_eps)
    if cfg.family == "moe":
        y, aux = moe_mod.moe_block(lp["moe"], x2, top_k=cfg.top_k,
                                   capacity_factor=cfg.capacity_factor, quant=q)
        h = h + y
    else:
        h = h + mlp_block(lp["mlp"], x2, quant=q)
    return h, kv, aux


def _embed(cfg, params, tokens, prefix_embeds=None):
    h = jnp.take(params["embed"], tokens, axis=0).astype(jnp.bfloat16)
    if prefix_embeds is not None:
        p = prefix_embeds.astype(jnp.bfloat16)
        h = jax.lax.dynamic_update_slice(h, p, (0, 0, 0))
    return shard(h, "batch", "act_seq", None)


def _stack_forward(cfg: ModelConfig, tp: int, params, h, *, collect_kv: bool):
    """Runs the layer stack. Returns (h, caches (or None), aux_sum)."""
    flags = _global_flags(cfg)
    if cfg.family == "hybrid":
        caches, aux_total = [], jnp.float32(0.0)
        for l in range(cfg.n_layers):
            lp = jax.tree.map(lambda x, _l=l: x[_l], params["layers"])
            h, kv, aux = _block(cfg, tp, h, lp, flags[l], return_kv=collect_kv)
            h = shard(h, "batch", "act_seq", None)
            caches.append(kv)
            aux_total += aux
        return h, (caches if collect_kv else None), aux_total

    def body(carry, xs):
        hh, aux_total = carry
        lp, flag = xs
        hh, kv, aux = _block(cfg, tp, hh, lp, flag, return_kv=collect_kv)
        hh = shard(hh, "batch", "act_seq", None)
        return (hh, aux_total + aux), kv

    if cfg.remat == "save_attn":
        policy = jax.checkpoint_policies.save_only_these_names("attn_out")
        body = jax.checkpoint(body, policy=policy)
    else:
        body = jax.checkpoint(body)
    (h, aux_total), kvs = jax.lax.scan(body, (h, jnp.float32(0.0)),
                                       (params["layers"], flags))
    return h, (kvs if collect_kv else None), aux_total


def _logits(cfg, tp, params, h):
    logits = jnp.dot(h, params["head"].astype(h.dtype))
    axes = ("batch", None, "tp") if logits.ndim == 3 else ("batch", "tp")
    return shard(logits, *axes)


# --------------------------------------------------------------------------
# train loss
# --------------------------------------------------------------------------

def cross_entropy(logits, labels, true_vocab):
    """logits: (B, S, V') bf16; labels: (B, S) int32, -1 = masked."""
    lg = logits.astype(jnp.float32)
    vp = lg.shape[-1]
    if true_vocab < vp:
        col = jax.lax.broadcasted_iota(jnp.int32, lg.shape, lg.ndim - 1)
        lg = jnp.where(col < true_vocab, lg, -1e30)
    lse = jax.nn.logsumexp(lg, axis=-1)
    lab = jnp.take_along_axis(lg, jnp.maximum(labels, 0)[..., None],
                              axis=-1)[..., 0]
    mask = (labels >= 0).astype(jnp.float32)
    ce = (lse - lab) * mask
    return jnp.sum(ce) / jnp.maximum(jnp.sum(mask), 1.0)


def lm_loss(cfg: ModelConfig, tp: int, params, batch):
    h = _embed(cfg, params, batch["tokens"], batch.get("prefix_embeds"))
    h, _, aux = _stack_forward(cfg, tp, params, h, collect_kv=False)
    h = rms_norm(h, params["final_norm"], cfg.norm_eps)
    logits = _logits(cfg, tp, params, h)
    loss = cross_entropy(logits, batch["labels"], cfg.vocab_size)
    if cfg.family == "moe":
        loss = loss + MOE_AUX_COEF * aux / cfg.n_layers
    return loss


# --------------------------------------------------------------------------
# caches
# --------------------------------------------------------------------------

def init_lm_cache(cfg: ModelConfig, tp: int, batch: int, seq: int):
    hq, hkv = cfg.padded_heads(tp)
    dh = cfg.head_dim
    L = cfg.n_layers
    if cfg.family == "ssm":
        nh = _ssm_heads(cfg, tp)
        per = ssm_mod.init_ssm_cache(batch, nh, cfg.ssm_head_dim,
                                     cfg.ssm_state, nh * cfg.ssm_head_dim)
        return jax.tree.map(lambda x: jnp.broadcast_to(x, (L, *x.shape)), per)
    if cfg.family == "hybrid":
        flags = [bool(f) for f in _global_flags(cfg)]
        nh = _ssm_heads(cfg, tp)
        caches = []
        for l in range(L):
            cap = seq if flags[l] else min(cfg.swa_window, seq)
            caches.append({
                "k": jnp.zeros((batch, cap, hkv, dh), jnp.bfloat16),
                "v": jnp.zeros((batch, cap, hkv, dh), jnp.bfloat16),
                "ssm": ssm_mod.init_ssm_cache(batch, nh, cfg.ssm_head_dim,
                                              cfg.ssm_state,
                                              nh * cfg.ssm_head_dim),
            })
        return tuple(caches)
    if cfg.decode_unroll:
        # per-layer buffers: each is its own (donatable) argument, so the
        # unrolled decode updates caches in place with one-token DUS only
        return tuple({"k": jnp.zeros((batch, seq, hkv, dh), jnp.bfloat16),
                      "v": jnp.zeros((batch, seq, hkv, dh), jnp.bfloat16)}
                     for _ in range(L))
    return {
        "k": jnp.zeros((L, batch, seq, hkv, dh), jnp.bfloat16),
        "v": jnp.zeros((L, batch, seq, hkv, dh), jnp.bfloat16),
    }


def lm_cache_axes(cfg: ModelConfig):
    if cfg.family == "ssm":
        return ssm_mod.SSMCache(
            state=(None, "batch", "tp", None, None),
            conv_x=(None, "batch", None, "tp"),
            conv_B=(None, "batch", None, None),
            conv_C=(None, "batch", None, None))
    if cfg.family == "hybrid":
        per = {
            "k": ("batch", "cache_seq", None, None),
            "v": ("batch", "cache_seq", None, None),
            "ssm": ssm_mod.SSMCache(
                state=("batch", "tp", None, None),
                conv_x=("batch", None, "tp"),
                conv_B=("batch", None, None),
                conv_C=("batch", None, None)),
        }
        return tuple(per for _ in range(cfg.n_layers))
    if cfg.decode_unroll:
        per = {"k": ("batch", "cache_seq", None, None),
               "v": ("batch", "cache_seq", None, None)}
        return tuple(per for _ in range(cfg.n_layers))
    return {"k": (None, "batch", "cache_seq", None, None),
            "v": (None, "batch", "cache_seq", None, None)}


# --------------------------------------------------------------------------
# prefill
# --------------------------------------------------------------------------

def lm_prefill(cfg: ModelConfig, tp: int, params, batch):
    """Causal forward over the prompt; returns (cache, last-token logits)."""
    h = _embed(cfg, params, batch["tokens"], batch.get("prefix_embeds"))
    seq = batch["tokens"].shape[1]
    if cfg.family == "ssm":
        # caches (stacked SSMCache from the scan ys) carry the final SSD
        # state + conv tails so decode continues exactly where prefill ended.
        h, cache, _ = _stack_forward(cfg, tp, params, h, collect_kv=True)
    elif cfg.family == "hybrid":
        h, kvs, _ = _stack_forward(cfg, tp, params, h, collect_kv=True)
        cache = []
        for l, (akv, skv) in enumerate(kvs):
            k, v = akv
            cap = min(cfg.swa_window, seq) if not bool(_global_flags(cfg)[l]) else seq
            # ring alignment: token t lives at slot t % cap
            k_tail = jnp.roll(k[:, -cap:], seq % cap, axis=1)
            v_tail = jnp.roll(v[:, -cap:], seq % cap, axis=1)
            cache.append({"k": k_tail.astype(jnp.bfloat16),
                          "v": v_tail.astype(jnp.bfloat16),
                          "ssm": skv})
        cache = tuple(cache)
    elif cfg.decode_unroll:
        h, kvs, _ = _stack_forward(cfg, tp, params, h, collect_kv=True)
        k, v = kvs  # stacked (L, B, S, Hkv, dh) from the scan ys
        cache = tuple(
            {"k": shard(k[l].astype(jnp.bfloat16), "batch", "cache_seq",
                        None, None),
             "v": shard(v[l].astype(jnp.bfloat16), "batch", "cache_seq",
                        None, None)} for l in range(cfg.n_layers))
    else:
        h, kvs, _ = _stack_forward(cfg, tp, params, h, collect_kv=True)
        k, v = kvs
        cache = {"k": shard(k.astype(jnp.bfloat16), "layers", "batch",
                            "cache_seq", None, None),
                 "v": shard(v.astype(jnp.bfloat16), "layers", "batch",
                            "cache_seq", None, None)}
    h = rms_norm(h, params["final_norm"], cfg.norm_eps)
    logits = _logits(cfg, tp, params, h[:, -1, :])
    return cache, logits


# --------------------------------------------------------------------------
# decode (one token)
# --------------------------------------------------------------------------

def _decode_block(cfg, tp, h1, lp, cache_l, cache_len, is_global):
    heads = (*cfg.padded_heads(tp), cfg.head_dim)
    q = cfg.quant
    nh = _ssm_heads(cfg, tp)
    x = rms_norm(h1, lp["ln1"], cfg.norm_eps)
    if cfg.family == "ssm":
        y, new_cache = ssm_mod.ssm_decode_step(
            lp["ssm"], cache_l, x, n_heads=nh, head_dim=cfg.ssm_head_dim,
            n_state=cfg.ssm_state, quant=q)
        return h1 + y, new_cache
    if cfg.family == "hybrid":
        a_out, ck, cv = attn.decode_attn_block(
            lp["attn"], x, cache_l["k"], cache_l["v"], cache_len,
            cfg_heads=heads, rope_theta=cfg.rope_theta, quant=q)
        s_out, new_ssm = ssm_mod.ssm_decode_step(
            lp["ssm"], cache_l["ssm"], x, n_heads=nh,
            head_dim=cfg.ssm_head_dim, n_state=cfg.ssm_state, quant=q)
        h1 = h1 + 0.5 * (a_out + s_out)
        h1 = h1 + mlp_block(lp["mlp"], rms_norm(h1, lp["ln2"], cfg.norm_eps),
                            quant=q)
        return h1, {"k": ck, "v": cv, "ssm": new_ssm}
    a_out, ck, cv = attn.decode_attn_block(
        lp["attn"], x, cache_l["k"], cache_l["v"], cache_len,
        cfg_heads=heads, rope_theta=cfg.rope_theta, quant=q)
    h1 = h1 + a_out
    x2 = rms_norm(h1, lp["ln2"], cfg.norm_eps)
    if cfg.family == "moe":
        y, _ = moe_mod.moe_block(lp["moe"], x2[:, None, :], top_k=cfg.top_k,
                                 capacity_factor=cfg.capacity_factor, quant=q)
        h1 = h1 + y[:, 0, :]
    else:
        h1 = h1 + mlp_block(lp["mlp"], x2, quant=q)
    return h1, {"k": ck, "v": cv}


def lm_decode(cfg: ModelConfig, tp: int, params, cache, tokens1, cache_len):
    """tokens1: (B,) int32 — the newly sampled token; cache_len: scalar."""
    h = jnp.take(params["embed"], tokens1, axis=0).astype(jnp.bfloat16)
    h = shard(h, "batch", None)
    flags = _global_flags(cfg)
    if cfg.family == "hybrid" or cfg.decode_unroll:
        new_caches = []
        for l in range(cfg.n_layers):
            lp = jax.tree.map(lambda x, _l=l: x[_l], params["layers"])
            h, nc = _decode_block(cfg, tp, h, lp, cache[l], cache_len, flags[l])
            new_caches.append(nc)
        new_cache = tuple(new_caches)
    else:
        def body(carry, xs):
            hh = carry
            lp, cache_l, flag = xs
            hh, nc = _decode_block(cfg, tp, hh, lp, cache_l, cache_len, flag)
            return hh, nc

        h, new_cache = jax.lax.scan(body, h, (params["layers"], cache, flags))
    h = rms_norm(h, params["final_norm"], cfg.norm_eps)
    logits = _logits(cfg, tp, params, h)
    return logits, new_cache


# --------------------------------------------------------------------------
# builder
# --------------------------------------------------------------------------

def build_lm(cfg: ModelConfig, tp: int = 1) -> ModelFns:
    cfg.validate()
    return ModelFns(
        cfg=cfg, tp=tp,
        init=partial(init_lm, cfg, tp=tp),
        param_axes=partial(lm_param_axes, cfg),
        loss=partial(lm_loss, cfg, tp),
        prefill=partial(lm_prefill, cfg, tp),
        decode=partial(lm_decode, cfg, tp),
        init_cache=partial(init_lm_cache, cfg, tp),
    )
