# Architecture zoo: composable JAX model definitions (pure functions over
# param pytrees) covering dense GQA decoders, MoE, SSM (mamba2/SSD), hybrid
# attn+SSM, encoder-decoder, and VLM backbones.  All support:
#   train forward (CE loss), prefill (KV-cache build), decode (1 token)
# with logical-axis shardings supplied by repro.dist.sharding.
