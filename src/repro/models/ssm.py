"""Mamba-2 (SSD, state-space duality) block: chunked quadratic-within /
recurrent-across formulation (arXiv:2405.21060) in pure JAX.

Per head h with state size N, head dim P:
    h_t = exp(dt_t * A) * h_{t-1} + dt_t * (B_t outer x_t)
    y_t = C_t . h_t + D * x_t
Chunked: within a chunk the dual quadratic (attention-like) form is used;
across chunks the state is carried by a ``lax.scan`` — the standard SSD
schedule, MXU-friendly (einsums) instead of a length-L recurrence.

TP: heads ("tp") shard over the model axis; B/C (per-group, G=1) are
replicated — the state recurrence is head-local so the scan has no
collectives (DESIGN.md §5).
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.models.common import dense, normal_init, rms_norm, shard

CONV_WIDTH = 4


class SSMParams(NamedTuple):
    wx: jnp.ndarray        # (d, di)
    wz: jnp.ndarray        # (d, di)
    wB: jnp.ndarray        # (d, N)
    wC: jnp.ndarray        # (d, N)
    wdt: jnp.ndarray       # (d, H)
    dt_bias: jnp.ndarray   # (H,)
    A_log: jnp.ndarray     # (H,)
    D: jnp.ndarray         # (H,)
    conv_x: jnp.ndarray    # (CONV_WIDTH, di) depthwise
    conv_B: jnp.ndarray    # (CONV_WIDTH, N)
    conv_C: jnp.ndarray    # (CONV_WIDTH, N)
    gate_norm: jnp.ndarray # (di,)
    wo: jnp.ndarray        # (di, d)


def init_ssm(keys, d_model, d_inner, n_state, n_heads):
    return SSMParams(
        wx=normal_init(next(keys), (d_model, d_inner)),
        wz=normal_init(next(keys), (d_model, d_inner)),
        wB=normal_init(next(keys), (d_model, n_state)),
        wC=normal_init(next(keys), (d_model, n_state)),
        wdt=normal_init(next(keys), (d_model, n_heads)),
        dt_bias=jnp.log(jnp.expm1(jnp.full((n_heads,), 0.01))),  # softplus^-1
        A_log=jnp.log(jnp.linspace(1.0, 16.0, n_heads)),
        D=jnp.ones((n_heads,)),
        conv_x=normal_init(next(keys), (CONV_WIDTH, d_inner), scale=0.1),
        conv_B=normal_init(next(keys), (CONV_WIDTH, n_state), scale=0.1),
        conv_C=normal_init(next(keys), (CONV_WIDTH, n_state), scale=0.1),
        gate_norm=jnp.ones((d_inner,)),
        wo=normal_init(next(keys), (d_inner, d_model)),
    )


def ssm_axes():
    return SSMParams(
        wx=(None, "fsdp", "tp"), wz=(None, "fsdp", "tp"),
        wB=(None, "fsdp", None), wC=(None, "fsdp", None),
        wdt=(None, "fsdp", "tp"),
        dt_bias=(None, "tp"), A_log=(None, "tp"), D=(None, "tp"),
        conv_x=(None, None, "tp"), conv_B=(None, None, None),
        conv_C=(None, None, None),
        gate_norm=(None, "tp"), wo=(None, "tp", "fsdp"),
    )


def _causal_conv(x, w):
    """Depthwise causal conv. x: (B, L, D); w: (W, D)."""
    w_len = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (w_len - 1, 0), (0, 0)))
    out = sum(xp[:, i:i + x.shape[1], :] * w[i][None, None, :] for i in range(w_len))
    return out


def ssd_chunked(x, dt, A, Bmat, Cmat, chunk: int):
    """Chunked SSD scan.

    x: (B, L, H, P); dt: (B, L, H) (post-softplus); A: (H,) negative;
    Bmat/Cmat: (B, L, N).  Returns (y: (B, L, H, P), final state (B,H,P,N)).
    """
    b, l, h, p = x.shape
    n = Bmat.shape[-1]
    nc = max(l // chunk, 1)
    q = l // nc
    assert l % q == 0, (l, chunk)

    xr = x.reshape(b, nc, q, h, p)
    dtr = dt.reshape(b, nc, q, h)
    br = Bmat.reshape(b, nc, q, n)
    cr = Cmat.reshape(b, nc, q, n)

    la = dtr * A[None, None, None, :]              # (B,nc,Q,H) log-decay <= 0
    cum = jnp.cumsum(la, axis=2)                   # (B,nc,Q,H)

    # intra-chunk (dual quadratic form)
    cb = jnp.einsum("bcqn,bckn->bcqk", cr, br, optimize=True)     # (B,nc,Q,K)
    dec = jnp.exp(cum[:, :, :, None, :] - cum[:, :, None, :, :])  # (B,nc,Q,K,H)
    tri = jnp.tril(jnp.ones((q, q), bool))
    m = cb[..., None] * dec * dtr[:, :, None, :, :]
    m = jnp.where(tri[None, None, :, :, None], m, 0.0)
    y_intra = jnp.einsum("bcqkh,bckhp->bcqhp", m, xr, optimize=True)

    # per-chunk end state contribution
    dec_end = jnp.exp(cum[:, :, -1:, :] - cum)                    # (B,nc,Q,H)
    s_chunk = jnp.einsum("bcqh,bcqn,bcqhp->bchpn", dec_end * dtr, br, xr,
                         optimize=True)                           # (B,nc,H,P,N)

    # inter-chunk recurrence
    def step(h_prev, xs):
        cum_c, c_c, s_c = xs  # (B,Q,H), (B,Q,N), (B,H,P,N)
        y_in = jnp.einsum("bqn,bqh,bhpn->bqhp", c_c, jnp.exp(cum_c), h_prev,
                          optimize=True)
        h_new = jnp.exp(cum_c[:, -1])[:, :, None, None] * h_prev + s_c
        return h_new, y_in

    h0 = jnp.zeros((b, h, p, n), jnp.float32)
    xs = (cum.transpose(1, 0, 2, 3), cr.transpose(1, 0, 2, 3),
          s_chunk.transpose(1, 0, 2, 3, 4))
    h_last, y_inter = jax.lax.scan(step, h0, xs)
    y_inter = y_inter.transpose(1, 0, 2, 3, 4)     # (B,nc,Q,H,P)

    return (y_intra + y_inter).reshape(b, l, h, p), h_last


def ssm_block(p: SSMParams, u, *, n_heads, head_dim, n_state, chunk,
              quant="none", return_cache=False):
    """Full mamba2 mixer. u: (B, L, d) -> (B, L, d) [, SSMCache for decode]."""
    b, l, _ = u.shape
    x_raw = dense(u, p.wx, quant=quant)            # (B,L,di)
    z = dense(u, p.wz, quant=quant)
    bm_raw = dense(u, p.wB)
    cm_raw = dense(u, p.wC)
    dt_raw = dense(u, p.wdt)
    x = jax.nn.silu(_causal_conv(x_raw, p.conv_x.astype(x_raw.dtype)))
    bm = jax.nn.silu(_causal_conv(bm_raw, p.conv_B.astype(bm_raw.dtype)))
    cm = jax.nn.silu(_causal_conv(cm_raw, p.conv_C.astype(cm_raw.dtype)))
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p.dt_bias)
    a = -jnp.exp(p.A_log.astype(jnp.float32))
    # pad seq to a chunk multiple; dt=0 on padding -> decay 1, zero update,
    # so the final state is unaffected and padded outputs are sliced off.
    pad = (-l) % min(chunk, max(l, 1))
    if pad:
        padw = ((0, 0), (0, pad), (0, 0))
        x, bm, cm = (jnp.pad(t, padw) for t in (x, bm, cm))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        dt = dt * (jnp.arange(l + pad) < l)[None, :, None]
    lp_ = l + pad
    xh = x.reshape(b, lp_, n_heads, head_dim).astype(jnp.float32)
    xh = shard(xh, "batch", None, "tp", None)
    y, h_last = ssd_chunked(xh, dt, a, bm.astype(jnp.float32),
                            cm.astype(jnp.float32), chunk)
    y = y + p.D[None, None, :, None] * xh
    y = y.reshape(b, lp_, -1)[:, :l].astype(u.dtype)
    y = rms_norm(y * jax.nn.silu(z), p.gate_norm)
    out = dense(y, p.wo, quant=quant)
    if return_cache:
        tail = CONV_WIDTH - 1
        cache = SSMCache(state=h_last,
                         conv_x=x_raw[:, -tail:].astype(jnp.bfloat16),
                         conv_B=bm_raw[:, -tail:].astype(jnp.bfloat16),
                         conv_C=cm_raw[:, -tail:].astype(jnp.bfloat16))
        return out, cache
    return out


# --------------------------------------------------------------------------
# Decode (single token): O(1) state update — why SSMs own long_500k.
# --------------------------------------------------------------------------

class SSMCache(NamedTuple):
    state: jnp.ndarray      # (B, H, P, N) fp32
    conv_x: jnp.ndarray     # (B, CONV_WIDTH-1, di)
    conv_B: jnp.ndarray     # (B, CONV_WIDTH-1, N)
    conv_C: jnp.ndarray     # (B, CONV_WIDTH-1, N)


def init_ssm_cache(batch, n_heads, head_dim, n_state, d_inner):
    return SSMCache(
        state=jnp.zeros((batch, n_heads, head_dim, n_state), jnp.float32),
        conv_x=jnp.zeros((batch, CONV_WIDTH - 1, d_inner), jnp.bfloat16),
        conv_B=jnp.zeros((batch, CONV_WIDTH - 1, n_state), jnp.bfloat16),
        conv_C=jnp.zeros((batch, CONV_WIDTH - 1, n_state), jnp.bfloat16),
    )


def _conv_step(cache, new, w):
    """cache: (B, W-1, D); new: (B, D); w: (W, D) -> (out (B, D), new cache)."""
    window = jnp.concatenate([cache, new[:, None]], axis=1)     # (B, W, D)
    out = jnp.sum(window * w[None], axis=1)
    return out, window[:, 1:]


def ssm_decode_step(p: SSMParams, cache: SSMCache, u1, *, n_heads, head_dim,
                    n_state, quant="none"):
    """u1: (B, d) one token. Returns (y1, new_cache)."""
    b = u1.shape[0]
    x = dense(u1, p.wx, quant=quant)
    z = dense(u1, p.wz, quant=quant)
    bm = dense(u1, p.wB)
    cm = dense(u1, p.wC)
    dt_raw = dense(u1, p.wdt)
    x, cx = _conv_step(cache.conv_x, x, p.conv_x.astype(x.dtype))
    bm, cb = _conv_step(cache.conv_B, bm, p.conv_B.astype(bm.dtype))
    cm, cc = _conv_step(cache.conv_C, cm, p.conv_C.astype(cm.dtype))
    x, bm, cm = jax.nn.silu(x), jax.nn.silu(bm), jax.nn.silu(cm)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p.dt_bias)   # (B,H)
    a = -jnp.exp(p.A_log.astype(jnp.float32))
    xh = x.reshape(b, n_heads, head_dim).astype(jnp.float32)
    decay = jnp.exp(dt * a[None, :])                                # (B,H)
    upd = jnp.einsum("bh,bn,bhp->bhpn", dt, bm.astype(jnp.float32), xh)
    state = decay[:, :, None, None] * cache.state + upd
    y = jnp.einsum("bn,bhpn->bhp", cm.astype(jnp.float32), state)
    y = y + p.D[None, :, None] * xh
    y = y.reshape(b, -1).astype(u1.dtype)
    y = rms_norm(y * jax.nn.silu(z), p.gate_norm)
    y = dense(y, p.wo, quant=quant)
    return y, SSMCache(state=state, conv_x=cx, conv_B=cb, conv_C=cc)
