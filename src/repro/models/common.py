"""Shared model components: RMSNorm, RoPE, QAT-able dense projection, inits.

Everything is a pure function over explicit params; layers that the paper's
technique applies to (dense projections) route through ``dense()`` which
applies int8 fake-quant when the config asks for ``quant='qat-int8'`` —
the LM-scale generalisation of the paper's integer training (DESIGN.md §2).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.dist.sharding import shard as _shard  # logical-axis constraint helper

COMPUTE_DTYPE = jnp.bfloat16


def rms_norm(x, gain, eps=1e-5):
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    scale = jax.lax.rsqrt(jnp.mean(jnp.square(x32), axis=-1, keepdims=True) + eps)
    return (x32 * scale).astype(dt) * gain.astype(dt)


def fake_quant_int8(x):
    """Dynamic symmetric per-tensor int8 fake-quant with STE (paper's QAT,
    stateless variant used at LM scale)."""
    s = jnp.max(jnp.abs(x)) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(x / s), -127, 127) * s
    return x + jax.lax.stop_gradient(q - x)


@jax.custom_vjp
def _dense_int8_core(x, w):
    """True int8 forward dot (s8 x s8 -> s32 in the HLO, 2x MXU rate on TPU)
    with dynamic symmetric scales; backward is the bf16 STE."""
    sx = jnp.max(jnp.abs(x)) / 127.0 + 1e-12
    sw = jnp.max(jnp.abs(w), axis=0, keepdims=True) / 127.0 + 1e-12
    xq = jnp.clip(jnp.round(x / sx), -127, 127).astype(jnp.int8)
    wq = jnp.clip(jnp.round(w / sw), -127, 127).astype(jnp.int8)
    acc = jax.lax.dot_general(xq, wq, (((x.ndim - 1,), (0,)), ((), ())),
                              preferred_element_type=jnp.int32)
    return (acc.astype(jnp.float32) * (sx * sw)).astype(x.dtype)


def _dense_int8_fwd(x, w):
    return _dense_int8_core(x, w), (x, w)


def _dense_int8_bwd(res, g):
    x, w = res
    dx = jnp.einsum("...f,df->...d", g, w.astype(g.dtype))
    dw = jnp.einsum("...d,...f->df", x.astype(g.dtype), g)
    return dx.astype(x.dtype), dw.astype(w.dtype)


_dense_int8_core.defvjp(_dense_int8_fwd, _dense_int8_bwd)


def dense(x, w, b=None, *, quant: str = "none"):
    """x @ w (+ b). quant='qat-int8': fake-quant both operands (semantic QAT,
    STE backward). quant='int8-hlo': emit a real int8 dot (deployment form —
    halves dot operand bytes, doubles MXU rate; STE backward in bf16)."""
    if quant == "int8-hlo":
        y = _dense_int8_core(x, w.astype(jnp.float32))
    else:
        if quant == "qat-int8":
            x = fake_quant_int8(x)
            w = fake_quant_int8(w)
        y = jnp.dot(x, w.astype(x.dtype))
    if b is not None:
        y = y + b.astype(y.dtype)
    return y


# --------------------------------------------------------------------------
# RoPE
# --------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float = 1e4):
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x, positions, theta: float = 1e4):
    """x: (..., S, H, Dh); positions: broadcastable to (..., S)."""
    dh = x.shape[-1]
    freqs = rope_freqs(dh, theta)                        # (Dh/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, Dh/2)
    cos = jnp.cos(angles)[..., None, :]                  # (..., S, 1, Dh/2)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# --------------------------------------------------------------------------
# Inits (all fp32 masters; compute casts to bf16)
# --------------------------------------------------------------------------

def normal_init(key, shape, scale=0.02, dtype=jnp.float32):
    return scale * jax.random.normal(key, shape, dtype)


def key_iter(key):
    while True:
        key, sub = jax.random.split(key)
        yield sub


def shard(x, *logical_axes):
    """Apply a logical-axis sharding constraint (no-op outside a mesh)."""
    return _shard(x, *logical_axes)
