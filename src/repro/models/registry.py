"""Architecture registry: ModelConfig -> ModelFns (init/loss/prefill/decode)
plus cache logical axes, dispatching on family."""

from __future__ import annotations

from repro.configs.base import ModelConfig
from repro.models.encdec import build_encdec, encdec_cache_axes
from repro.models.lm import ModelFns, build_lm, lm_cache_axes


def build(cfg: ModelConfig, tp: int = 1) -> ModelFns:
    if cfg.family == "mrf":
        from repro.models.mrf import build_mrf
        return build_mrf(cfg, tp)
    if cfg.family == "encdec":
        return build_encdec(cfg, tp)
    return build_lm(cfg, tp)


def cache_axes(cfg: ModelConfig):
    if cfg.family == "mrf":
        raise NotImplementedError("mrf nets are feed-forward: no decode cache")
    if cfg.family == "encdec":
        return encdec_cache_axes(cfg)
    return lm_cache_axes(cfg)
