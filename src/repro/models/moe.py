"""Mixture-of-Experts FFN: top-k routing with per-group capacity (GShard/T5X
style dense dispatch), shared experts (DeepSeekMoE), expert parallelism over
the ``model`` mesh axis.

Tokens are processed in groups of ``group_size``; each group independently
assigns its tokens to per-expert capacity slots C = ceil(gs * k * cf / E).
The dispatch/combine tensors are (G, s, E, C) one-hots — einsum-based so the
all-to-all falls out of GSPMD when expert weights are sharded on E.  The
group size bounds the dispatch-einsum overhead (FLOPs ~ N * E*C * d with
E*C = k*cf*s) — see EXPERIMENTS.md §Perf for the measured overhead and the
group-size lever.
"""

from __future__ import annotations

import math
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.models.common import normal_init, shard
from repro.models.mlp import MLPParams, init_mlp, mlp_axes, mlp_block


class MoEParams(NamedTuple):
    router: jnp.ndarray      # (d, E)
    w_gate: jnp.ndarray      # (E, d, ff)
    w_in: jnp.ndarray        # (E, d, ff)
    w_out: jnp.ndarray       # (E, ff, d)
    shared: MLPParams | None # dense shared-experts MLP (width = n_shared * ff)


def init_moe(keys, d_model, d_ff, n_experts, n_shared, gated=True):
    def ex(shape, scale=0.02):
        return normal_init(next(keys), shape, scale)

    return MoEParams(
        router=ex((d_model, n_experts)),
        w_gate=ex((n_experts, d_model, d_ff)),
        w_in=ex((n_experts, d_model, d_ff)),
        w_out=ex((n_experts, d_ff, d_model)),
        shared=init_mlp(keys, d_model, n_shared * d_ff, gated) if n_shared else None,
    )


def moe_axes(n_shared, gated=True):
    return MoEParams(
        router=(None, "fsdp", None),
        w_gate=(None, "tp", "fsdp", None),
        w_in=(None, "tp", "fsdp", None),
        w_out=(None, "tp", None, "fsdp"),
        shared=mlp_axes(gated) if n_shared else None,
    )


def moe_block(p: MoEParams, x, *, top_k: int, capacity_factor: float = 1.25,
              group_size: int = 256, quant: str = "none"):
    """x: (B, S, d) -> (y, aux_loss). Dropped tokens pass through the residual."""
    b, s, d = x.shape
    n_exp = p.router.shape[-1]
    tokens = x.reshape(-1, d)
    n = tokens.shape[0]
    gs = min(group_size, n)
    n_groups = n // gs
    assert n % gs == 0, (n, gs)
    xg = tokens.reshape(n_groups, gs, d)
    # groups carry the batch sharding when there are many; a single group
    # (decode) keeps tokens sharded inside the group instead
    g_axes = ("batch", None, None) if n_groups > 1 else (None, "batch", None)
    xg = shard(xg, *g_axes)

    logits = jnp.einsum("gsd,de->gse", xg.astype(jnp.float32),
                        p.router.astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, idx = jax.lax.top_k(probs, top_k)          # (G, s, k)
    gate_vals = gate_vals / jnp.sum(gate_vals, axis=-1, keepdims=True)

    capacity = max(int(math.ceil(gs * top_k * capacity_factor / n_exp)), 4)

    # per-expert capacity slot assignment, k choices in priority order
    combine = jnp.zeros((n_groups, gs, n_exp, capacity), jnp.float32)
    base = jnp.zeros((n_groups, n_exp), jnp.float32)
    for j in range(top_k):
        onehot = jax.nn.one_hot(idx[:, :, j], n_exp, dtype=jnp.float32)  # (G,s,E)
        pos = jnp.cumsum(onehot, axis=1) - 1.0 + base[:, None, :]
        within = (pos < capacity) & (onehot > 0)
        slot = jax.nn.one_hot(pos.astype(jnp.int32), capacity, dtype=jnp.float32)
        combine += (gate_vals[:, :, j, None, None]
                    * jnp.where(within[..., None], onehot[..., None] * slot, 0.0))
        base += jnp.sum(onehot * within, axis=1)
    dispatch = (combine > 0.0).astype(x.dtype)
    combine = combine.astype(jnp.float32)

    expert_in = jnp.einsum("gsec,gsd->gecd", dispatch, xg, optimize=True)
    e_axes = ("batch", "tp", None, None) if n_groups > 1 else (None, "tp", None, None)
    expert_in = shard(expert_in, *e_axes)
    h_in = jnp.einsum("gecd,edf->gecf", expert_in, p.w_in.astype(x.dtype),
                      optimize=True)
    h_gate = jnp.einsum("gecd,edf->gecf", expert_in, p.w_gate.astype(x.dtype),
                        optimize=True)
    h = jax.nn.silu(h_gate) * h_in
    expert_out = jnp.einsum("gecf,efd->gecd", h, p.w_out.astype(x.dtype),
                            optimize=True)
    expert_out = shard(expert_out, *e_axes)
    y = jnp.einsum("gsec,gecd->gsd", combine.astype(x.dtype), expert_out,
                   optimize=True)
    y = y.reshape(b, s, d)

    # load-balance auxiliary loss (Switch/GShard)
    frac_tokens = jnp.mean(
        jax.nn.one_hot(idx[:, :, 0], n_exp, dtype=jnp.float32), axis=(0, 1))
    frac_probs = jnp.mean(probs, axis=(0, 1))
    aux = n_exp * jnp.sum(frac_tokens * frac_probs)

    if p.shared is not None:
        y = y + mlp_block(p.shared, x, quant=quant)
    return y, aux
