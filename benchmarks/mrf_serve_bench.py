"""MRF serving benchmark: sync vs pipelined serving on the same request
trace, for both recon-engine backends (float / full-integer int8).

Sync mode is the per-tile-retirement baseline (the pre-queue engine);
pipelined mode streams the same trace through the persistent request queue
and the double-buffered wave executor (one host sync per wave, staging of
wave N+1 overlapped with compute of wave N).  Both modes run the identical
jitted per-bucket forward, so their maps are bit-identical — the benchmark
measures pure scheduling: voxels/s plus p50/p90/p99 latency **from enqueue
time** per mode, and ``pipelined_speedup_vs_sync`` per backend.

Both backends serve on the **autotuned** bucket set: the trace is replayed
once through a probe executor to record its request-size distribution, then
``serve_autotune.tune_buckets`` picks the set against measured per-bucket
medians on this rig.  The int8 backend runs its rig-default implementation
(fused whole-network kernel on TPU, vectorized lax fallback elsewhere); the
pre-PR per-layer interpreter chain is re-measured as ``int8_before_layered``
so the JSON carries the before/after int8 curve, and
``int8_vs_float_speedup`` records the closed gap per mode.  Throughput
samples are **interleaved** across all four (backend, mode) engines — one
drain each per repetition, medians per engine — so machine-load drift
spreads evenly instead of biasing whichever engine ran last.

The **saturation sweep** closes the overload story: real-time-paced
request arrivals at multiples of the measured pipelined capacity, served
through an admission-policied engine with an injected kernel failure on
the first measured wave (the circuit breaker trips and the rest of the
sweep serves through the bit-exact lax fallback).  Per offered-load level
it records p99 latency from enqueue, the shed rate, and the fraction of
waves served degraded; a no-admission 2x level rides along so the JSON
shows what shedding buys (bounded p99 vs queue collapse).

Writes machine-readable ``BENCH_mrf_serve.json`` (regenerated in place;
commit it to record a perf data point) besides the CSV rows run.py prints.

Weights need no training for a throughput benchmark: a random net with
observer calibration passes exercises the identical compute path.
"""

from __future__ import annotations

import json
import pathlib
import statistics
import time

import jax
import jax.numpy as jnp

from benchmarks import serve_autotune
from repro.configs import get_config
from repro.core import mrf_net, qat
from repro.serve.admission import AdmissionPolicy
from repro.serve.executor import WaveExecutor
from repro.serve.faults import FaultInjector, FaultSpec
from repro.serve.queue import RequestState
from repro.serve.recon import ReconEngine, ReconRequest, latency_percentiles

OUT_PATH = pathlib.Path("BENCH_mrf_serve.json")

# ragged per-request voxel counts: a mix of partial and multi-bucket slices
REQUEST_VOXELS = (700, 1024, 333, 96, 2048, 1500, 811, 64)

# close a wave at 2 full buckets: the 6576-voxel trace splits into several
# waves per drain, so pipelined double-buffering actually has waves to
# overlap (one monolithic wave would make the modes trivially identical)
MAX_WAVE_VOXELS = 2048

# saturation sweep: (label, offered load as a multiple of measured
# capacity, admission policy on?)
SATURATION_LEVELS = (("0.5x", 0.5, True), ("1x", 1.0, True),
                     ("2x", 2.0, True), ("2x_no_admission", 2.0, False))
SAT_DURATION_S = 1.0
SAT_BUDGET_VOXELS = 4 * MAX_WAVE_VOXELS  # admission pending-voxel budget


def _calibrated_net(cfg, seed: int = 0):
    sizes = mrf_net.layer_sizes(cfg.mrf_n_frames, cfg.mrf_hidden)
    params = mrf_net.init_params(jax.random.PRNGKey(seed), sizes)
    qstate = qat.init_qat_state(len(params))
    x = jax.random.normal(jax.random.PRNGKey(seed + 1), (256, sizes[0]))
    for _ in range(4):
        _, qstate = qat.forward_qat(params, qstate, x)
    return params, qat.export_int8(params, qstate)


def _request_wave(cfg, seed: int = 0):
    d = 2 * cfg.mrf_n_frames
    reqs = []
    for i, n in enumerate(REQUEST_VOXELS):
        x = jax.random.normal(jax.random.fold_in(jax.random.PRNGKey(seed), i),
                              (n, d), jnp.float32)
        reqs.append(ReconRequest(features=x, request_id=f"req-{i}"))
    return reqs


def _bench_mode(engine: ReconEngine, requests, waves: int) -> dict:
    """Non-interleaved single-engine measurement (median over drains)."""
    engine.reconstruct(requests)  # warmup: traces every bucket shape
    results, rates = [], []
    voxels = 0
    for _ in range(waves):
        results.extend(engine.reconstruct(requests))
        rates.append(engine.last_wave["voxels_per_s"])
        voxels += engine.last_wave["total_voxels"]
    pct = latency_percentiles(results)
    return {"voxels_per_s": statistics.median(rates),
            "latency_from_enqueue_ms": pct,
            "requests": len(results), "voxels": int(voxels),
            "waves_per_drain": engine.last_wave["n_waves"],
            "buckets_traced": engine.compile_cache_size()}


def _saturation_level(ints, buckets, requests, offered_vps, *,
                      admission: bool,
                      duration_s: float = SAT_DURATION_S) -> dict:
    """Serve ``duration_s`` of real-time-paced arrivals at ``offered_vps``
    voxels/s through a fresh overload-hardened engine; returns the level's
    ledger (latency, shed rate, degraded-wave fraction).

    An injected kernel failure on wave 0 trips the fused->lax circuit
    breaker during warmup, so the warmup drain also compiles the degraded
    buckets and *every measured wave* serves degraded (bit-exact by the
    PR 7 parity proof) — the sweep measures overload behaviour *through*
    the fault, with the breaker's one-time recompile cost paid outside the
    timed window.
    """
    eng = ReconEngine(
        backend="int8", int_layers=ints, int8_impl="fused",
        mode="pipelined", buckets=buckets,
        max_wave_voxels=MAX_WAVE_VOXELS, max_wait_ms=5.0,
        admission=(AdmissionPolicy(max_pending_voxels=SAT_BUDGET_VOXELS)
                   if admission else None),
        injector=FaultInjector([FaultSpec(kind="kernel_fail", wave=0)]))
    eng.reconstruct(requests)  # warmup: trips the breaker, traces buckets
    warm_degraded = eng.executor.n_degraded_waves
    tickets = []
    sent = i = 0
    t0 = time.perf_counter()
    while True:
        elapsed = time.perf_counter() - t0
        if elapsed >= duration_s:
            break
        # arrival pacing: keep cumulative offered voxels on the target line
        while sent < offered_vps * elapsed:
            r = requests[i % len(requests)]
            tickets.append(eng.enqueue(r))
            sent += r.n_voxels
            i += 1
        eng.poll()
    eng.drain()
    done = [t for t in tickets if t.state == RequestState.DONE]
    shed = [t for t in tickets if t.state == RequestState.SHED]
    pct = latency_percentiles([t.result for t in done])
    lw = eng.last_wave
    return {"offered_voxels_per_s": offered_vps,
            "admission": admission,
            "submitted": len(tickets), "done": len(done),
            "shed": len(shed),
            "failed": len(tickets) - len(done) - len(shed),
            "shed_rate": len(shed) / max(len(tickets), 1),
            "degraded_wave_frac": (
                (eng.executor.n_degraded_waves - warm_degraded)
                / max(lw["n_waves"], 1)),
            "p50_ms": pct["p50_ms"], "p99_ms": pct["p99_ms"],
            "served_voxels_per_s": lw["voxels_per_s"]}


def _tuned_buckets(ints, requests, reps: int) -> dict:
    """Record the trace's size distribution through a probe executor and
    tune the bucket set against measured per-bucket medians (the
    measurement-driven replacement for DEFAULT_BUCKETS)."""
    probe = WaveExecutor(backend="int8", int_layers=ints)
    probe.dispatch([r.features for r in requests]).wait()

    def time_buckets(buckets):
        return serve_autotune.measure_bucket_times(
            probe._fwd, buckets, probe.in_dim, reps=reps)

    return serve_autotune.tune_buckets(probe.request_sizes, time_buckets)


def run(waves: int = 5, reps: int = 5, out_path=OUT_PATH):
    """run.py suite entry: yields (name, us_per_call, derived) rows and
    writes the JSON record — per backend, sync vs pipelined voxels/s on the
    autotuned bucket set, latency-from-enqueue percentiles, per-bucket
    tile throughput, pipelined_speedup_vs_sync, int8_vs_float_speedup and
    the before/after int8 curve."""
    cfg = get_config("mrf-fpga")
    params, ints = _calibrated_net(cfg)
    requests = _request_wave(cfg)
    tuned = _tuned_buckets(ints, requests, reps)
    buckets = tuned["buckets"]
    record = {"suite": "mrf_serve", "arch": cfg.name,
              "n_frames": cfg.mrf_n_frames,
              "request_voxels": list(REQUEST_VOXELS), "waves": waves,
              "max_wave_voxels": MAX_WAVE_VOXELS,
              "buckets": list(buckets),
              "autotune": {"predicted_trace_s": tuned["predicted_trace_s"],
                           "bucket_times_s": tuned["bucket_times_s"]},
              "backends": {}}
    rows = [("mrf_serve/buckets", 0.0, f"autotuned={list(buckets)}")]

    engines = {}
    for backend, net_kw in (("float", {"params": params}),
                            ("int8", {"int_layers": ints})):
        for mode in ("sync", "pipelined"):
            engines[(backend, mode)] = ReconEngine(
                backend=backend, mode=mode, buckets=buckets,
                max_wave_voxels=MAX_WAVE_VOXELS, **net_kw)

    # interleaved sampling: one drain per engine per repetition, so load
    # drift spreads across engines instead of biasing the last one measured
    for eng in engines.values():
        eng.reconstruct(requests)  # warmup: traces every bucket shape
    samples = {k: [] for k in engines}
    results = {k: [] for k in engines}
    for _ in range(waves):
        for k, eng in engines.items():
            results[k].extend(eng.reconstruct(requests))
            samples[k].append(eng.last_wave["voxels_per_s"])

    for backend in ("float", "int8"):
        by_mode = {}
        for mode in ("sync", "pipelined"):
            eng = engines[(backend, mode)]
            pct = latency_percentiles(results[(backend, mode)])
            r = {"voxels_per_s": statistics.median(samples[(backend, mode)]),
                 "latency_from_enqueue_ms": pct,
                 "requests": len(results[(backend, mode)]),
                 "waves_per_drain": eng.last_wave["n_waves"],
                 "buckets_traced": eng.compile_cache_size()}
            by_mode[mode] = r
            rows.append((f"mrf_serve/{backend}/{mode}",
                         pct["p50_ms"] * 1e3,
                         f"voxels/s={r['voxels_per_s']:.0f} "
                         f"p99={pct['p99_ms']:.1f}ms"))
        by_mode["pipelined_speedup_vs_sync"] = (
            by_mode["pipelined"]["voxels_per_s"]
            / max(by_mode["sync"]["voxels_per_s"], 1e-12))
        # per-bucket tile throughput through this backend's jitted forward
        bt = serve_autotune.measure_bucket_times(
            engines[(backend, "sync")].executor._fwd, buckets,
            engines[(backend, "sync")].in_dim, reps=reps)
        by_mode["per_bucket_voxels_per_s"] = {
            str(b): b / max(t, 1e-12) for b, t in sorted(bt.items())}
        record["backends"][backend] = by_mode
        rows.append((f"mrf_serve/{backend}/speedup", 0.0,
                     f"pipelined_speedup_vs_sync="
                     f"{by_mode['pipelined_speedup_vs_sync']:.3f}"))

    # the closed gap: rig-default int8 impl vs float, same buckets/trace
    record["int8_impl"] = engines[("int8", "sync")].int8_impl
    record["int8_vs_float_speedup"] = {
        mode: (record["backends"]["int8"][mode]["voxels_per_s"]
               / max(record["backends"]["float"][mode]["voxels_per_s"], 1e-12))
        for mode in ("sync", "pipelined")}
    rows.append(("mrf_serve/int8_vs_float", 0.0,
                 "sync={sync:.3f} pipelined={pipelined:.3f}".format(
                     **record["int8_vs_float_speedup"])))

    # before/after curve: the pre-PR per-layer interpreter chain, one drain
    # (it is ~10-30x slower; a single wave bounds bench time)
    before = _bench_mode(
        ReconEngine(backend="int8", int_layers=ints, int8_impl="layered",
                    mode="sync", max_wave_voxels=MAX_WAVE_VOXELS),
        requests, 1)
    record["int8_before_layered"] = {
        "voxels_per_s": before["voxels_per_s"],
        "speedup_after_vs_before": (
            record["backends"]["int8"]["sync"]["voxels_per_s"]
            / max(before["voxels_per_s"], 1e-12))}
    rows.append(("mrf_serve/int8_before_layered", 0.0,
                 f"voxels/s={before['voxels_per_s']:.0f} after/before="
                 f"{record['int8_before_layered']['speedup_after_vs_before']:.1f}x"))

    # saturation sweep: offered load vs p99 / shed rate / degraded fraction
    capacity = record["backends"]["int8"]["pipelined"]["voxels_per_s"]
    sat = {"capacity_voxels_per_s": capacity,
           "budget_voxels": SAT_BUDGET_VOXELS,
           "duration_s": SAT_DURATION_S,
           "note": ("fused int8 engine; an injected kernel_fail at wave 0 "
                    "trips the circuit breaker during warmup, so every "
                    "measured wave serves degraded (lax, bit-exact) — "
                    "degraded_wave_frac records it"),
           "levels": {}}
    for name, mult, adm in SATURATION_LEVELS:
        lvl = _saturation_level(ints, buckets, requests, capacity * mult,
                                admission=adm)
        sat["levels"][name] = lvl
        rows.append((f"mrf_serve/saturation/{name}", lvl["p99_ms"] * 1e3,
                     f"shed={lvl['shed_rate']:.0%} degraded="
                     f"{lvl['degraded_wave_frac']:.0%} "
                     f"served={lvl['served_voxels_per_s']:.0f}vox/s"))
    record["saturation"] = sat

    pathlib.Path(out_path).write_text(json.dumps(record, indent=1))
    rows.append(("mrf_serve/json", 0.0, f"wrote {out_path}"))
    return rows
