"""MRF serving benchmark: sync vs pipelined serving on the same request
trace, for both recon-engine backends (float / int8-Pallas).

Sync mode is the per-tile-retirement baseline (the pre-queue engine);
pipelined mode streams the same trace through the persistent request queue
and the double-buffered wave executor (one host sync per wave, staging of
wave N+1 overlapped with compute of wave N).  Both modes run the identical
jitted per-bucket forward, so their maps are bit-identical — the benchmark
measures pure scheduling: voxels/s plus p50/p90/p99 latency **from enqueue
time** per mode, and ``pipelined_speedup_vs_sync`` per backend.

Writes machine-readable ``BENCH_mrf_serve.json`` (regenerated in place;
commit it to record a perf data point) besides the CSV rows run.py prints.

Weights need no training for a throughput benchmark: a random net with
observer calibration passes exercises the identical compute path.
"""

from __future__ import annotations

import json
import pathlib

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core import mrf_net, qat
from repro.serve.recon import ReconEngine, ReconRequest, latency_percentiles

OUT_PATH = pathlib.Path("BENCH_mrf_serve.json")

# ragged per-request voxel counts: a mix of partial and multi-bucket slices
REQUEST_VOXELS = (700, 1024, 333, 96, 2048, 1500, 811, 64)

# close a wave at 2 full buckets: the 6576-voxel trace splits into several
# waves per drain, so pipelined double-buffering actually has waves to
# overlap (one monolithic wave would make the modes trivially identical)
MAX_WAVE_VOXELS = 2048


def _calibrated_net(cfg, seed: int = 0):
    sizes = mrf_net.layer_sizes(cfg.mrf_n_frames, cfg.mrf_hidden)
    params = mrf_net.init_params(jax.random.PRNGKey(seed), sizes)
    qstate = qat.init_qat_state(len(params))
    x = jax.random.normal(jax.random.PRNGKey(seed + 1), (256, sizes[0]))
    for _ in range(4):
        _, qstate = qat.forward_qat(params, qstate, x)
    return params, qat.export_int8(params, qstate)


def _request_wave(cfg, seed: int = 0):
    d = 2 * cfg.mrf_n_frames
    reqs = []
    for i, n in enumerate(REQUEST_VOXELS):
        x = jax.random.normal(jax.random.fold_in(jax.random.PRNGKey(seed), i),
                              (n, d), jnp.float32)
        reqs.append(ReconRequest(features=x, request_id=f"req-{i}"))
    return reqs


def _bench_mode(engine: ReconEngine, requests, waves: int) -> dict:
    engine.reconstruct(requests)  # warmup: traces every bucket shape
    results = []
    wall = voxels = 0.0
    for _ in range(waves):
        results.extend(engine.reconstruct(requests))
        wall += engine.last_wave["wall_s"]
        voxels += engine.last_wave["total_voxels"]
    pct = latency_percentiles(results)
    return {"voxels_per_s": voxels / max(wall, 1e-12),
            "latency_from_enqueue_ms": pct,
            "requests": len(results), "voxels": int(voxels),
            "waves_per_drain": engine.last_wave["n_waves"],
            "buckets_traced": engine.compile_cache_size()}


def run(waves: int = 5, out_path=OUT_PATH):
    """run.py suite entry: yields (name, us_per_call, derived) rows and
    writes the JSON record — per backend, sync vs pipelined voxels/s,
    latency-from-enqueue percentiles, and pipelined_speedup_vs_sync."""
    cfg = get_config("mrf-fpga")
    params, ints = _calibrated_net(cfg)
    requests = _request_wave(cfg)
    record = {"suite": "mrf_serve", "arch": cfg.name,
              "n_frames": cfg.mrf_n_frames,
              "request_voxels": list(REQUEST_VOXELS), "waves": waves,
              "max_wave_voxels": MAX_WAVE_VOXELS,
              "backends": {}}
    rows = []
    for backend, net_kw in (("float", {"params": params}),
                            ("int8", {"int_layers": ints})):
        by_mode = {}
        for mode in ("sync", "pipelined"):
            engine = ReconEngine(backend=backend, mode=mode,
                                 max_wave_voxels=MAX_WAVE_VOXELS, **net_kw)
            r = _bench_mode(engine, requests, waves)
            by_mode[mode] = r
            rows.append((f"mrf_serve/{backend}/{mode}",
                         r["latency_from_enqueue_ms"]["p50_ms"] * 1e3,
                         f"voxels/s={r['voxels_per_s']:.0f} "
                         f"p99={r['latency_from_enqueue_ms']['p99_ms']:.1f}ms"))
        by_mode["pipelined_speedup_vs_sync"] = (
            by_mode["pipelined"]["voxels_per_s"]
            / max(by_mode["sync"]["voxels_per_s"], 1e-12))
        record["backends"][backend] = by_mode
        rows.append((f"mrf_serve/{backend}/speedup", 0.0,
                     f"pipelined_speedup_vs_sync="
                     f"{by_mode['pipelined_speedup_vs_sync']:.3f}"))
    pathlib.Path(out_path).write_text(json.dumps(record, indent=1))
    rows.append(("mrf_serve/json", 0.0, f"wrote {out_path}"))
    return rows
