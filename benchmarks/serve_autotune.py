"""Measurement-driven serving autotune: bucket set + fused block shape.

The wave executor pads every ragged tail up to a bucket, so the bucket set
is a real cost knob: too-coarse buckets waste MXU cycles on padding, while
every extra bucket adds one jit cache entry (the no-recompile bound).  The
seed's ``DEFAULT_BUCKETS`` (powers of two) is a shape-agnostic guess; this
pass replaces it with a set tuned to the **recorded request-size
distribution** (``WaveExecutor.request_sizes`` — every voxel count the
executor dispatched):

1. ``candidate_bucket_sets`` proposes lane-aligned sets from the size
   distribution's quantiles (plus the power-of-two fallback).
2. ``measure_bucket_times`` times the engine's actual jitted per-bucket
   forward on the rig — interleaved repetitions, per-bucket **medians**, so
   one noisy scheduler event cannot skew a whole bucket column.
3. ``tune_buckets`` scores every candidate set by replaying the recorded
   distribution through ``plan_tiles`` against the measured per-bucket
   costs and returns the arg-min (the timing function is injectable, so the
   scoring logic is unit-testable without a device).

Block shapes for the fused whole-network kernel come from a static VMEM
footprint model (``pick_block_m``): the largest voxel tile whose weights +
activations + accumulator fit the per-core VMEM budget with headroom.  The
choice is cross-checked against the analytical model the repo already
carries: ``analysis.hlo_cost.analyze_hlo`` (trip-aware FLOPs / HBM-proxy
bytes / int8 fraction from the compiled module) feeds
``analysis.roofline.roofline_terms``; ``predicted_tile_terms`` records the
predicted TPU-roofline time next to the measured rig time per bucket, so a
mispredicted shape shows up as a predicted-vs-measured outlier in the JSON.

Writes ``BENCH_serve_autotune.json``; ``mrf_serve_bench`` consumes
``tune_buckets`` to serve the int8-vs-float comparison on the tuned set.
"""

from __future__ import annotations

import json
import pathlib
import statistics
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis.hlo_cost import analyze_hlo
from repro.analysis.roofline import TPU_V5E, roofline_terms
from repro.serve.executor import DEFAULT_BUCKETS, WaveExecutor, plan_tiles

OUT_PATH = pathlib.Path("BENCH_serve_autotune.json")

LANE = 128             # MXU lane width: buckets stay lane-aligned
MAX_BUCKETS = 6        # jit cache bound: at most this many shapes traced
VMEM_BYTES = 16 * 2 ** 20   # per-core VMEM (v5e); the fused kernel's budget
VMEM_HEADROOM = 0.5    # leave half for Mosaic spills / double buffering


def _align_up(n: int, m: int = LANE) -> int:
    return max(m, -(-int(n) // m) * m)


def candidate_bucket_sets(sizes, *, lane: int = LANE,
                          max_buckets: int = MAX_BUCKETS) -> list:
    """Lane-aligned candidate bucket sets from a request-size distribution.

    One candidate per quantile-count k: the aligned {q_1..q_k, max} cut
    points (duplicates collapse, so skewed traces yield small sets), plus
    the power-of-two ``DEFAULT_BUCKETS`` as the control.  Every candidate
    respects the jit cache bound (``len <= max_buckets``).
    """
    sizes = [int(s) for s in sizes if int(s) > 0]
    if not sizes:
        return [tuple(DEFAULT_BUCKETS)]
    arr = np.asarray(sizes, np.float64)
    cands = []
    for k in (2, 3, 4, max_buckets):
        qs = np.percentile(arr, np.linspace(100.0 / k, 100.0, k))
        cand = tuple(sorted({_align_up(q, lane) for q in qs}))
        if 0 < len(cand) <= max_buckets:
            cands.append(cand)
    cands.append(tuple(DEFAULT_BUCKETS))
    # dedupe preserving order (first proposal wins)
    seen, out = set(), []
    for c in cands:
        if c not in seen:
            seen.add(c)
            out.append(c)
    return out


def measure_bucket_times(fwd, buckets, in_dim: int, *, reps: int = 7) -> dict:
    """Median seconds per (bucket, in_dim) tile through a jitted forward.

    Interleaved repetitions: one pass over all buckets per rep (not reps of
    one bucket back-to-back), so slow drift in machine load spreads evenly
    across buckets instead of biasing whichever ran last.
    """
    buckets = sorted({int(b) for b in buckets})
    tiles = {b: jnp.zeros((b, in_dim), jnp.float32) for b in buckets}
    for b in buckets:                       # compile outside the timed region
        jax.block_until_ready(fwd(tiles[b]))
    samples: dict = {b: [] for b in buckets}
    for _ in range(max(int(reps), 1)):
        for b in buckets:
            t0 = time.perf_counter()
            jax.block_until_ready(fwd(tiles[b]))
            samples[b].append(time.perf_counter() - t0)
    return {b: statistics.median(s) for b, s in samples.items()}


def trace_cost(sizes, buckets, tile_time) -> float:
    """Predicted seconds to serve the recorded trace on a bucket set:
    replay every request size through ``plan_tiles`` and charge each tile
    its measured (or modeled) per-bucket time."""
    total = 0.0
    for n in sizes:
        for _off, _cnt, b in plan_tiles(int(n), buckets):
            total += tile_time[b]
    return total


def tune_buckets(sizes, time_buckets, *, lane: int = LANE,
                 max_buckets: int = MAX_BUCKETS) -> dict:
    """Pick the bucket set minimizing measured cost over the recorded trace.

    ``time_buckets(buckets) -> {bucket: seconds}`` is injectable — the real
    caller passes a closure over ``measure_bucket_times`` and the engine's
    jitted forward; tests pass an analytic model and check the scoring.
    """
    sizes = [int(s) for s in sizes]
    cands = candidate_bucket_sets(sizes, lane=lane, max_buckets=max_buckets)
    all_buckets = sorted({b for c in cands for b in c})
    times = time_buckets(all_buckets)
    scored = [{"buckets": list(c),
               "predicted_trace_s": trace_cost(sizes, c, times)}
              for c in cands]
    scored.sort(key=lambda r: r["predicted_trace_s"])
    best = scored[0]
    return {"buckets": tuple(best["buckets"]),
            "predicted_trace_s": best["predicted_trace_s"],
            "candidates": scored,
            "bucket_times_s": {str(b): times[b] for b in all_buckets},
            "n_sizes": len(sizes)}


# --------------------------------------------------------------------------
# Fused-kernel block shape: static VMEM model + roofline cross-check.
# --------------------------------------------------------------------------

def fused_vmem_bytes(block_m: int, in_dim_p: int, widths) -> int:
    """VMEM-resident bytes of one fused-kernel grid step.

    x tile (f32) + every layer's weights (int8) / bias (int32) / scale
    (f32) + the worst-layer working set: int8 activations, int32
    accumulator, f32 rescale, f32 out tile.
    """
    widths = [int(w) for w in widths]
    w_bytes = 0
    k = int(in_dim_p)
    for n in widths:
        w_bytes += k * n + 8 * n    # int8 weights + int32 bias + f32 scale
        k = n
    wmax = max(widths)
    work = block_m * wmax * (1 + 4 + 4) + block_m * widths[-1] * 4
    return 4 * block_m * int(in_dim_p) + w_bytes + work


def pick_block_m(in_dim_p: int, widths, *, vmem_bytes: int = VMEM_BYTES,
                 headroom: float = VMEM_HEADROOM,
                 candidates=(1024, 512, 256, 128)) -> dict:
    """Largest voxel tile whose fused-kernel footprint fits the VMEM budget."""
    budget = vmem_bytes * headroom
    table = {bm: fused_vmem_bytes(bm, in_dim_p, widths) for bm in candidates}
    fits = [bm for bm in sorted(candidates, reverse=True)
            if table[bm] <= budget]
    block_m = fits[0] if fits else min(candidates)
    return {"block_m": block_m, "vmem_budget_bytes": int(budget),
            "footprint_bytes": {str(bm): int(v) for bm, v in table.items()}}


def predicted_tile_terms(fwd, bucket: int, in_dim: int) -> dict:
    """TPU-roofline prediction for one bucket tile of a jitted forward.

    Compile, run the trip-aware HLO analyzer, convert to roofline time
    terms (int8 dot FLOPs ride the 2x MXU path).  Off-TPU this predicts
    what the *deployment* rig would do — recorded next to the measured rig
    time as the cross-check, not as a claim about this host.
    """
    x = jnp.zeros((int(bucket), int(in_dim)), jnp.float32)
    jitted = fwd if hasattr(fwd, "lower") else jax.jit(fwd)
    hlo = jitted.lower(x).compile().as_text()
    hc = analyze_hlo(hlo)
    flops = float(hc["flops"])
    frac = (float(hc.get("flops_int8", 0.0)) / flops) if flops else 0.0
    terms = roofline_terms(
        flops_per_device=flops, bytes_per_device=float(hc["hbm_bytes"]),
        collective_bytes_per_device=float(hc["collectives"].get("total", 0)),
        chips=1, int8_fraction=frac)
    return {"flops": flops, "int8_fraction": frac,
            "hbm_bytes": float(hc["hbm_bytes"]),
            "dominant": terms["dominant"],
            "t_tpu_predicted_s": terms["t_bound_s"],
            "tpu_peak_int8_ops": TPU_V5E["peak_int8_ops"]}


# --------------------------------------------------------------------------
# run.py suite entry
# --------------------------------------------------------------------------

def run(reps: int = 7, out_path=OUT_PATH):
    """Autotune the int8 serving executor on this rig's measurements.

    Records the request-size distribution by replaying the benchmark trace
    through a probe executor, tunes the bucket set against measured
    per-bucket medians, picks the fused block shape from the VMEM model,
    and cross-checks with the analytical roofline.  Yields run.py CSV rows
    and writes ``BENCH_serve_autotune.json``.
    """
    from benchmarks.mrf_serve_bench import (REQUEST_VOXELS, _calibrated_net,
                                            _request_wave)
    from repro.configs import get_config

    cfg = get_config("mrf-fpga")
    _params, ints = _calibrated_net(cfg)
    requests = _request_wave(cfg)

    # probe pass: dispatch the trace once so the executor records the
    # request-size distribution the tuner consumes (the production flow:
    # serve first, read executor.request_sizes, retune)
    probe = WaveExecutor(backend="int8", int_layers=ints)
    probe.dispatch([r.features for r in requests]).wait()
    sizes = list(probe.request_sizes)
    assert sizes == [int(n) for n in REQUEST_VOXELS]

    def time_buckets(buckets):
        return measure_bucket_times(probe._fwd, buckets, probe.in_dim,
                                    reps=reps)

    tuned = tune_buckets(sizes, time_buckets)
    pre = probe._prepadded
    block = pick_block_m(pre.in_dim_p, pre.padded_widths)
    top_bucket = max(tuned["buckets"])
    roof = predicted_tile_terms(probe._fwd, top_bucket, probe.in_dim)

    # DEFAULT_BUCKETS is always among the scored candidates (the control)
    default_cost = next(c["predicted_trace_s"] for c in tuned["candidates"]
                        if c["buckets"] == sorted(DEFAULT_BUCKETS))
    record = {"suite": "serve_autotune", "arch": cfg.name,
              "int8_impl": probe.int8_impl,
              "request_sizes": sizes, "reps": reps,
              "default_buckets": list(DEFAULT_BUCKETS),
              "default_predicted_trace_s": default_cost,
              "tuned": {**tuned, "buckets": list(tuned["buckets"])},
              "fused_block": block,
              "roofline_check": {"bucket": top_bucket, **roof}}
    pathlib.Path(out_path).write_text(json.dumps(record, indent=1))

    speed = (record["default_predicted_trace_s"]
             / max(tuned["predicted_trace_s"], 1e-12))
    rows = [("serve_autotune/buckets", tuned["predicted_trace_s"] * 1e6,
             f"buckets={list(tuned['buckets'])} "
             f"trace_speedup_vs_default={speed:.3f}"),
            ("serve_autotune/block_m", 0.0,
             f"block_m={block['block_m']} "
             f"vmem={block['footprint_bytes'][str(block['block_m'])]}B"),
            ("serve_autotune/roofline", roof["t_tpu_predicted_s"] * 1e6,
             f"dominant={roof['dominant']} "
             f"int8_fraction={roof['int8_fraction']:.2f}"),
            ("serve_autotune/json", 0.0, f"wrote {out_path}")]
    return rows
