"""Paper Eq. (3): training-time model — FPGA (200 s for 250M samples) vs CPU
(~16 h) vs this framework's TPU fused-kernel roofline projection.

Also *measures* the software per-sample step cost on this container's CPU
and the fused Pallas kernel (interpret mode, so a correctness-path timing,
not TPU wall time) to validate the orders of magnitude the paper compares.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.core import fpga_cost_model as fcm
from repro.core import mrf_net
from repro.data.epg import default_sequence
from repro.data.pipeline import MRFSampleStream, sample_batch
from repro.kernels.fused_train import ops as ft_ops
from repro.optim import sgd

N_PAPER = 250_000_000


def run(measure_batch: int = 4096):
    sizes = mrf_net.layer_sizes(32)
    rows = []

    # --- the paper's own arithmetic, reproduced exactly -------------------
    eq3 = fcm.paper_eq3_seconds()
    model = fcm.train_seconds(sizes, N_PAPER)
    rows.append(("eq3/fpga_paper", 0.0,
                 f"200s stated; eq3={eq3:.0f}s; our cycle model={model:.0f}s "
                 f"(fwd {fcm.fwd_cycles(sizes)} + bwd {fcm.bwd_cycles(sizes)} cycles)"))

    # --- measured CPU software step (jit'd SGD, this container) -----------
    stream = MRFSampleStream(seq=default_sequence(32), batch_size=measure_batch)
    x, y = sample_batch(stream, jax.random.PRNGKey(0))
    params = mrf_net.init_params(jax.random.PRNGKey(1), sizes)
    opt = sgd(1e-3)
    opt_state = opt.init(params)

    @jax.jit
    def step(params, opt_state, x, y):
        loss, grads = jax.value_and_grad(mrf_net.mse_loss)(params, x, y)
        return *opt.update(grads, opt_state, params), loss

    step(params, opt_state, x, y)[2].block_until_ready()  # compile
    t0 = time.perf_counter()
    reps = 5
    for _ in range(reps):
        params, opt_state, loss = step(params, opt_state, x, y)
    loss.block_until_ready()
    per_sample_cpu = (time.perf_counter() - t0) / (reps * measure_batch)
    cpu_250m = per_sample_cpu * N_PAPER
    rows.append(("eq3/cpu_measured", per_sample_cpu * 1e6,
                 f"{cpu_250m:.0f}s for 250M on THIS cpu (jit'd JAX) vs paper "
                 f"Keras-CPU 57600s — a tuned software baseline closes "
                 f"{57600/cpu_250m:.0f}x of the paper's 250x; vs FPGA 200s: "
                 f"{cpu_250m/eq3:.1f}x slower"))

    # --- TPU roofline projection for the fused VMEM-resident kernel -------
    tpu = fcm.tpu_train_seconds(sizes, N_PAPER, chips=1, int8=True)
    rows.append(("eq3/tpu_fused_projection", 0.0,
                 f"{tpu['t_total_s']:.2f}s for 250M on ONE v5e chip, priced "
                 f"on the padded 128-lane layers the kernel executes "
                 f"({tpu['bound']}-bound; compute {tpu['t_compute_s']:.2f}s, "
                 f"stream {tpu['t_memory_s']:.2f}s) -> "
                 f"{eq3/tpu['t_total_s']:.0f}x faster than the paper's FPGA"))

    # --- measured fused kernel step (interpret mode) ----------------------
    b = 1024
    xk = jnp.zeros((b, sizes[0]), jnp.float32)
    yk = jnp.zeros((b, 2), jnp.float32)
    new, losses = ft_ops.fused_train_step(params, xk, yk, lr=1e-3,
                                          tile_batch=256)
    jax.block_until_ready(losses)
    t0 = time.perf_counter()
    new, losses = ft_ops.fused_train_step(params, xk, yk, lr=1e-3,
                                          tile_batch=256)
    jax.block_until_ready(losses)
    per_call = time.perf_counter() - t0
    rows.append(("eq3/fused_kernel_interpret", per_call / b * 1e6,
                 "interpret-mode correctness path (TPU wall time is the "
                 "roofline row above)"))
    return rows
