"""Kernel micro-benchmarks: fused_train and qat_dense vs their pure-jnp
oracles (interpret mode on CPU — relative numbers validate the paths; TPU
wall time comes from the §Roofline projection)."""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.core import mrf_net
from repro.kernels.fused_train import ops as ft_ops, ref as ft_ref
from repro.kernels.qat_dense import ops as qd_ops, ref as qd_ref


def _time(fn, *args, reps=3):
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps


def run():
    rows = []
    sizes = mrf_net.layer_sizes(32)
    params = mrf_net.init_params(jax.random.PRNGKey(0), sizes)
    x = jax.random.normal(jax.random.PRNGKey(1), (512, sizes[0]))
    y = jax.random.uniform(jax.random.PRNGKey(2), (512, 2))

    t_k = _time(lambda: ft_ops.fused_train_step(params, x, y, lr=1e-3,
                                                tile_batch=128))
    t_r = _time(lambda: ft_ref.ref_train(params, x, y, lr=1e-3,
                                         tile_batch=128))
    rows.append(("kernel/fused_train", t_k * 1e6,
                 f"oracle {t_r*1e6:.0f}us; interpret/oracle {t_k/t_r:.1f}x"))

    xq = jax.random.randint(jax.random.PRNGKey(3), (256, 256), -128, 128, jnp.int8)
    wq = jax.random.randint(jax.random.PRNGKey(4), (256, 256), -128, 128, jnp.int8)
    bq = jnp.zeros((256,), jnp.int32)
    s = jnp.full((256,), 1e-3, jnp.float32)
    t_k = _time(lambda: qd_ops.qat_dense(xq, wq, bq, s))
    t_r = _time(lambda: qd_ref.ref_qat_dense(xq, wq, bq, s))
    rows.append(("kernel/qat_dense_int8", t_k * 1e6,
                 f"oracle {t_r*1e6:.0f}us; bit-exact; MXU int8 target "
                 f"394 TOPS (2x bf16)"))
    return rows
