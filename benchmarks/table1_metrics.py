"""Paper Table 1: error metrics (MAPE / MPE / RMSE on T1, T2) for the
original vs the adapted+quantized network, on 5000 held-out synthetic
signals.

The paper trains 500 epochs x 1000 steps on 250M signals (16 h CPU); this
harness runs a scaled schedule (CPU container) — the comparison of interest
(original vs quantized degradation pattern) is preserved.  Columns mirror
the paper's table; the paper's numbers are printed alongside.
"""

from __future__ import annotations

import time

from repro.core import mrf_net, qat
from repro.core.train_loop import TrainConfig, evaluate, train
from repro.data.epg import default_sequence

PAPER_TABLE1 = {
    "original": {"T1": {"MAPE_%": 2.15, "MPE_%": -0.66, "RMSE_ms": 75},
                 "T2": {"MAPE_%": 8.89, "MPE_%": 0.02, "RMSE_ms": 145}},
    "quantized": {"T1": {"MAPE_%": 2.36, "MPE_%": 0.12, "RMSE_ms": 78},
                  "T2": {"MAPE_%": 11.07, "MPE_%": -3.12, "RMSE_ms": 148}},
}


def run(steps: int = 800, verbose: bool = False):
    seq = default_sequence(32)
    rows = []
    t0 = time.perf_counter()

    # original (9-layer) float net — the Barbieri baseline
    cfg_o = TrainConfig(hidden=mrf_net.ORIGINAL_HIDDEN, steps=steps,
                        lr=1e-3, batch_size=256)
    params_o, _, _ = train(cfg_o, verbose=verbose)
    m_o = evaluate(params_o, seq)

    # adapted net with QAT -> full-integer export (the paper's FPGA net)
    cfg_q = TrainConfig(hidden=mrf_net.ADAPTED_HIDDEN, steps=steps,
                        lr=1e-3, batch_size=256, qat=True)
    params_q, qstate, _ = train(cfg_q, verbose=verbose)
    ints = qat.export_int8(params_q, qstate)
    m_q = evaluate(params_q, seq, int_layers=ints)

    wall = time.perf_counter() - t0
    us = wall / (2 * steps) * 1e6
    for name, m, paper in (("original", m_o, PAPER_TABLE1["original"]),
                           ("quantized-int8", m_q, PAPER_TABLE1["quantized"])):
        for p in ("T1", "T2"):
            rows.append((f"table1/{name}/{p}", us,
                         f"MAPE={m[p]['MAPE_%']:.2f}% (paper {paper[p]['MAPE_%']}%) "
                         f"MPE={m[p]['MPE_%']:+.2f}% RMSE={m[p]['RMSE_ms']:.0f}ms "
                         f"(paper {paper[p]['RMSE_ms']}ms)"))
    return rows
