"""§Roofline summary over the dry-run records (experiments/dryrun/*.json):
per (arch x shape x mesh) — the three terms, dominant bottleneck, useful-
FLOPs ratio.  The full table lives in EXPERIMENTS.md; this harness surfaces
the single-pod baselines as benchmark rows."""

from __future__ import annotations

import json
import pathlib

DRYRUN_DIR = pathlib.Path(__file__).resolve().parent.parent / "experiments" / "dryrun"


def load_records(label="baseline", mesh="single"):
    recs = []
    for p in sorted(DRYRUN_DIR.glob(f"*_{mesh}_{label}.json")):
        r = json.loads(p.read_text())
        if r.get("status") == "ok":
            recs.append(r)
    return recs


def run():
    rows = []
    recs = load_records()
    if not recs:
        return [("roofline/missing", 0.0,
                 "run PYTHONPATH=src python -m repro.launch.dryrun first")]
    for r in recs:
        rf = r["roofline"]
        rows.append((f"roofline/{r['arch']}/{r['shape']}", 0.0,
                     f"dom={rf['dominant']} bound={rf['t_bound_s']:.3f}s "
                     f"compute={rf['t_compute_s']:.3f}s "
                     f"mem={rf['t_memory_s']:.3f}s "
                     f"coll={rf['t_collective_s']:.3f}s "
                     f"frac={rf['roofline_fraction']:.3f} "
                     f"useful={rf['useful_flops_ratio'] or 0:.2f}"))
    return rows
