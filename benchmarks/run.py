# One function per paper table. Prints ``name,us_per_call,derived`` CSV.
#   table1_metrics   — paper Table 1 (original vs quantized error metrics)
#   table_eq3_timing — paper Eq. 3 training-time model (FPGA/CPU/TPU)
#   table_resources  — paper §3 FPGA resource estimates
#   kernel_bench     — Pallas kernel micro-benchmarks vs oracles
#   roofline_report  — §Roofline summary from the dry-run records
#   engine_bench     — samples/s for the three MRF training backends,
#                      stepwise AND chunked dispatch (--chunk-steps) with
#                      chunk_speedup_vs_stepwise per backend
#                      (writes BENCH_train_engine.json, the perf trajectory)
#   mrf_serve_bench  — recon serving stack: sync vs pipelined voxels/s on
#                      autotuned buckets + latency-from-enqueue percentiles,
#                      pipelined_speedup_vs_sync, int8_vs_float_speedup,
#                      per-bucket breakdown and the before/after int8 curve
#                      (writes BENCH_mrf_serve.json)
#   serve_autotune   — measured bucket-set + fused block-shape autotune with
#                      the roofline/hlo_cost cross-check
#                      (writes BENCH_serve_autotune.json)
from __future__ import annotations

import argparse


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma list: table1,eq3,resources,kernels,roofline,"
                         "engine,mrf_serve,serve_autotune")
    ap.add_argument("--steps", type=int, default=800,
                    help="training steps for table1 (scaled schedule)")
    ap.add_argument("--engine-steps", type=int, default=20,
                    help="timed steps per backend for the engine suite")
    ap.add_argument("--chunk-steps", type=int, default=16,
                    help="chunk length for the engine suite's chunked-mode "
                         "runs (the stepwise baseline always runs too)")
    ap.add_argument("--serve-waves", type=int, default=5,
                    help="timed request waves per backend for mrf_serve")
    ap.add_argument("--serve-reps", type=int, default=5,
                    help="interleaved timing repetitions for the serving "
                         "suites' per-bucket medians")
    args = ap.parse_args()
    want = set(args.only.split(",")) if args.only else None

    from benchmarks import (engine_bench, kernel_bench, mrf_serve_bench,
                            roofline_report, serve_autotune, table1_metrics,
                            table_eq3_timing, table_resources)

    suites = [
        ("eq3", table_eq3_timing.run, {}),
        ("resources", table_resources.run, {}),
        ("kernels", kernel_bench.run, {}),
        ("roofline", roofline_report.run, {}),
        ("engine", engine_bench.run, {"steps": args.engine_steps,
                                      "chunk_steps": args.chunk_steps}),
        ("serve_autotune", serve_autotune.run, {"reps": args.serve_reps}),
        ("mrf_serve", mrf_serve_bench.run, {"waves": args.serve_waves,
                                            "reps": args.serve_reps}),
        ("table1", table1_metrics.run, {"steps": args.steps}),
    ]
    print("name,us_per_call,derived")
    for key, fn, kw in suites:
        if want and key not in want:
            continue
        try:
            for name, us, derived in fn(**kw):
                print(f'{name},{us:.2f},"{derived}"', flush=True)
        except Exception as e:  # keep the harness running
            print(f'{key}/ERROR,0,"{type(e).__name__}: {e}"', flush=True)


if __name__ == '__main__':
    main()
