"""Engine throughput trajectory: samples/s for the MRF training backend
variants (float / qat-int8 / fused-pallas SGD / fused-pallas Adam) through
the unified engine, on the paper's adapted net — in both dispatch modes:
stepwise (one Python dispatch + one host sync per step, the baseline) and
chunked.  Chunked float/qat runs ``chunk_steps`` steps per ``lax.scan``
dispatch with in-scan batch synthesis; chunked fused-pallas runs the whole
chunk as ONE multi-step kernel launch with weights (and Adam moments)
VMEM-resident across every step.  Stepwise and chunked are bit-identical,
so ``chunk_speedup_vs_stepwise`` is pure dispatch/HBM-traffic recovery;
the fused number is recorded again at top level as
``fused_multistep_speedup_vs_stepwise``, the headline this repo tracks.

Besides the CSV rows the run.py harness prints, writes machine-readable
``BENCH_train_engine.json`` so successive PRs can track the perf trajectory
(the file is regenerated in place; commit it to record a data point).
"""

from __future__ import annotations

import json
import pathlib
import tempfile

import jax

from repro.configs import get_config
from repro.ft.runner import RunnerConfig
from repro.models import registry
from repro.train import engine

OUT_PATH = pathlib.Path("BENCH_train_engine.json")

# variant name -> EngineConfig kwargs; "backend" defaults to the variant
# name (fused-pallas-adam is the fused backend with the in-kernel Adam rule)
BACKEND_CFGS = {
    "float": dict(optimizer="adam", lr=1e-3),
    "qat-int8": dict(optimizer="adam", lr=1e-3),
    "fused-pallas": dict(optimizer="sgd", lr=2e-2, tile_batch=128),
    "fused-pallas-adam": dict(backend="fused-pallas", optimizer="adam",
                              lr=1e-3, tile_batch=128),
}


def _bench_backend(fns, backend: str, steps: int, batch: int,
                   warmup: int, chunk_steps: int = 1,
                   repeats: int = 3) -> dict:
    """Steady-state per-step time from the runner's on_metrics ticks.

    Stepwise: each tick's dt is a synced per-step wall time.  Chunked: each
    tick carries chunk_wall/n, so a steady tick is the true per-step cost
    incl. the once-per-chunk dispatch + metrics fetch.  ``warmup`` steps
    (compile + cache warm) are discarded; for chunked runs the caller passes
    a whole first chunk as warmup so every steady chunk is full-length (no
    ragged-tail recompile in the timed region).

    Aggregation is timeit-style best-of-``repeats`` medians: the shared CPU
    rig throws multi-ms scheduler stalls that can poison a whole run, and
    the fastest repeat's median is the closest observable to what the
    hardware allows.
    """
    stream = engine.default_stream(fns.cfg, batch)
    kwargs = dict(BACKEND_CFGS[backend])
    kwargs.setdefault("backend", backend)
    ecfg = engine.EngineConfig(max_grad_norm=None,
                               chunk_steps=chunk_steps, **kwargs)
    best, wall = None, None
    for _ in range(repeats):
        dts = []  # per-step wall times from the runner; head incl. compile
        with tempfile.TemporaryDirectory(prefix="engine_bench_") as ckpt:
            total = steps + warmup
            rcfg = RunnerConfig(total_steps=total, ckpt_dir=ckpt,
                                ckpt_every=total + 1)
            _, _, info = engine.train(
                fns, ecfg, rcfg, stream=stream,
                data_key=jax.random.PRNGKey(1), batch_size=batch,
                on_metrics=lambda step, metrics, dt: dts.append(dt))
        steady = sorted(dts[warmup:])
        med = steady[len(steady) // 2]
        if best is None or med < best:
            best, wall = med, info["wall_seconds"]
    return {"samples_per_s": batch / best,
            "us_per_step": best * 1e6,
            "wall_seconds": wall, "steps": steps,
            "chunk_steps": chunk_steps, "repeats": repeats}


def run(steps: int = 24, batch: int = 16, chunk_steps: int = 16,
        out_path=OUT_PATH):
    """run.py suite entry: yields (name, us_per_call, derived) rows and
    writes the JSON trajectory file (stepwise + chunked per backend).

    batch=16 is the dispatch-bound regime chunking targets: per-step device
    work under the host round-trip cost — the paper's whole premise for the
    <30k-param net, whose FPGA loop streams per-sample.  Larger batches
    shift the loop compute-bound (chunking still wins, by less).  The JSON
    records the batch, so trajectory points stay self-describing across PRs.
    """
    cfg = get_config("mrf-fpga")
    fns = registry.build(cfg)
    # chunked timed region: whole chunks only (first chunk = warmup)
    chunked_steps = max(1, round(steps / chunk_steps)) * chunk_steps
    record = {"suite": "train_engine", "arch": cfg.name, "batch": batch,
              "n_frames": cfg.mrf_n_frames, "chunk_steps": chunk_steps,
              "backends": {}}
    rows = []
    for backend in BACKEND_CFGS:
        r = _bench_backend(fns, backend, steps=steps, batch=batch, warmup=2)
        c = _bench_backend(fns, backend, steps=chunked_steps, batch=batch,
                           warmup=chunk_steps, chunk_steps=chunk_steps)
        r["chunked"] = c
        r["chunk_speedup_vs_stepwise"] = (
            c["samples_per_s"] / r["samples_per_s"])
        record["backends"][backend] = r
        rows.append((f"engine/{backend}", r["us_per_step"],
                     f"samples/s={r['samples_per_s']:.0f}"))
        rows.append((f"engine/{backend}/chunked{chunk_steps}",
                     c["us_per_step"],
                     f"samples/s={c['samples_per_s']:.0f} "
                     f"speedup={r['chunk_speedup_vs_stepwise']:.2f}x"))
    record["fused_multistep_speedup_vs_stepwise"] = (
        record["backends"]["fused-pallas"]["chunk_speedup_vs_stepwise"])
    pathlib.Path(out_path).write_text(json.dumps(record, indent=1))
    rows.append(("engine/json", 0.0, f"wrote {out_path}"))
    return rows
