"""Engine throughput trajectory: samples/s for the three MRF training
backends (float / qat-int8 / fused-pallas) through the unified engine, on the
paper's adapted net.

Besides the CSV rows the run.py harness prints, writes machine-readable
``BENCH_train_engine.json`` so successive PRs can track the perf trajectory
(the file is regenerated in place; commit it to record a data point).
"""

from __future__ import annotations

import json
import pathlib
import tempfile

import jax

from repro.configs import get_config
from repro.data.pipeline import make_batch_factory
from repro.ft.runner import RunnerConfig
from repro.models import registry
from repro.train import engine

OUT_PATH = pathlib.Path("BENCH_train_engine.json")

BACKEND_CFGS = {
    "float": dict(optimizer="adam", lr=1e-3),
    "qat-int8": dict(optimizer="adam", lr=1e-3),
    "fused-pallas": dict(optimizer="sgd", lr=2e-2, tile_batch=128),
}


def _bench_backend(fns, backend: str, steps: int, batch: int,
                   warmup: int) -> dict:
    stream = engine.default_stream(fns.cfg, batch)
    ecfg = engine.EngineConfig(backend=backend, max_grad_norm=None,
                               **BACKEND_CFGS[backend])
    dts = []  # per-step wall times from the runner; head includes compile
    with tempfile.TemporaryDirectory(prefix="engine_bench_") as ckpt:
        rcfg = RunnerConfig(total_steps=steps + warmup, ckpt_dir=ckpt,
                            ckpt_every=steps + warmup + 1)
        _, _, info = engine.train(
            fns, ecfg, rcfg,
            batches=make_batch_factory(stream, jax.random.PRNGKey(1)),
            batch_size=batch,
            on_metrics=lambda step, metrics, dt: dts.append(dt))
    steady = dts[warmup:]
    per_step = sum(steady) / len(steady)
    return {"samples_per_s": batch / per_step,
            "us_per_step": per_step * 1e6,
            "wall_seconds": info["wall_seconds"], "steps": steps}


def run(steps: int = 20, batch: int = 256, out_path=OUT_PATH):
    """run.py suite entry: yields (name, us_per_call, derived) rows and
    writes the JSON trajectory file."""
    cfg = get_config("mrf-fpga")
    fns = registry.build(cfg)
    record = {"suite": "train_engine", "arch": cfg.name, "batch": batch,
              "n_frames": cfg.mrf_n_frames, "backends": {}}
    rows = []
    for backend in ("float", "qat-int8", "fused-pallas"):
        r = _bench_backend(fns, backend, steps=steps, batch=batch, warmup=2)
        record["backends"][backend] = r
        rows.append((f"engine/{backend}", r["us_per_step"],
                     f"samples/s={r['samples_per_s']:.0f}"))
    pathlib.Path(out_path).write_text(json.dumps(record, indent=1))
    rows.append(("engine/json", 0.0, f"wrote {out_path}"))
    return rows
