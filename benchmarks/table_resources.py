"""Paper §3 resource table: FPGA LUT/DSP/FF estimates for the NN + backprop
blocks, from the calibrated analytic model, against the paper's stated
numbers (145k LUT / 5k DSP / 146k FF; 8% LUT, 40% DSP of an ALVEO U250) and
the balancing proposal from the conclusion (+274k LUT to free ~2k DSP)."""

from __future__ import annotations

from repro.core import fpga_cost_model as fcm
from repro.core import mrf_net


def run():
    sizes = mrf_net.layer_sizes(32)
    est = fcm.resource_estimate(sizes)
    paper = fcm.PAPER["resources_nn"]
    rows = [
        ("resources/model_LUT", 0.0,
         f"{est['LUT']:,} (paper {paper['LUT']:,}; {est['LUT_frac']:.1%} of U250)"),
        ("resources/model_DSP", 0.0,
         f"{est['DSP']:,} (paper {paper['DSP']:,}; {est['DSP_frac']:.1%} of U250)"),
        ("resources/model_FF", 0.0, f"{est['FF']:,} (paper {paper['FF']:,})"),
        ("resources/pcie", 0.0,
         f"paper adds {fcm.PAPER['resources_pcie']['LUT']:,} LUT / "
         f"{fcm.PAPER['resources_pcie']['FF']:,} FF / "
         f"{fcm.PAPER['resources_pcie']['BRAM']} BRAM for PCIe"),
        ("resources/balance_proposal", 0.0,
         "conclusion: +274k LUT to remove ~2k DSP -> both ~24%, enabling a "
         "2x parallel NN instance"),
    ]
    return rows
