"""Generate EXPERIMENTS.md §Dry-run and §Roofline markdown tables from
experiments/dryrun/*.json records.

Usage: PYTHONPATH=src python experiments/make_tables.py [--label baseline]
Prints markdown to stdout (paste/refresh into EXPERIMENTS.md).
"""

from __future__ import annotations

import argparse
import json
import pathlib

HERE = pathlib.Path(__file__).resolve().parent
DRYRUN = HERE / "dryrun"

SHAPE_ORDER = {"train_4k": 0, "prefill_32k": 1, "decode_32k": 2, "long_500k": 3}


def load(label: str, mesh: str):
    out = []
    for p in sorted(DRYRUN.glob(f"*_{mesh}_{label}.json")):
        r = json.loads(p.read_text())
        out.append(r)
    out.sort(key=lambda r: (r["arch"], SHAPE_ORDER.get(r["shape"], 9)))
    return out


def fmt_bytes(b):
    if b >= 1e12:
        return f"{b/1e12:.2f}TB"
    if b >= 1e9:
        return f"{b/1e9:.2f}GB"
    return f"{b/1e6:.1f}MB"


def dryrun_table(label: str):
    print(f"\n### §Dry-run — compile proof, {label} "
          f"(single-pod 16x16=256 chips AND multi-pod 2x16x16=512 chips)\n")
    print("| arch | shape | mesh | status | compile s | peak mem/dev | "
          "wire bytes/dev (collectives) | HLO flops/dev |")
    print("|---|---|---|---|---|---|---|---|")
    for mesh in ("single", "multi"):
        for r in load(label, mesh):
            if r.get("status") != "ok":
                print(f"| {r['arch']} | {r['shape']} | {mesh} | "
                      f"ERROR: {r.get('error','')[:60]} | | | | |")
                continue
            mem = r.get("memory", {})
            peak = mem.get("peak_per_device_bytes")
            print(f"| {r['arch']} | {r['shape']} | {mesh} | ok | "
                  f"{r['compile_s']:.1f} | "
                  f"{fmt_bytes(peak) if peak else 'n/a'} | "
                  f"{fmt_bytes(r['collectives'].get('total', 0))} | "
                  f"{r['hlo_cost']['flops']:.2e} |")


def roofline_table(label: str):
    print(f"\n### §Roofline — per-cell terms, {label} (single-pod, 256 chips; "
          "v5e: 197 TF/s bf16, 819 GB/s HBM, 50 GB/s/link ICI)\n")
    print("| arch | shape | compute s | memory s | collective s | dominant | "
          "roofline frac | MODEL_FLOPS/HLO | next lever |")
    print("|---|---|---|---|---|---|---|---|---|")
    for r in load(label, "single"):
        if r.get("status") != "ok":
            continue
        rf = r["roofline"]
        lever = suggest_lever(r)
        useful = rf.get("useful_flops_ratio") or 0.0
        print(f"| {r['arch']} | {r['shape']} | {rf['t_compute_s']:.4f} | "
              f"{rf['t_memory_s']:.4f} | {rf['t_collective_s']:.4f} | "
              f"{rf['dominant']} | {rf['roofline_fraction']:.3f} | "
              f"{useful:.2f} | {lever} |")


def suggest_lever(r) -> str:
    rf = r["roofline"]
    kinds = r["hlo_cost"].get("hbm_by_kind", {})
    if rf["dominant"] == "collective":
        return "SP / comm overlap / int8 grads"
    if rf["dominant"] == "compute":
        return "int8 QAT matmuls (paper) / causal block-skip"
    top = next(iter(kinds), "")
    if r["kind"] == "decode":
        return "unroll decode + bf16/int8 weights&KV"
    if top in ("copy", "transpose"):
        return "layout: fuse transposes (flash kernel)"
    if top == "reduce-window":
        return "flash attention kernel (fuse softmax)"
    return "flash attention kernel / remat policy"


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--label", default="baseline")
    ap.add_argument("--section", choices=["dryrun", "roofline", "both"],
                    default="both")
    args = ap.parse_args()
    if args.section in ("dryrun", "both"):
        dryrun_table(args.label)
    if args.section in ("roofline", "both"):
        roofline_table(args.label)


if __name__ == "__main__":
    main()
