"""Quickstart: the paper's pipeline end to end in ~a minute on CPU.

1. simulate MRF fingerprints (Bloch/EPG, SNR+phase augmentation)
2. train the FPGA-adapted net with QAT (software reference path)
3. export the full-integer network and evaluate paper Table-1 metrics
4. run the SAME integer network through the Pallas int8 kernel path and
   check bit-exactness (the paper's FPGA-vs-Python criterion)

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import jax
import jax.numpy as jnp

from repro.core import qat
from repro.core.train_loop import TrainConfig, evaluate, train
from repro.data.epg import default_sequence, simulate_fingerprints
from repro.kernels.qat_dense.ops import int_forward_pallas


def main():
    print("=== 1. simulate fingerprints ===")
    seq = default_sequence(n_frames=32)
    t1 = jnp.array([800.0, 1400.0, 300.0])   # ms — GM/WM/fat-ish
    t2 = jnp.array([80.0, 110.0, 50.0])
    sig = simulate_fingerprints(seq, t1, t2)
    print(f"fingerprints {sig.shape} {sig.dtype}; |s|_2 = "
          f"{jnp.linalg.norm(sig, axis=-1)}")

    print("\n=== 2. QAT training (scaled schedule) ===")
    cfg = TrainConfig(n_frames=32, steps=300, qat=True, lr=1e-3,
                      batch_size=256, log_every=100)
    params, qstate, info = train(cfg)
    print(f"trained {info['sizes']} in {info['wall_seconds']:.1f}s")

    print("\n=== 3. full-integer export + Table-1 metrics ===")
    ints = qat.export_int8(params, qstate)
    m = evaluate(params, seq, int_layers=ints, n=2000)
    for p in ("T1", "T2"):
        print(f"  {p}: MAPE {m[p]['MAPE_%']:.2f}%  MPE {m[p]['MPE_%']:+.2f}%  "
              f"RMSE {m[p]['RMSE_ms']:.0f} ms")

    print("\n=== 4. Pallas int8 path bit-exactness ===")
    x = jax.random.normal(jax.random.PRNGKey(0), (64, 64))
    y_sw = qat.int_forward(ints, x)
    y_pl = int_forward_pallas(ints, x)
    print(f"  software == Pallas kernel: {bool(jnp.array_equal(y_sw, y_pl))}")


if __name__ == "__main__":
    main()
