"""The paper's end use-case: reconstruct T1/T2 *maps* from MRF signals —
as a thin client of the batched serving engine (``repro.serve.recon``).

Trains the adapted QAT net, exports it to the servable full-integer artifact
(save -> load round-trip, the deployment unit), simulates the phantom
acquisition, and submits the slice as a request to the int8 engine — the
same engine ``python -m repro.launch.serve --arch mrf-fpga`` runs in
production.  Denormalization and map re-assembly live inside the engine
(``data.pipeline.denormalize_targets``), not here.

Run:  PYTHONPATH=src python examples/phantom_recon.py
"""

import tempfile

import jax

from repro.core import qat
from repro.core.train_loop import TrainConfig, train
from repro.data.epg import default_sequence
from repro.data.phantom import acquire_slice, make_phantom, tissue_errors
from repro.serve.recon import ReconEngine, ReconRequest


def main():
    print("=== train adapted QAT net (scaled schedule) ===")
    cfg = TrainConfig(n_frames=32, steps=600, qat=True, lr=1e-3,
                      batch_size=256, log_every=200)
    params, qstate, _ = train(cfg)

    print("\n=== export -> save -> load the servable int8 artifact ===")
    ints = qat.export_int8(params, qstate)
    with tempfile.TemporaryDirectory(prefix="mrf_artifact_") as tmp:
        path = qat.save_int8_artifact(f"{tmp}/mrf_int8", ints)
        served = qat.load_int8_artifact(path)
        print(f"  artifact: {path.name}")

        print("\n=== simulate phantom acquisition ===")
        n = 32
        t1_map, t2_map, mask = make_phantom(n)
        seq = default_sequence(32)
        feats, msk = acquire_slice(seq, t1_map, t2_map, mask, snr=25.0,
                                   key=jax.random.PRNGKey(0))
        print(f"  {int(msk.sum())} voxels, {feats.shape[1]} features each")

        print("\n=== reconstruct through the int8 serving engine ===")
        engine = ReconEngine(backend="int8", int_layers=served)
        request = ReconRequest(features=feats, mask=msk, request_id="phantom")
        engine.reconstruct([request])  # warmup wave: compile, don't time
        result, = engine.reconstruct([request])
        wave = engine.last_wave
        print(f"  {wave['voxels_per_s']:.0f} voxels/s  "
              f"latency {result.latency_s*1e3:.1f} ms")

    for name, e in tissue_errors(result.t1_ms, result.t2_ms,
                                 t1_map, mask).items():
        print(f"  {name:6s}: T1 err {e['T1_err_%']:5.1f}%   "
              f"T2 err {e['T2_err_%']:5.1f}%")

    # coarse ASCII render of the T1 map (the paper's Fig-style output)
    print("\nreconstructed T1 map (ms / 100):")
    for row in result.t1_ms[::2]:
        print("  " + "".join(f"{int(v/100):2d}" if v > 50 else " ."
                             for v in row[::2]))


if __name__ == "__main__":
    main()
