"""The paper's end use-case: reconstruct T1/T2 *maps* from MRF signals.

Builds a synthetic 2D brain phantom (CSF / grey / white matter regions),
simulates the MRF acquisition per voxel (with noise), trains the adapted QAT
net, exports it to full-integer form, and reconstructs the parameter maps
voxel-by-voxel through the **Pallas int8 kernel path** — the deployment
pipeline the paper targets inside the scanner.

Run:  PYTHONPATH=src python examples/phantom_recon.py
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import qat
from repro.core.train_loop import TrainConfig, train
from repro.data.epg import augment, default_sequence, simulate_fingerprints, to_features
from repro.data.pipeline import T1_RANGE_MS, T2_RANGE_MS
from repro.kernels.qat_dense.ops import int_forward_pallas

# tissue classes: (T1 ms, T2 ms) at 3T-ish values
TISSUES = {"background": (0.0, 0.0), "csf": (3500.0, 450.0),
           "grey": (1400.0, 110.0), "white": (800.0, 80.0)}


def make_phantom(n: int = 32):
    """Concentric-ellipse phantom; returns (t1_map, t2_map, mask) (n, n)."""
    yy, xx = np.mgrid[0:n, 0:n]
    cy = cx = (n - 1) / 2
    r2 = ((yy - cy) / (n * 0.45)) ** 2 + ((xx - cx) / (n * 0.38)) ** 2
    t1 = np.zeros((n, n)); t2 = np.zeros((n, n))
    for name, r_out in (("white", 1.0), ("grey", 0.55), ("csf", 0.18)):
        m = r2 <= r_out
        t1[m], t2[m] = TISSUES[name]
    mask = r2 <= 1.0
    return t1, t2, mask


def main():
    print("=== train adapted QAT net (scaled schedule) ===")
    cfg = TrainConfig(n_frames=32, steps=600, qat=True, lr=1e-3,
                      batch_size=256, log_every=200)
    params, qstate, _ = train(cfg)
    ints = qat.export_int8(params, qstate)

    print("\n=== simulate phantom acquisition ===")
    n = 32
    t1, t2, mask = make_phantom(n)
    seq = default_sequence(32)
    vox = mask.reshape(-1)
    sig = simulate_fingerprints(seq, jnp.asarray(t1.reshape(-1)[vox]),
                                jnp.asarray(t2.reshape(-1)[vox]))
    sig = augment(jax.random.PRNGKey(0), sig, snr_range=(25.0, 25.0))
    x = to_features(sig)
    print(f"  {int(vox.sum())} voxels, {x.shape[1]} features each")

    print("\n=== reconstruct maps through the int8 Pallas path ===")
    pred = np.asarray(int_forward_pallas(ints, x))
    t1_hat = np.zeros(n * n); t2_hat = np.zeros(n * n)
    t1_hat[vox] = pred[:, 0] * T1_RANGE_MS[1]
    t2_hat[vox] = pred[:, 1] * T2_RANGE_MS[1]
    t1_hat = t1_hat.reshape(n, n); t2_hat = t2_hat.reshape(n, n)

    for name, (ref1, ref2) in list(TISSUES.items())[1:]:
        m = (t1 == ref1) & mask
        e1 = np.mean(np.abs(t1_hat[m] - ref1)) / ref1 * 100
        e2 = np.mean(np.abs(t2_hat[m] - ref2)) / ref2 * 100
        print(f"  {name:6s}: T1 err {e1:5.1f}%   T2 err {e2:5.1f}%")

    # coarse ASCII render of the T1 map (the paper's Fig-style output)
    print("\nreconstructed T1 map (ms / 100):")
    for row in t1_hat[::2]:
        print("  " + "".join(f"{int(v/100):2d}" if v > 50 else " ." for v in row[::2]))


if __name__ == "__main__":
    main()
