"""The paper's end use-case: reconstruct T1/T2 *maps* from MRF signals —
as a thin client of the pipelined serving stack (``repro.serve.recon``).

Trains the adapted QAT net, exports it to the servable full-integer artifact
(save -> load round-trip, the deployment unit), simulates the phantom
acquisition slice by slice, and *streams* each slice into the engine's
persistent request queue as it is acquired — ``enqueue`` admits it (timing
starts here), ``poll`` dispatches due waves mid-scan, ``drain`` flushes the
rest through the double-buffered wave executor.  This is the same stack
``python -m repro.launch.serve --arch mrf-fpga --serve-mode pipelined``
runs in production.  Denormalization and map re-assembly live inside the
engine (``data.pipeline.denormalize_targets``), not here.

Run:  PYTHONPATH=src python examples/phantom_recon.py
"""

import tempfile

import jax

from repro.core import qat
from repro.core.train_loop import TrainConfig, train
from repro.data.epg import default_sequence
from repro.data.phantom import acquire_slice, make_phantom, tissue_errors
from repro.serve.queue import RequestState
from repro.serve.recon import ReconEngine, ReconRequest

N_SLICES = 4


def main():
    print("=== train adapted QAT net (scaled schedule) ===")
    cfg = TrainConfig(n_frames=32, steps=600, qat=True, lr=1e-3,
                      batch_size=256, log_every=200)
    params, qstate, _ = train(cfg)

    print("\n=== export -> save -> load the servable int8 artifact ===")
    ints = qat.export_int8(params, qstate)
    with tempfile.TemporaryDirectory(prefix="mrf_artifact_") as tmp:
        path = qat.save_int8_artifact(f"{tmp}/mrf_int8", ints)
        served = qat.load_int8_artifact(path)
        print(f"  artifact: {path.name}")

        print(f"\n=== stream {N_SLICES} phantom slices through the "
              f"pipelined int8 engine ===")
        n = 32
        t1_map, t2_map, mask = make_phantom(n)
        seq = default_sequence(32)
        engine = ReconEngine(backend="int8", int_layers=served,
                             mode="pipelined", max_wave_voxels=1024)
        # warmup: trace the bucket shapes outside the streamed scan
        feats0, msk0 = acquire_slice(seq, t1_map, t2_map, mask, snr=25.0,
                                     key=jax.random.PRNGKey(0))
        engine.reconstruct([ReconRequest(features=feats0, mask=msk0)])

        tickets = []
        for i in range(N_SLICES):  # "acquisition": one slice per noise draw
            feats, msk = acquire_slice(seq, t1_map, t2_map, mask, snr=25.0,
                                       key=jax.random.PRNGKey(i))
            tickets.append(engine.enqueue(
                ReconRequest(features=feats, mask=msk,
                             request_id=f"slice-{i}")))
            engine.poll()  # dispatch any wave already due mid-scan
        engine.drain()
        wave = engine.last_wave
        # no voxels/s here: the session wall includes the EPG acquisition
        # simulation between enqueues, which would dwarf the serving time —
        # per-slice latency below is the meaningful serving figure
        print(f"  {wave['total_voxels']} voxels served in "
              f"{wave['n_waves']} waves")
        for t in tickets:
            detail = (f"latency {t.latency_s*1e3:6.1f} ms (from enqueue)"
                      if t.state == RequestState.DONE else t.error)
            print(f"  {t.request.request_id}: {t.state:9s} {detail}")
        done = [t for t in tickets if t.state == RequestState.DONE]
        if len(done) != len(tickets):  # partial failure must not pass as
            raise SystemExit("some slices failed; see states above")  # smoke
        result = done[0].result

    for name, e in tissue_errors(result.t1_ms, result.t2_ms,
                                 t1_map, mask).items():
        print(f"  {name:6s}: T1 err {e['T1_err_%']:5.1f}%   "
              f"T2 err {e['T2_err_%']:5.1f}%")

    # coarse ASCII render of the T1 map (the paper's Fig-style output)
    print("\nreconstructed T1 map (ms / 100):")
    for row in result.t1_ms[::2]:
        print("  " + "".join(f"{int(v/100):2d}" if v > 50 else " ."
                             for v in row[::2]))


if __name__ == "__main__":
    main()
