"""Batched serving demo: prefill a wave of requests, then lockstep decode —
the control flow the decode_32k / long_500k dry-run cells price at scale.

Run:  PYTHONPATH=src python examples/serve_batch.py [--arch mamba2-1.3b]
(mamba2 demonstrates O(1)-state decode — the long_500k story.)
"""

import argparse

from repro.launch.serve import main as serve_main


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mamba2-1.3b")
    ap.add_argument("--requests", type=int, default=8)
    args = ap.parse_args()
    serve_main(["--arch", args.arch, "--smoke",
                "--requests", str(args.requests),
                "--prompt-len", "48", "--gen-len", "24"])


if __name__ == "__main__":
    main()
