"""Train a reduced-config LM from the architecture zoo for a few hundred
steps on CPU, under the full fault-tolerant runner (async checkpoints,
resume, straggler watchdog) — including a mid-run injected crash to
demonstrate recovery.

Run:  PYTHONPATH=src python examples/lm_train_smoke.py [--arch tinyllama-1.1b]
"""

import argparse
import tempfile

from repro.launch.train import main as train_main


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--quant", default=None, choices=[None, "qat-int8"])
    args = ap.parse_args()

    with tempfile.TemporaryDirectory() as d:
        argv = ["--arch", args.arch, "--smoke", "--steps", str(args.steps),
                "--batch", "8", "--seq", "128", "--ckpt-dir", d,
                "--ckpt-every", "50",
                "--inject-fault-at", str(args.steps // 2)]
        if args.quant:
            argv += ["--quant", args.quant]
        print(f"training {args.arch} (smoke) with a crash injected at step "
              f"{args.steps // 2} — the runner must recover from the "
              f"checkpoint:")
        train_main(argv)


if __name__ == "__main__":
    main()
