"""End-to-end driver for the paper's contribution: ON-ACCELERATOR training of
the MRF reconstruction net with the fused Pallas kernel (weights resident in
VMEM, samples streaming through), in both the paper-faithful per-sample SGD
mode and the MXU-native minibatch mode — then the Eq. 3 cost-model comparison.

Run:  PYTHONPATH=src python examples/mrf_fpga_train.py [--steps 300]
"""

import argparse
import time

import jax
import jax.numpy as jnp

from repro.core import fpga_cost_model as fcm
from repro.core import mrf_net
from repro.core.metrics import table1_metrics
from repro.data.epg import default_sequence
from repro.data.pipeline import (MRFSampleStream, T1_RANGE_MS, T2_RANGE_MS,
                                 make_eval_set, sample_batch)
from repro.kernels.fused_train import ops as ft_ops


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=512)
    ap.add_argument("--lr", type=float, default=2e-2)  # plain SGD (the paper's FPGA rule) needs a hotter lr than Adam
    ap.add_argument("--mode", choices=["minibatch", "stream"],
                    default="minibatch",
                    help="stream = paper-faithful per-sample SGD (slow on "
                         "CPU interpret mode); minibatch = MXU-native")
    args = ap.parse_args()

    seq = default_sequence(32)
    stream = MRFSampleStream(seq=seq, batch_size=args.batch)
    sizes = mrf_net.layer_sizes(32)
    params = mrf_net.init_params(jax.random.PRNGKey(0), sizes)
    tile = 1 if args.mode == "stream" else 128

    print(f"fused on-accelerator training: {args.mode} mode, "
          f"{args.steps} x {args.batch} samples, net {sizes}")
    key = jax.random.PRNGKey(1)
    t0 = time.perf_counter()
    for step in range(args.steps):
        x, y = sample_batch(stream, jax.random.fold_in(key, step))
        params, losses = ft_ops.fused_train_step(params, x, y, lr=args.lr,
                                                 tile_batch=tile)
        if step % 50 == 0 or step == args.steps - 1:
            print(f"  step {step:4d}  loss {float(losses[-1]):.6f}")
    wall = time.perf_counter() - t0
    n_samples = args.steps * args.batch

    x, y = make_eval_set(seq, n=2000)
    pred = mrf_net.forward(params, x)
    scale = jnp.array([T1_RANGE_MS[1], T2_RANGE_MS[1]])
    m = table1_metrics(pred * scale, y * scale)
    for p in ("T1", "T2"):
        print(f"  {p}: MAPE {m[p]['MAPE_%']:.2f}%  RMSE {m[p]['RMSE_ms']:.0f} ms")

    print("\n=== Eq. 3 comparison (250M samples) ===")
    print(f"  paper FPGA (200 MHz, 160 cyc/sample): "
          f"{fcm.paper_eq3_seconds():.0f} s")
    print(f"  our cycle model of the same design:  "
          f"{fcm.train_seconds(sizes, 250_000_000):.0f} s")
    tpu = fcm.tpu_train_seconds(sizes, 250_000_000, chips=1, int8=True)
    print(f"  one TPU v5e chip, fused kernel:      {tpu['t_total_s']:.1f} s "
          f"({tpu['bound']}-bound)")
    print(f"  this run (CPU interpret mode):       "
          f"{wall / n_samples * 250_000_000:.0f} s extrapolated")


if __name__ == "__main__":
    main()
