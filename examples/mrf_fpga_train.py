"""End-to-end driver for the paper's contribution: ON-ACCELERATOR training of
the MRF reconstruction net with the fused Pallas kernel (weights resident in
VMEM, samples streaming through), in both the paper-faithful per-sample SGD
mode and the MXU-native minibatch mode — then the Eq. 3 cost-model comparison.

The loop itself is the unified engine (repro.train.engine -> ft.runner): the
same checkpointed, fault-tolerant runner the LM zoo trains under, with the
``fused-pallas`` backend selected.

Run:  PYTHONPATH=src python examples/mrf_fpga_train.py [--steps 300]
"""

import argparse
import tempfile

import jax

from repro.configs import get_config
from repro.core import fpga_cost_model as fcm
from repro.core import mrf_net
from repro.core.metrics import table1_metrics_normalized
from repro.data.pipeline import make_eval_set
from repro.ft.runner import RunnerConfig
from repro.models import registry
from repro.train import engine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=512)
    ap.add_argument("--lr", type=float, default=2e-2)  # plain SGD (the paper's FPGA rule) needs a hotter lr than Adam
    ap.add_argument("--mode", choices=["minibatch", "stream"],
                    default="minibatch",
                    help="stream = paper-faithful per-sample SGD (slow on "
                         "CPU interpret mode); minibatch = MXU-native")
    ap.add_argument("--chunk-steps", type=int, default=1,
                    help=">1: lax.scan chunk per dispatch with in-scan batch "
                         "synthesis (bit-identical; cuts host dispatch "
                         "overhead, the fair setting for the Eq. 3 "
                         "extrapolation)")
    args = ap.parse_args()

    cfg = get_config("mrf-fpga")
    fns = registry.build(cfg)
    sizes = mrf_net.layer_sizes(cfg.mrf_n_frames, cfg.mrf_hidden)
    stream = engine.default_stream(cfg, args.batch)
    tile = 1 if args.mode == "stream" else 128

    print(f"fused on-accelerator training: {args.mode} mode, "
          f"{args.steps} x {args.batch} samples, net {sizes}")
    ecfg = engine.EngineConfig(backend="fused-pallas", lr=args.lr,
                               optimizer="sgd", tile_batch=tile,
                               chunk_steps=args.chunk_steps)

    def log(step, metrics, dt):
        if (step - 1) % 50 == 0 or step == args.steps:
            print(f"  step {step - 1:4d}  loss {float(metrics['loss']):.6f}")

    with tempfile.TemporaryDirectory(prefix="mrf_fused_") as ckpt_dir:
        rcfg = RunnerConfig(total_steps=args.steps, ckpt_dir=ckpt_dir,
                            ckpt_every=max(args.steps // 3, 1))
        state, _, info = engine.train(
            fns, ecfg, rcfg, stream=stream, data_key=jax.random.PRNGKey(1),
            init_key=jax.random.PRNGKey(0), batch_size=args.batch,
            on_metrics=log)
    wall = info["wall_seconds"]
    n_samples = args.steps * args.batch

    x, y = make_eval_set(stream.seq, n=2000)
    pred = mrf_net.forward(state.params, x)
    m = table1_metrics_normalized(pred, y)
    for p in ("T1", "T2"):
        print(f"  {p}: MAPE {m[p]['MAPE_%']:.2f}%  RMSE {m[p]['RMSE_ms']:.0f} ms")

    print("\n=== Eq. 3 comparison (250M samples) ===")
    print(f"  paper FPGA (200 MHz, 160 cyc/sample): "
          f"{fcm.paper_eq3_seconds():.0f} s")
    print(f"  our cycle model of the same design:  "
          f"{fcm.train_seconds(sizes, 250_000_000):.0f} s")
    tpu = fcm.tpu_train_seconds(sizes, 250_000_000, chips=1, int8=True)
    print(f"  one TPU v5e chip, fused kernel:      {tpu['t_total_s']:.1f} s "
          f"({tpu['bound']}-bound)")
    print(f"  this run (CPU interpret mode):       "
          f"{wall / n_samples * 250_000_000:.0f} s extrapolated")


if __name__ == "__main__":
    main()
