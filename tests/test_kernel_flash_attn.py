"""Flash attention kernel vs naive oracle: causal, sliding-window, GQA
grouping, padding, block-size sweeps (hypothesis)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_fallback import given, settings, strategies as st

from repro.kernels.flash_attn.ops import flash_attention
from repro.kernels.flash_attn.ref import ref_attention

jax.config.update("jax_platform_name", "cpu")


def _case(b, s, hq, hkv, dh, seed=0, dtype=jnp.float32):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(ks[0], (b, s, hq, dh), dtype)
    k = jax.random.normal(ks[1], (b, s, hkv, dh), dtype)
    v = jax.random.normal(ks[2], (b, s, hkv, dh), dtype)
    return q, k, v


@pytest.mark.parametrize("hq,hkv", [(4, 4), (4, 2), (6, 2)])
@pytest.mark.parametrize("causal", [True, False])
def test_matches_oracle(hq, hkv, causal):
    q, k, v = _case(2, 64, hq, hkv, 16)
    got = flash_attention(q, k, v, causal=causal, block_q=16, block_k=16)
    want = ref_attention(q, k, v, causal=causal)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-5)


@pytest.mark.parametrize("window", [8, 24])
def test_sliding_window(window):
    q, k, v = _case(1, 96, 4, 2, 8, seed=1)
    got = flash_attention(q, k, v, causal=True, window=window,
                          block_q=16, block_k=16)
    want = ref_attention(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-5)


def test_ragged_seq_padding():
    q, k, v = _case(1, 50, 2, 2, 8, seed=2)  # not a block multiple
    got = flash_attention(q, k, v, causal=True, block_q=16, block_k=16)
    want = ref_attention(q, k, v, causal=True)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-5)


@settings(max_examples=8, deadline=None)
@given(s=st.integers(8, 96), hkv=st.sampled_from([1, 2]),
       g=st.sampled_from([1, 2, 3]), dh=st.sampled_from([8, 16]),
       bq=st.sampled_from([8, 16, 32]), seed=st.integers(0, 2**16))
def test_property_shapes(s, hkv, g, dh, bq, seed):
    q, k, v = _case(1, s, hkv * g, hkv, dh, seed=seed)
    got = flash_attention(q, k, v, causal=True, block_q=bq, block_k=bq)
    want = ref_attention(q, k, v, causal=True)
    np.testing.assert_allclose(got, want, rtol=5e-4, atol=5e-5)


def test_bf16_io():
    q, k, v = _case(1, 64, 4, 2, 16, seed=3, dtype=jnp.bfloat16)
    got = flash_attention(q, k, v, causal=True, block_q=16, block_k=16)
    want = ref_attention(q, k, v, causal=True)
    assert got.dtype == jnp.bfloat16
    np.testing.assert_allclose(got.astype(np.float32),
                               want.astype(np.float32), rtol=2e-2, atol=2e-2)
