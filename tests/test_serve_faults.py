"""Chaos suite for the overload-hardened serving stack.

Covers the robustness layer end to end with *deterministic* fault
schedules (``serve.faults``): the solo-retry blast-radius fix, the int8
circuit breaker's bit-exact fused->lax degradation, the wave watchdog,
admission control / load shedding (``serve.admission``), the adaptive
pipelining controller, and the lifecycle properties the whole stack must
keep under any schedule — every admitted ticket terminates in exactly one
of done/failed/shed, drain() terminates, and degraded results are
bit-identical to healthy ones.

Runs on the int8 backend wherever results are compared: integer
arithmetic is composition-invariant, so "bit-identical" is exact equality
even when retries reshuffle requests into different waves/buckets.
"""

import dataclasses
import random

import jax
import numpy as np
import pytest

from _serve_helpers import calibrated_net as _calibrated_net, \
    features as _features

from repro.ft.straggler import Ewma
from repro.serve.admission import (AdaptiveController, AdmissionPolicy,
                                   ShedReason)
from repro.serve.faults import (FAULT_KINDS, FaultInjector, FaultSpec,
                                InjectedServeFault, WaveTimeout)
from repro.serve.queue import RequestQueue, RequestState
from repro.serve.recon import ReconEngine, ReconRequest

jax.config.update("jax_platform_name", "cpu")


@pytest.fixture(scope="module")
def ints():
    _, _, layers = _calibrated_net()
    return layers


@pytest.fixture(scope="module")
def ref(ints):
    """Healthy fault-free reference engine: the bit-exactness oracle.

    Deliberately an *engine* (jitted lax forward), not eager
    ``qat.int_forward``: XLA's fusion of the input quantization can flip
    ``round`` on exact .5 ties vs the eager path, so "bit-identical under
    faults" is defined against fault-free serving — the actual property —
    not against a differently-compiled forward.
    """
    return ReconEngine(backend="int8", int_layers=ints, int8_impl="lax")


def _want_maps(ref_engine, feats):
    """Fault-free (n, 2) ms maps for one request's features."""
    res, = ref_engine.reconstruct([ReconRequest(features=feats)])
    return res.t1_ms, res.t2_ms


def _engine(layers, **kw):
    kw.setdefault("int8_impl", "lax")
    return ReconEngine(backend="int8", int_layers=layers, **kw)


def _reqs(sizes, prefix="r"):
    return [ReconRequest(features=_features(n, seed=100 + i),
                         request_id=f"{prefix}{i}")
            for i, n in enumerate(sizes)]


def _assert_done_bitexact(ticket, ref_engine):
    assert ticket.state == RequestState.DONE
    assert ticket.error is None and ticket.result is not None
    t1, t2 = _want_maps(ref_engine, ticket.request.features)
    assert np.array_equal(ticket.result.t1_ms, t1)
    assert np.array_equal(ticket.result.t2_ms, t2)


# --------------------------------------------------------------------------
# FaultSpec / FaultInjector unit behaviour
# --------------------------------------------------------------------------

def test_faultspec_validation():
    with pytest.raises(ValueError, match="not in"):
        FaultSpec(kind="nope", wave=0)
    with pytest.raises(ValueError, match="exactly one"):
        FaultSpec(kind="dispatch_raise")
    with pytest.raises(ValueError, match="exactly one"):
        FaultSpec(kind="dispatch_raise", wave=0, request_id="r")
    with pytest.raises(ValueError, match="wave="):
        FaultSpec(kind="kernel_fail", request_id="r")


def test_injector_one_shot_vs_persistent():
    inj = FaultInjector([FaultSpec(kind="dispatch_raise", wave=1),
                         {"kind": "dispatch_raise", "request_id": "bad"}])
    assert inj.n_armed() == 2
    inj.fire_dispatch(0, ["a"])  # wave 1 spec doesn't fire at wave 0
    with pytest.raises(InjectedServeFault):
        inj.fire_dispatch(1, ["a"])
    assert inj.n_armed() == 1  # wave spec is one-shot
    inj.fire_dispatch(1, ["a"])  # already disarmed
    for w in (2, 3):  # request_id spec re-fires on every wave with "bad"
        with pytest.raises(InjectedServeFault, match="bad"):
            inj.fire_dispatch(w, ["bad", "a"])
    assert inj.n_armed() == 1
    assert inj.fired == [(1, "dispatch_raise"), (2, "dispatch_raise"),
                         (3, "dispatch_raise")]


def test_injector_wait_point():
    inj = FaultInjector([FaultSpec(kind="tile_timeout", wave=0),
                         FaultSpec(kind="slow_wave", wave=1, delay_s=2.5)])
    with pytest.raises(WaveTimeout):
        inj.fire_wait(0)
    spec = inj.fire_wait(1)
    assert spec is not None and spec.delay_s == 2.5
    assert inj.fire_wait(2) is None


# --------------------------------------------------------------------------
# blast radius: solo retry (the satellite-2 regression tests)
# --------------------------------------------------------------------------

def test_transient_dispatch_fault_spares_wave_mates(ints, ref):
    """Regression: one crashing dispatch used to fail every wave-mate.
    Now a transient fault costs each mate one solo retry — all succeed."""
    eng = _engine(ints, injector=FaultInjector(
        [FaultSpec(kind="dispatch_raise", wave=0)]))
    tickets = [eng.enqueue(r) for r in _reqs([40, 50, 60])]
    eng.drain()
    for t in tickets:
        _assert_done_bitexact(t, ref)
    # every mate retried exactly once, each in its own solo wave
    assert eng.last_wave["n_retries"] == 3
    assert eng.last_wave["n_waves"] == 3
    assert eng.last_wave["n_failed"] == 0
    assert eng.n_retries_total == 3


def test_poisoned_request_fails_alone(ints, ref):
    """A persistent (request-keyed) fault exhausts its bounded retry and
    fails — alone; wave-mates survive via their solo retries."""
    eng = _engine(ints, max_retries=1, injector=FaultInjector(
        [FaultSpec(kind="dispatch_raise", request_id="p1")]))
    tickets = [eng.enqueue(r) for r in _reqs([40, 50, 60], prefix="p")]
    eng.drain()
    good0, bad, good2 = tickets
    _assert_done_bitexact(good0, ref)
    _assert_done_bitexact(good2, ref)
    assert bad.state == RequestState.FAILED
    assert "after retry" in bad.error and "p1" in bad.error
    assert bad.result is None
    assert eng.last_wave["n_failed"] == 1


def test_zero_retries_restores_fail_the_wave(ints):
    eng = _engine(ints, max_retries=0, injector=FaultInjector(
        [FaultSpec(kind="dispatch_raise", wave=0)]))
    tickets = [eng.enqueue(r) for r in _reqs([40, 50])]
    eng.drain()
    assert all(t.state == RequestState.FAILED for t in tickets)
    assert eng.n_retries_total == 0


def test_timeout_retries_without_tripping_breaker(ints, ref):
    """An injected wave timeout is an infra fault, not a kernel bug: the
    wave retries solo and the circuit breaker must NOT trip."""
    eng = _engine(ints, injector=FaultInjector(
        [FaultSpec(kind="tile_timeout", wave=0)]))
    tickets = [eng.enqueue(r) for r in _reqs([40, 50])]
    eng.drain()
    for t in tickets:
        _assert_done_bitexact(t, ref)
    h = eng.health()
    assert not h["degraded"]
    assert h["n_kernel_failures"] == 0
    assert h["n_retries_total"] == 2


def test_assembly_corrupt_fails_only_that_request(ints, ref):
    eng = _engine(ints, injector=FaultInjector(
        [FaultSpec(kind="assembly_corrupt", request_id="a1")]))
    tickets = [eng.enqueue(r) for r in _reqs([40, 50, 60], prefix="a")]
    eng.drain()
    _assert_done_bitexact(tickets[0], ref)
    _assert_done_bitexact(tickets[2], ref)
    assert tickets[1].state == RequestState.FAILED
    assert "a1" in tickets[1].error


def test_solo_retry_preserves_latency_accounting(ints):
    """Requeue keeps enqueue_t: a retried request's latency still spans
    from original admission, not from the retry."""
    t_now = [0.0]
    eng = _engine(ints, clock=lambda: t_now[0], injector=FaultInjector(
        [FaultSpec(kind="dispatch_raise", wave=0)]))
    ticket = eng.enqueue(_reqs([40])[0])
    t_now[0] = 3.0
    eng.drain()
    assert ticket.state == RequestState.DONE
    assert ticket.latency_s >= 3.0


# --------------------------------------------------------------------------
# circuit breaker: fused -> lax degradation, bit-exact
# --------------------------------------------------------------------------

def test_kernel_fail_trips_breaker_and_serves_degraded(ints, ref):
    eng = ReconEngine(backend="int8", int_layers=ints, int8_impl="fused",
                      injector=FaultInjector(
                          [FaultSpec(kind="kernel_fail", wave=0)]))
    tickets = [eng.enqueue(r) for r in _reqs([40, 333])]
    eng.drain()
    # the wave completes (failing tile re-enqueued degraded): no retries
    for t in tickets:
        _assert_done_bitexact(t, ref)
    h = eng.health()
    assert h["degraded"] and h["int8_impl"] == "lax"
    assert "bit-exact" in h["degraded_reason"]
    assert h["n_kernel_failures"] == 1 and h["n_degraded_waves"] >= 1
    assert h["n_retries_total"] == 0
    assert eng.last_wave["degraded"]
    # the engine keeps serving after the trip, still bit-exact
    res, = eng.reconstruct([ReconRequest(features=_features(70, seed=9),
                                         request_id="after")])
    t1_want, _ = _want_maps(ref, _features(70, seed=9))
    assert np.array_equal(res.t1_ms, t1_want)
    assert eng.health()["n_degraded_waves"] >= 2


def test_kernel_fail_without_fallback_uses_retry_path(ints, ref):
    """No fallback exists for the lax impl: a kernel failure propagates
    into the engine's bounded solo retry instead of degrading."""
    eng = _engine(ints, injector=FaultInjector(
        [FaultSpec(kind="kernel_fail", wave=0)]))
    tickets = [eng.enqueue(r) for r in _reqs([40, 50])]
    eng.drain()
    for t in tickets:
        _assert_done_bitexact(t, ref)
    h = eng.health()
    assert not h["degraded"]
    assert h["n_kernel_failures"] == 1
    assert h["n_retries_total"] == 2


# --------------------------------------------------------------------------
# watchdog + adaptive pipelining
# --------------------------------------------------------------------------

def test_wave_timeout_watchdog_counts_slow_waves(ints):
    eng = _engine(ints, wave_timeout_s=1e-9)  # everything is a stall
    eng.reconstruct([ReconRequest(features=_features(40, seed=1))])
    assert eng.n_slow_waves >= 1


def test_injected_slow_wave_shrinks_cap_and_depth(ints):
    ctrl = AdaptiveController(depth=2, wave_voxels=1024,
                              target_wave_ms=None)
    eng = _engine(ints, mode="pipelined", max_wave_voxels=1024,
                  adaptive=ctrl,
                  injector=FaultInjector(
                      [FaultSpec(kind="slow_wave", wave=0, delay_s=10.0)]))
    ticket = eng.enqueue(_reqs([200])[0])
    eng.drain()
    assert ticket.state == RequestState.DONE
    assert eng.n_slow_waves == 1
    # the synthetic 10s stall dwarfs staging: depth shrinks; cap halves
    h = eng.health()
    assert h["inflight_depth"] == 1
    assert h["max_wave_voxels"] == 512
    assert eng.queue.max_wave_voxels == 512


def test_adaptive_requires_pipelined(ints):
    with pytest.raises(ValueError, match="pipelined"):
        _engine(ints, mode="sync", adaptive=True)


def test_adaptive_controller_depth_rules():
    c = AdaptiveController(min_depth=1, max_depth=4, depth=2,
                           target_wave_ms=None)
    for _ in range(6):  # staging dominates compute -> grow to max, stay
        d, _cap = c.observe(staging_s=1.0, compute_s=1.0, n_voxels=128)
    assert d == 4
    for _ in range(12):  # staging hidden -> shrink to min, stay
        d, _cap = c.observe(staging_s=0.0, compute_s=1.0, n_voxels=128)
    assert d == 1


def test_adaptive_controller_cap_sizing_and_stall():
    c = AdaptiveController(target_wave_ms=50.0, min_wave_voxels=128,
                           max_wave_voxels=4096)
    # observed 10k voxels/s -> 50ms wave = 500 voxels -> lane-snapped 384
    _, cap = c.observe(staging_s=0.0, compute_s=0.1, n_voxels=1000)
    assert cap == 384
    # a stall halves instead of resizing; stays lane-snapped + clamped
    _, cap = c.observe(staging_s=0.0, compute_s=0.1, n_voxels=1000,
                       stalled=True)
    assert cap == 128  # 384 // 2 = 192 -> lane floor 128
    # clamping: a huge rate cannot exceed max_wave_voxels
    for _ in range(8):
        _, cap = c.observe(staging_s=0.0, compute_s=0.001, n_voxels=10**6)
    assert cap == 4096


def test_adaptive_controller_validates_bounds():
    with pytest.raises(ValueError, match="min_depth"):
        AdaptiveController(min_depth=0)
    with pytest.raises(ValueError, match="min_depth"):
        AdaptiveController(min_depth=3, max_depth=2)
    with pytest.raises(ValueError, match="wave_voxels"):
        AdaptiveController(min_wave_voxels=512, max_wave_voxels=128)


def test_ewma_shared_primitive():
    e = Ewma(alpha=0.5)
    assert e.update(10.0) == 10.0          # first sample seeds the value
    assert e.update(20.0) == 15.0          # 0.5*10 + 0.5*20
    assert e.update(15.0, alpha=0.0) == 15.0  # per-call override


# --------------------------------------------------------------------------
# admission control / load shedding
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class FakeReq:
    n_voxels: int
    request_id: str = ""


def test_queue_full_shed():
    q = RequestQueue(admission=AdmissionPolicy(max_pending_voxels=150,
                                               displace=False))
    t1 = q.submit(FakeReq(100))
    t2 = q.submit(FakeReq(100))
    t3 = q.submit(FakeReq(50))  # 100 + 50 fits the budget exactly
    assert t1.state == RequestState.PENDING
    assert t2.state == RequestState.SHED
    assert t2.shed_reason == ShedReason.QUEUE_FULL
    assert "shed at admission" in t2.error
    assert t3.state == RequestState.PENDING
    assert q.n_shed == 1 and q.pending_voxels() == 150


def test_deadline_shed_abstains_until_rate_known():
    pol = AdmissionPolicy(deadline_ms=50.0)
    q = RequestQueue(admission=pol)
    t1 = q.submit(FakeReq(100))  # no rate estimate yet: admitted
    assert t1.state == RequestState.PENDING
    pol.observe_service(1000, 1.0)  # 1000 voxels/s observed
    # 100 pending voxels -> est wait 100ms > 50ms deadline
    t2 = q.submit(FakeReq(10))
    assert t2.state == RequestState.SHED
    assert t2.shed_reason == ShedReason.DEADLINE
    # per-ticket deadline overrides the policy default
    t3 = q.submit(FakeReq(10), deadline_ms=500.0)
    assert t3.state == RequestState.PENDING


def test_priority_displacement():
    q = RequestQueue(admission=AdmissionPolicy(max_pending_voxels=150))
    low = q.submit(FakeReq(100, "low"), priority=0)
    high = q.submit(FakeReq(100, "high"), priority=1)
    assert high.state == RequestState.PENDING
    assert low.state == RequestState.SHED
    assert low.shed_reason == ShedReason.DISPLACED
    assert q.pending_voxels() == 100
    # equal priority cannot displace: sheds as queue_full instead
    peer = q.submit(FakeReq(100, "peer"), priority=1)
    assert peer.state == RequestState.SHED
    assert peer.shed_reason == ShedReason.QUEUE_FULL


def test_requeue_rejects_non_scheduled():
    q = RequestQueue()
    t = q.submit(FakeReq(10))
    with pytest.raises(ValueError, match="scheduled"):
        q.requeue(t)


def test_engine_shed_accounting_and_reconstruct_raises(ints, ref):
    eng = _engine(ints, admission=AdmissionPolicy(max_pending_voxels=100,
                                                  displace=False))
    r_ok, r_shed = _reqs([80, 80], prefix="s")
    t_ok = eng.enqueue(r_ok)
    t_shed = eng.enqueue(r_shed)
    assert t_shed.state == RequestState.SHED
    eng.drain()
    _assert_done_bitexact(t_ok, ref)
    assert eng.last_wave["n_shed"] == 1
    h = eng.health()
    assert h["n_shed_total"] == 1
    assert h["service_rate_voxels_per_s"] > 0  # fed at wave retire
    # the batch API refuses to half-serve: shed requests raise
    with pytest.raises(ValueError, match="shed"):
        eng.reconstruct(_reqs([80, 80], prefix="b"))


# --------------------------------------------------------------------------
# lifecycle properties under arbitrary schedules (the chaos property)
# --------------------------------------------------------------------------

def _random_schedule(rng, request_ids, n_waves=5):
    sched = []
    for _ in range(rng.randint(0, 4)):
        kind = rng.choice(list(FAULT_KINDS))
        by_wave = (kind in ("kernel_fail", "tile_timeout", "slow_wave")
                   or rng.random() < 0.5)
        if by_wave:
            sched.append(FaultSpec(kind=kind, wave=rng.randrange(n_waves)))
        else:
            sched.append(FaultSpec(kind=kind,
                                   request_id=rng.choice(request_ids)))
    return sched


@pytest.mark.parametrize("seed", range(6))
def test_chaos_every_ticket_terminates_exactly_once(ints, ref, seed):
    """THE property: under any fault schedule, drain() terminates and
    every admitted ticket ends in exactly one terminal state — done
    tickets bit-identical to the healthy reference, failed tickets carry
    errors, shed tickets carry structured reasons.  Nothing is lost,
    nothing is wedged, and the engine stays serviceable afterwards."""
    rng = random.Random(seed)
    sizes = [rng.randint(30, 120) for _ in range(5)]
    reqs = _reqs(sizes, prefix=f"c{seed}_")
    ids = [r.request_id for r in reqs]
    admission = (AdmissionPolicy(max_pending_voxels=rng.choice([200, 10**6]),
                                 displace=rng.random() < 0.5)
                 if rng.random() < 0.5 else None)
    eng = _engine(
        ints,
        mode=rng.choice(["sync", "pipelined"]),
        max_wave_voxels=rng.choice([None, 128]),
        max_retries=1,
        admission=admission,
        injector=FaultInjector(_random_schedule(rng, ids)))
    tickets = [eng.enqueue(r, priority=rng.randint(0, 1)) for r in reqs]
    eng.drain()  # must terminate (retries are bounded)

    by_state = {s: [t for t in tickets if t.state == s]
                for s in RequestState.TERMINAL}
    assert sum(len(v) for v in by_state.values()) == len(tickets), \
        f"non-terminal tickets: {[t.state for t in tickets]}"
    for t in by_state[RequestState.DONE]:
        _assert_done_bitexact(t, ref)
    for t in by_state[RequestState.FAILED]:
        assert t.error and t.result is None
    for t in by_state[RequestState.SHED]:
        assert t.shed_reason in ShedReason.ALL and t.result is None
    if admission is None:
        assert not by_state[RequestState.SHED]
    assert eng.queue.n_pending == 0 and not eng._inflight
    stats = eng.last_wave
    assert stats["n_requests"] == len(by_state[RequestState.DONE])
    assert stats["n_failed"] == len(by_state[RequestState.FAILED])
    assert stats["n_shed"] == len(by_state[RequestState.SHED])
    # the engine is not wedged: a clean request still serves, bit-exact
    after = eng.enqueue(ReconRequest(features=_features(64, seed=7777),
                                     request_id="after"))
    eng.drain()
    if after.state == RequestState.SHED:  # tight chaos budget can shed it
        assert after.shed_reason in ShedReason.ALL
    else:
        _assert_done_bitexact(after, ref)


def test_chaos_streaming_poll_path_terminates(ints, ref):
    """The streaming (enqueue/poll/drain) path upholds the same property
    with faults landing during poll-driven dispatch."""
    eng = _engine(ints, max_wave_voxels=128, max_wait_ms=0.0,
                  mode="pipelined", injector=FaultInjector(
                      [FaultSpec(kind="dispatch_raise", wave=0),
                       FaultSpec(kind="tile_timeout", wave=2)]))
    tickets = []
    for r in _reqs([100, 100, 100, 100], prefix="s"):
        tickets.append(eng.enqueue(r))
        eng.poll()
    eng.drain()
    assert all(t.state in RequestState.TERMINAL for t in tickets)
    done = [t for t in tickets if t.state == RequestState.DONE]
    for t in done:
        _assert_done_bitexact(t, ref)
    assert len(done) == 4  # both faults were transient: everyone lands
