"""Unified-engine tests: the MRF nets registered as first-class archs, the
three backends (float / qat-int8 / fused-pallas) through one
``(state, batch) -> (state, metrics)`` contract, equivalence against the
historical hand-rolled loops, and the launcher end to end."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_smoke
from repro.configs.base import param_count
from repro.core import mrf_net, qat
from repro.core.train_loop import TrainConfig, train
from repro.data.epg import default_sequence
from repro.data.pipeline import MRFSampleStream, sample_batch
from repro.models import registry
from repro.optim import adam, sgd
from repro.train import engine
from repro.train.step import init_train_state

jax.config.update("jax_platform_name", "cpu")


def _params_equal(a, b, atol=0.0):
    for la, lb in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_allclose(np.asarray(la), np.asarray(lb), atol=atol,
                                   rtol=0.0)


# --------------------------------------------------------------------------
# registration: the paper's nets are ordinary archs
# --------------------------------------------------------------------------

def test_mrf_archs_registered():
    for name in ("mrf-fpga", "mrf-original"):
        assert name in ARCHS
        cfg = get_smoke(name)
        assert cfg.family == "mrf"
        fns = registry.build(cfg)
        params = fns.init(jax.random.PRNGKey(0))
        assert param_count(cfg) == mrf_net.param_count(params)
        # the analytic count knows the adapted net is the original minus two
        assert param_count(ARCHS["mrf-original"].CONFIG) > param_count(
            ARCHS["mrf-fpga"].CONFIG)


def test_mrf_prefill_is_inference_and_no_decode():
    cfg = get_smoke("mrf-fpga")
    fns = registry.build(cfg)
    params = fns.init(jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 2 * cfg.mrf_n_frames))
    _, pred = fns.prefill(params, {"x": x})
    assert pred.shape == (4, 2)
    with pytest.raises(NotImplementedError):
        fns.decode(params, None, None, 0)


# --------------------------------------------------------------------------
# backend equivalence vs the historical hand-rolled loops
# --------------------------------------------------------------------------

def test_float_engine_matches_handrolled_adam_loop():
    """train() (engine + ft.runner) must reproduce the pre-refactor loop
    bit-for-bit: same init split, same per-step batch keys, un-clipped Adam."""
    hidden = (32, 16)
    cfg = TrainConfig(n_frames=16, hidden=hidden, steps=8, lr=1e-3,
                      batch_size=32, log_every=100)
    params_e, _, info = train(cfg, verbose=False)

    # the original core/train_loop.train() body, verbatim semantics
    stream = MRFSampleStream(seq=default_sequence(16), batch_size=32)
    sizes = mrf_net.layer_sizes(16, hidden)
    key = jax.random.PRNGKey(0)
    key, k_init = jax.random.split(key)
    params = mrf_net.init_params(k_init, sizes)
    opt = adam(1e-3)
    opt_state = opt.init(params)

    @jax.jit
    def step(params, opt_state, x, y):
        loss, grads = jax.value_and_grad(mrf_net.mse_loss)(params, x, y)
        params, opt_state = opt.update(grads, opt_state, params)
        return params, opt_state, loss

    for i in range(8):
        x, y = sample_batch(stream, jax.random.fold_in(key, i))
        params, opt_state, loss = step(params, opt_state, x, y)

    _params_equal(params_e, params)
    assert info["sizes"] == sizes


def test_qat_engine_step_matches_handrolled_qat_step():
    """One qat-int8 engine step == the pre-refactor QAT step, exactly:
    has_aux value_and_grad over the fake-quant forward, then Adam."""
    cfg = get_smoke("mrf-fpga")
    fns = registry.build(cfg)
    params = fns.init(jax.random.PRNGKey(0))
    qstate = qat.init_qat_state(len(params))
    opt = adam(1e-3)
    stream = MRFSampleStream(seq=default_sequence(cfg.mrf_n_frames),
                             batch_size=32)
    x, y = sample_batch(stream, jax.random.PRNGKey(5))

    def loss_fn(params, qstate, x, y):
        pred, new_qstate = qat.forward_qat(params, qstate, x, train=True)
        return jnp.mean(jnp.square(pred - y)), new_qstate

    # the pre-refactor core.train_loop QAT step, verbatim (incl. the jit)
    @jax.jit
    def ref_step(params, qstate, opt_state, x, y):
        (loss, new_qstate), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params, qstate, x, y)
        params, opt_state = opt.update(grads, opt_state, params)
        return params, new_qstate, opt_state, loss

    params_r, new_qstate_r, _, loss_r = ref_step(params, qstate,
                                                 opt.init(params), x, y)

    step_fn, _ = engine.build(fns, engine.EngineConfig(
        backend="qat-int8", lr=1e-3, max_grad_norm=None, donate=False))
    state = init_train_state(params, opt, aux=qstate)
    new_state, metrics = step_fn(state, {"x": x, "y": y})

    np.testing.assert_array_equal(np.asarray(metrics["loss"]),
                                  np.asarray(loss_r))
    _params_equal(new_state.params, params_r)
    np.testing.assert_array_equal(np.asarray(new_state.aux["act_absmax"]),
                                  np.asarray(new_qstate_r["act_absmax"]))


def test_fused_engine_step_matches_float_reference():
    """One fused-pallas engine step (tile_batch=128 -> a single tile, so one
    minibatch-SGD update) must match the float reference step with SGD."""
    cfg = get_smoke("mrf-fpga")
    fns = registry.build(cfg)
    key = jax.random.PRNGKey(0)
    stream = MRFSampleStream(seq=default_sequence(cfg.mrf_n_frames),
                             batch_size=128)
    x, y = sample_batch(stream, jax.random.PRNGKey(7))
    batch = {"x": x, "y": y}
    lr = 2e-2

    fused_fn, fused_init = engine.build(fns, engine.EngineConfig(
        backend="fused-pallas", lr=lr, optimizer="sgd", tile_batch=128,
        interpret=True, donate=False))
    float_fn, float_init = engine.build(fns, engine.EngineConfig(
        backend="float", lr=lr, optimizer="sgd", max_grad_norm=None,
        donate=False))

    state_k, _ = fused_fn(fused_init(key), batch)
    state_r, _ = float_fn(float_init(key), batch)
    _params_equal(state_k.params, state_r.params, atol=1e-5)
    assert int(state_k.step) == int(state_r.step) == 1


def test_fused_engine_adam_step_matches_float_reference():
    """One fused-pallas engine step with optimizer='adam' (tile_batch=128 ->
    a single tile = one Adam update on the full minibatch) must match the
    float backend's Adam step: params, both moment stacks, and the step
    counter — the in-kernel Adam is the same rule, just resident in VMEM."""
    cfg = get_smoke("mrf-fpga")
    fns = registry.build(cfg)
    key = jax.random.PRNGKey(0)
    stream = MRFSampleStream(seq=default_sequence(cfg.mrf_n_frames),
                             batch_size=128)
    x, y = sample_batch(stream, jax.random.PRNGKey(7))
    batch = {"x": x, "y": y}
    lr = 1e-3

    fused_fn, fused_init = engine.build(fns, engine.EngineConfig(
        backend="fused-pallas", lr=lr, optimizer="adam", tile_batch=128,
        interpret=True, donate=False))
    float_fn, float_init = engine.build(fns, engine.EngineConfig(
        backend="float", lr=lr, optimizer="adam", max_grad_norm=None,
        donate=False))

    state_k, _ = fused_fn(fused_init(key), batch)
    state_r, _ = float_fn(float_init(key), batch)
    _params_equal(state_k.params, state_r.params, atol=1e-5)
    _params_equal(state_k.opt_state.mu, state_r.opt_state.mu, atol=1e-5)
    _params_equal(state_k.opt_state.nu, state_r.opt_state.nu, atol=1e-7)
    assert int(state_k.opt_state.step) == int(state_r.opt_state.step) == 1
    assert int(state_k.step) == 1


def test_engine_rejects_configs_fused_cannot_honor():
    """The fused path computes grads+update in-kernel: configs it cannot
    honor must fail loudly at build time, never train the wrong rule."""
    from repro.kernels.fused_train.ops import make_engine_step
    from repro.train.step import make_train_step

    with pytest.raises(ValueError, match="microbatches"):
        engine.EngineConfig(backend="fused-pallas", microbatches=2)
    with pytest.raises(ValueError, match="grad_compress"):
        engine.EngineConfig(backend="fused-pallas", grad_compress=True)
    with pytest.raises(ValueError, match="optimizer"):
        engine.EngineConfig(optimizer="rmsprop")  # any backend: whitelist
    with pytest.raises(ValueError, match="sgd"):
        make_engine_step(lr=1e-2, optimizer="rmsprop")

    fused = lambda p, o, a, b: (p, o, a, {})
    with pytest.raises(ValueError, match="microbatches"):
        make_train_step(None, sgd(1e-2), fused_step=fused, microbatches=4)
    with pytest.raises(ValueError, match="compress"):
        make_train_step(None, sgd(1e-2), fused_step=fused, grad_compress=True)


def test_fused_tile_adapts_to_awkward_batch():
    """tile_batch is a ceiling: a batch not divisible by it must still run
    (largest dividing tile), not crash on the kernel grid assert."""
    from repro.kernels.fused_train.ops import effective_tile
    assert effective_tile(192, 128) == 96
    assert effective_tile(100, 128) == 100
    assert effective_tile(7, 4) == 1
    # degradation on prime/awkward sizes: fall back toward per-sample tiles
    assert effective_tile(13, 8) == 1       # prime above the ceiling
    assert effective_tile(97, 128) == 97    # prime under the ceiling: 1 tile
    assert effective_tile(254, 128) == 127  # 2*127 -> the big prime factor
    assert effective_tile(96, 36) == 32     # largest divisor <= ceiling
    cfg = get_smoke("mrf-fpga")
    fns = registry.build(cfg)
    stream = MRFSampleStream(seq=default_sequence(cfg.mrf_n_frames),
                             batch_size=24)
    x, y = sample_batch(stream, jax.random.PRNGKey(11))
    step_fn, init_state = engine.build(fns, engine.EngineConfig(
        backend="fused-pallas", lr=1e-2, optimizer="sgd", tile_batch=16,
        donate=False))
    new_state, metrics = step_fn(init_state(jax.random.PRNGKey(0)),
                                 {"x": x, "y": y})
    assert np.isfinite(float(metrics["loss"]))
    assert int(new_state.step) == 1
    # prime batch: degrades all the way to per-sample streaming and still runs
    stream_p = MRFSampleStream(seq=default_sequence(cfg.mrf_n_frames),
                               batch_size=13)
    xp, yp = sample_batch(stream_p, jax.random.PRNGKey(12))
    state_p, metrics_p = step_fn(init_state(jax.random.PRNGKey(0)),
                                 {"x": xp, "y": yp})
    assert np.isfinite(float(metrics_p["loss"]))
    assert int(state_p.step) == 1


def test_fused_multi_tile_is_sequential_sgd():
    """tile_batch < batch: the engine step must equal per-tile sequential SGD
    (the paper's streaming regime), not one big minibatch update."""
    cfg = get_smoke("mrf-fpga")
    fns = registry.build(cfg)
    params = fns.init(jax.random.PRNGKey(0))
    stream = MRFSampleStream(seq=default_sequence(cfg.mrf_n_frames),
                             batch_size=64)
    x, y = sample_batch(stream, jax.random.PRNGKey(9))
    lr = 1e-2

    step_fn, init_state = engine.build(fns, engine.EngineConfig(
        backend="fused-pallas", lr=lr, optimizer="sgd", tile_batch=16,
        donate=False))
    new_state, _ = step_fn(init_state(jax.random.PRNGKey(0)), {"x": x, "y": y})

    opt = sgd(lr)
    p, s = params, opt.init(params)
    for t in range(0, 64, 16):
        g = jax.grad(mrf_net.mse_loss)(p, x[t:t + 16], y[t:t + 16])
        p, s = opt.update(g, s, p)
    _params_equal(new_state.params, p, atol=1e-5)


# --------------------------------------------------------------------------
# the launcher, end to end (checkpointing runner, all three backends)
# --------------------------------------------------------------------------

@pytest.mark.parametrize("backend", ["float", "qat-int8", "fused-pallas"])
def test_launcher_smoke_all_backends(backend, tmp_path):
    from repro.launch.train import main
    rc = main(["--arch", "mrf-fpga", "--smoke", "--steps", "3",
               "--batch", "128", "--backend", backend, "--lr", "1e-3",
               "--ckpt-dir", str(tmp_path / backend), "--ckpt-every", "2"])
    assert rc == 0
    # the runner checkpointed: step-0 safety ckpt + the periodic one
    assert (tmp_path / backend / "LATEST").exists()
    assert (tmp_path / backend / "step_2").exists()
