"""Unit/property tests for model substrates: SSD chunked-vs-sequential,
chunked attention vs naive full softmax, MoE routing invariants, RoPE, QAT."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_fallback import given, settings, strategies as st

from repro.models import attention as attn
from repro.models import ssm as ssm_mod
from repro.models.common import apply_rope, fake_quant_int8
from repro.models.moe import moe_block, init_moe
from repro.models.common import key_iter

jax.config.update("jax_platform_name", "cpu")


# --------------------------------------------------------------------------
# SSD: the chunked dual form must equal the naive sequential recurrence.
# --------------------------------------------------------------------------

def _ssd_sequential(x, dt, A, B, C):
    b, l, h, p = x.shape
    n = B.shape[-1]

    def step(hstate, t):
        decay = jnp.exp(dt[:, t] * A[None, :])                      # (B,H)
        upd = jnp.einsum("bh,bn,bhp->bhpn", dt[:, t], B[:, t], x[:, t])
        hstate = decay[:, :, None, None] * hstate + upd
        y = jnp.einsum("bn,bhpn->bhp", C[:, t], hstate)
        return hstate, y

    h0 = jnp.zeros((b, h, p, n), jnp.float32)
    hT, ys = jax.lax.scan(step, h0, jnp.arange(l))
    return ys.transpose(1, 0, 2, 3), hT


@pytest.mark.parametrize("chunk", [4, 8, 16])
def test_ssd_chunked_equals_sequential(chunk):
    key = jax.random.PRNGKey(0)
    b, l, h, p, n = 2, 16, 3, 4, 5
    ks = jax.random.split(key, 5)
    x = jax.random.normal(ks[0], (b, l, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, l, h)))
    A = -jnp.exp(jax.random.normal(ks[2], (h,)))
    B = jax.random.normal(ks[3], (b, l, n))
    C = jax.random.normal(ks[4], (b, l, n))
    y_seq, h_seq = _ssd_sequential(x, dt, A, B, C)
    y_chk, h_chk = ssm_mod.ssd_chunked(x, dt, A, B, C, chunk)
    np.testing.assert_allclose(y_chk, y_seq, rtol=2e-4, atol=2e-5)
    np.testing.assert_allclose(h_chk, h_seq, rtol=2e-4, atol=2e-5)


@settings(max_examples=10, deadline=None)
@given(l=st.integers(2, 24), chunk=st.sampled_from([2, 4, 8]),
       seed=st.integers(0, 2**16))
def test_property_ssd_any_length(l, chunk, seed):
    if l % chunk:
        l = (l // chunk + 1) * chunk
    key = jax.random.PRNGKey(seed)
    ks = jax.random.split(key, 5)
    b, h, p, n = 1, 2, 3, 4
    x = jax.random.normal(ks[0], (b, l, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, l, h)))
    A = -jnp.exp(jax.random.normal(ks[2], (h,)))
    B = jax.random.normal(ks[3], (b, l, n))
    C = jax.random.normal(ks[4], (b, l, n))
    y_seq, _ = _ssd_sequential(x, dt, A, B, C)
    y_chk, _ = ssm_mod.ssd_chunked(x, dt, A, B, C, chunk)
    np.testing.assert_allclose(y_chk, y_seq, rtol=5e-4, atol=5e-5)


# --------------------------------------------------------------------------
# Attention: chunked path vs naive softmax; GQA; SWA; decode split semantics.
# --------------------------------------------------------------------------

def _naive(q, k, v, causal=True, window=None):
    b, sq, hq, dh = q.shape
    hkv = k.shape[2]
    g = hq // hkv
    kk = jnp.repeat(k, g, axis=2)
    vv = jnp.repeat(v, g, axis=2)
    s = jnp.einsum("bqhd,bkhd->bhqk", q, kk) / np.sqrt(dh)
    qpos, kpos = jnp.arange(sq), jnp.arange(k.shape[1])
    keep = jnp.ones((sq, k.shape[1]), bool)
    if causal:
        keep &= kpos[None] <= qpos[:, None]
    if window is not None:
        keep &= kpos[None] > qpos[:, None] - window
    s = jnp.where(keep[None, None], s, -1e30)
    p = jax.nn.softmax(s, -1)
    return jnp.einsum("bhqk,bkhd->bqhd", p, vv)


@pytest.mark.parametrize("hq,hkv", [(4, 4), (4, 2), (6, 2)])
@pytest.mark.parametrize("window", [None, 5])
def test_attention_matches_naive(hq, hkv, window):
    key = jax.random.PRNGKey(1)
    b, s, dh = 2, 16, 8
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (b, s, hq, dh))
    k = jax.random.normal(ks[1], (b, s, hkv, dh))
    v = jax.random.normal(ks[2], (b, s, hkv, dh))
    got = attn.attention(q, k, v, causal=True, window=window, q_chunk=4)
    want = _naive(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)


def test_decode_attention_matches_naive_row():
    """Single-token decode == last row of full attention."""
    key = jax.random.PRNGKey(2)
    b, s, hq, hkv, dh = 2, 12, 4, 2, 8
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (b, s, hq, dh))
    k = jax.random.normal(ks[1], (b, s, hkv, dh))
    v = jax.random.normal(ks[2], (b, s, hkv, dh))
    full = _naive(q, k, v, causal=True)
    got = attn.decode_attention(q[:, -1], k, v, jnp.int32(s))
    np.testing.assert_allclose(got, full[:, -1], rtol=1e-4, atol=1e-5)


def test_rope_relative_shift_invariance():
    """RoPE: q.k depends only on relative distance."""
    key = jax.random.PRNGKey(3)
    q = jax.random.normal(key, (1, 1, 1, 16))
    k = jax.random.normal(jax.random.fold_in(key, 1), (1, 1, 1, 16))
    def dot_at(p_q, p_k):
        qr = apply_rope(q, jnp.array([[p_q]]))
        kr = apply_rope(k, jnp.array([[p_k]]))
        return float(jnp.sum(qr * kr))
    assert abs(dot_at(5, 3) - dot_at(105, 103)) < 1e-3
    assert abs(dot_at(5, 3) - dot_at(6, 3)) > 1e-4  # sanity: not constant


# --------------------------------------------------------------------------
# MoE invariants
# --------------------------------------------------------------------------

def test_moe_capacity_and_combine():
    key = jax.random.PRNGKey(4)
    keys = key_iter(key)
    d, ff, e = 16, 32, 4
    p = init_moe(keys, d, ff, e, n_shared=0)
    x = jax.random.normal(next(keys), (2, 8, d), jnp.float32)
    y, aux = moe_block(p, x, top_k=2, capacity_factor=2.0, group_size=8)
    assert y.shape == x.shape
    assert jnp.all(jnp.isfinite(y))
    assert float(aux) >= 1.0 - 1e-3  # load-balance loss lower bound is 1 (k=1 term)


def test_moe_grads_reach_all_experts_eventually():
    key = jax.random.PRNGKey(5)
    keys = key_iter(key)
    d, ff, e = 8, 16, 4
    p = init_moe(keys, d, ff, e, n_shared=1)

    def loss(p, x):
        y, aux = moe_block(p, x, top_k=2, capacity_factor=2.0, group_size=32)
        return jnp.mean(jnp.square(y)) + 0.01 * aux

    x = jax.random.normal(next(keys), (4, 32, d), jnp.float32)
    g = jax.grad(loss)(p, x)
    assert bool(jnp.any(g.router != 0))
    assert bool(jnp.any(g.w_in != 0))


# --------------------------------------------------------------------------
# LM-scale QAT forward (the paper's technique knob)
# --------------------------------------------------------------------------

def test_fake_quant_bounds_and_ste():
    x = jnp.array([-3.0, -0.01, 0.0, 0.5, 2.9])
    q = fake_quant_int8(x)
    assert jnp.max(jnp.abs(q - x)) <= jnp.max(jnp.abs(x)) / 127.0 + 1e-6
    g = jax.grad(lambda t: jnp.sum(fake_quant_int8(t) ** 2))(x)
    assert jnp.all(jnp.isfinite(g)) and bool(jnp.any(g != 0))  # STE passes grads


def test_qat_lm_trains():
    import dataclasses
    from repro.configs import get_smoke
    from repro.models import registry
    cfg = dataclasses.replace(get_smoke("tinyllama-1.1b"), quant="qat-int8")
    fns = registry.build(cfg, tp=1)
    key = jax.random.PRNGKey(0)
    params = fns.init(key)
    tokens = jax.random.randint(key, (2, 16), 0, cfg.vocab_size, jnp.int32)
    loss, grads = jax.value_and_grad(fns.loss)(params, {"tokens": tokens,
                                                        "labels": tokens})
    assert jnp.isfinite(loss)
    assert all(jnp.all(jnp.isfinite(g)) for g in jax.tree.leaves(grads))
