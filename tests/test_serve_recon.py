"""Serving-engine tests: bucket tiling, pad-to-bucket shape stability,
int8 artifact save/load bit-exactness, masked re-assembly + centralized
denormalization, and the multi-host-style data-parallel serving smoke
(simulated multi-device mesh + ``host_sharded_key`` request streams)."""

import json
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _serve_helpers import (calibrated_net as _calibrated_net,
                            features as _features)

from repro.core import mrf_net, qat
from repro.data.pipeline import denormalize_targets
from repro.serve.recon import (DEFAULT_BUCKETS, ReconEngine, ReconRequest,
                               latency_percentiles, plan_tiles)

jax.config.update("jax_platform_name", "cpu")


# --------------------------------------------------------------------------
# bucket tiling
# --------------------------------------------------------------------------

@pytest.mark.parametrize("n", [1, 64, 128, 129, 333, 1024, 1025, 5000])
def test_plan_tiles_covers_exactly(n):
    tiles = plan_tiles(n, DEFAULT_BUCKETS)
    off = 0
    for t_off, count, bucket in tiles:
        assert t_off == off
        assert 0 < count <= bucket
        assert bucket in DEFAULT_BUCKETS
        off += count
    assert off == n
    # the tail uses the smallest bucket that fits (minimal padding)
    _, count, bucket = tiles[-1]
    if count < bucket:
        smaller = [b for b in DEFAULT_BUCKETS if b < bucket]
        assert all(b < count for b in smaller)


def test_plan_tiles_empty_and_full():
    assert plan_tiles(0, DEFAULT_BUCKETS) == []
    assert plan_tiles(2048, DEFAULT_BUCKETS) == [(0, 1024, 1024),
                                                 (1024, 1024, 1024)]


# --------------------------------------------------------------------------
# int8 artifact: export -> save -> load -> serve, bit-exact
# --------------------------------------------------------------------------

def test_artifact_roundtrip_bitexact(tmp_path):
    _, _, ints = _calibrated_net()
    path = qat.save_int8_artifact(tmp_path / "net", ints)
    assert path.suffix == ".npz" and path.exists()
    loaded = qat.load_int8_artifact(path)
    assert len(loaded) == len(ints)
    for a, b in zip(ints, loaded):
        assert a.w_q.dtype == b.w_q.dtype == jnp.int8
        assert b.b_q.dtype == jnp.int32
        assert jnp.array_equal(a.w_q, b.w_q)
        assert jnp.array_equal(a.b_q, b.b_q)
        assert jnp.array_equal(a.s_in, b.s_in)
        assert jnp.array_equal(a.s_w, b.s_w)
        assert (a.s_out is None) == (b.s_out is None)
        if a.s_out is not None:
            assert jnp.array_equal(a.s_out, b.s_out)

    from repro.kernels.qat_dense.ops import int_forward_pallas
    x = _features(200, seed=3)
    want = qat.int_forward(ints, x)
    got = int_forward_pallas(loaded, x)
    assert jnp.array_equal(want, got), "loaded artifact must serve bit-exact"


# --------------------------------------------------------------------------
# engine: padding invariance, oracle equality, masked re-assembly
# --------------------------------------------------------------------------

@pytest.mark.parametrize("n_voxels", [1, 77, 128, 500])
def test_float_engine_matches_direct_forward(n_voxels):
    params, _, _ = _calibrated_net()
    engine = ReconEngine(backend="float", params=params)
    x = _features(n_voxels, seed=n_voxels)
    res, = engine.reconstruct([ReconRequest(features=x)])
    want = np.asarray(denormalize_targets(mrf_net.forward(params, x)))
    np.testing.assert_allclose(res.t1_ms, want[:, 0], rtol=1e-6)
    np.testing.assert_allclose(res.t2_ms, want[:, 1], rtol=1e-6)
    assert res.n_voxels == n_voxels and res.latency_s > 0


def test_int8_engine_matches_oracle_bitexact():
    _, _, ints = _calibrated_net()
    engine = ReconEngine(backend="int8", int_layers=ints)
    x = _features(333, seed=9)
    res, = engine.reconstruct([ReconRequest(features=x)])
    want = np.asarray(denormalize_targets(qat.int_forward(ints, x)))
    assert np.array_equal(res.t1_ms, want[:, 0])
    assert np.array_equal(res.t2_ms, want[:, 1])


@pytest.mark.parametrize("impl", ["fused", "lax", "layered"])
def test_int8_impls_serve_identical_maps(impl):
    """Every int8 implementation (fused whole-network kernel, pure-lax
    fallback, layered chain) serves the oracle's bits through the engine —
    switching impl can never change a reconstructed map."""
    _, _, ints = _calibrated_net()
    engine = ReconEngine(backend="int8", int_layers=ints, int8_impl=impl)
    assert engine.int8_impl == impl
    x = _features(333, seed=9)
    res, = engine.reconstruct([ReconRequest(features=x)])
    want = np.asarray(denormalize_targets(qat.int_forward(ints, x)))
    assert np.array_equal(res.t1_ms, want[:, 0])
    assert np.array_equal(res.t2_ms, want[:, 1])


def test_int8_impl_resolution_and_validation():
    _, _, ints = _calibrated_net()
    with pytest.raises(ValueError, match="int8 impl"):
        ReconEngine(backend="int8", int_layers=ints, int8_impl="tensorrt")
    # None resolves per rig: Pallas-compiled fused on TPU, lax elsewhere
    engine = ReconEngine(backend="int8", int_layers=ints)
    expect = "fused" if jax.default_backend() == "tpu" else "lax"
    assert engine.int8_impl == expect
    # a float engine has no int8 impl
    params, _, _ = _calibrated_net()
    assert ReconEngine(backend="float", params=params).int8_impl is None


def test_executor_records_request_size_distribution():
    """Every dispatched request's voxel count lands in request_sizes — the
    input to measured bucket autotuning."""
    params, _, _ = _calibrated_net()
    engine = ReconEngine(backend="float", params=params)
    engine.reconstruct([ReconRequest(features=_features(n, seed=n))
                        for n in (7, 333, 64)])
    engine.reconstruct([ReconRequest(features=_features(130, seed=130))])
    assert engine.request_sizes == [7, 333, 64, 130]


def test_masked_reassembly_and_background():
    params, _, _ = _calibrated_net()
    engine = ReconEngine(backend="float", params=params)
    mask = np.zeros((8, 9), bool)
    mask[2:6, 3:7] = True
    x = _features(int(mask.sum()), seed=5)
    res, = engine.reconstruct([ReconRequest(features=x, mask=mask)])
    assert res.t1_ms.shape == mask.shape
    assert np.all(res.t1_ms[~mask] == 0) and np.all(res.t2_ms[~mask] == 0)
    want = np.asarray(denormalize_targets(mrf_net.forward(params, x)))
    np.testing.assert_allclose(res.t1_ms[mask], want[:, 0], rtol=1e-6)


def test_request_validation():
    params, _, _ = _calibrated_net()
    engine = ReconEngine(backend="float", params=params)
    with pytest.raises(ValueError, match="feature dim"):
        engine.reconstruct([ReconRequest(features=jnp.zeros((4, 7)))])
    bad_mask = np.ones((3, 3), bool)
    with pytest.raises(ValueError, match="mask selects"):
        engine.reconstruct([ReconRequest(features=_features(4),
                                         mask=bad_mask)])
    with pytest.raises(ValueError, match="backend"):
        ReconEngine(backend="fp64", params=params)
    assert engine.reconstruct([]) == []
    assert all(np.isnan(v) for v in latency_percentiles([]).values())


def test_zero_voxel_requests_still_get_results():
    """An all-background slice (0 voxels) must yield a real ReconResult,
    alone in a wave or mixed with non-empty requests."""
    params, _, _ = _calibrated_net()
    engine = ReconEngine(backend="float", params=params)
    empty_mask = np.zeros((4, 4), bool)
    empty = ReconRequest(features=_features(0), mask=empty_mask,
                         request_id="empty")
    res, = engine.reconstruct([empty])
    assert res.n_voxels == 0 and res.t1_ms.shape == (4, 4)
    assert np.all(res.t1_ms == 0) and np.all(res.t2_ms == 0)
    mixed = engine.reconstruct([empty, ReconRequest(features=_features(30))])
    assert mixed[0].n_voxels == 0 and mixed[1].n_voxels == 30
    assert engine.last_wave["total_voxels"] == 30


def test_bucketing_never_recompiles_after_warmup():
    """Pad-to-bucket means ragged request mixes reuse the same traced
    shapes: the jit cache stays bounded by the bucket set."""
    params, _, _ = _calibrated_net()
    engine = ReconEngine(backend="float", params=params,
                         buckets=(128, 256, 512))
    wave1 = [ReconRequest(features=_features(n, seed=n))
             for n in (50, 300, 601)]
    engine.reconstruct(wave1)
    traced = engine.compile_cache_size()
    assert traced <= 3
    # different raggedness, same bucket set -> zero new traces
    wave2 = [ReconRequest(features=_features(n, seed=n))
             for n in (1, 77, 130, 512, 700)]
    engine.reconstruct(wave2)
    assert engine.compile_cache_size() == traced
    assert engine.bucket_shapes_run <= {128, 256, 512}


def test_pooled_wave_equals_individual_requests():
    """Pooling many requests into one wave must not change any prediction."""
    _, _, ints = _calibrated_net()
    engine = ReconEngine(backend="int8", int_layers=ints)
    reqs = [ReconRequest(features=_features(n, seed=n), request_id=str(n))
            for n in (40, 333, 128)]
    pooled = engine.reconstruct(reqs)
    pooled_wave = dict(engine.last_wave)
    for req, res in zip(reqs, pooled):
        solo, = engine.reconstruct([req])
        assert res.request_id == req.request_id
        assert np.array_equal(res.t1_ms, solo.t1_ms)
        assert np.array_equal(res.t2_ms, solo.t2_ms)
    pct = latency_percentiles(pooled)
    assert pct["p50_ms"] <= pct["p90_ms"] <= pct["p99_ms"]
    assert pooled_wave["total_voxels"] == 40 + 333 + 128


# --------------------------------------------------------------------------
# denormalization is centralized
# --------------------------------------------------------------------------

def test_denormalize_targets_owns_the_ranges():
    y = jnp.array([[0.5, 0.5], [1.0, 0.1]])
    ms = np.asarray(denormalize_targets(y))
    np.testing.assert_allclose(ms, [[2000.0, 300.0], [4000.0, 60.0]])
    custom = np.asarray(denormalize_targets(y, t1_range=(0.0, 1000.0),
                                            t2_range=(0.0, 100.0)))
    np.testing.assert_allclose(custom, [[500.0, 50.0], [1000.0, 10.0]])

    from repro.core.metrics import table1_metrics, table1_metrics_normalized
    pred, true = jnp.abs(_features(32, 1)[:, :2]), jnp.abs(_features(32, 2)[:, :2])
    a = table1_metrics_normalized(pred, true)
    b = table1_metrics(np.asarray(denormalize_targets(pred)),
                       np.asarray(denormalize_targets(true)))
    assert a == b


# --------------------------------------------------------------------------
# multi-host-style data-parallel serving smoke (ROADMAP open item)
# --------------------------------------------------------------------------

_DP_SUBPROC = textwrap.dedent("""
    import os, json
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import sys; sys.path.insert(0, "src")
    import jax, jax.numpy as jnp, numpy as np
    from repro.core import mrf_net
    from repro.data.epg import default_sequence
    from repro.data.pipeline import MRFSampleStream, host_sharded_key, sample_batch
    from repro.dist.sharding import AxisRules, make_compat_mesh, use_rules
    from repro.serve.recon import ReconEngine, ReconRequest

    n_frames = 8
    sizes = mrf_net.layer_sizes(n_frames)
    params = mrf_net.init_params(jax.random.PRNGKey(0), sizes)
    stream = MRFSampleStream(seq=default_sequence(n_frames), batch_size=256)

    # two simulated hosts draw i.i.d. request streams without coordination
    reqs = []
    for host in range(2):
        key = host_sharded_key(seed=7, process_index=host)
        x, _ = sample_batch(stream, jax.random.fold_in(key, 0))
        reqs.append(ReconRequest(features=x, request_id=f"host{host}"))
    assert not np.allclose(np.asarray(reqs[0].features),
                           np.asarray(reqs[1].features))

    # mesh-less reference vs batch-sharded serving on an 8-device mesh
    ref = ReconEngine(backend="float", params=params).reconstruct(reqs)
    mesh = make_compat_mesh((8,), ("data",))
    rules = AxisRules(rules={"batch": "data"}, mesh=mesh)
    with use_rules(rules):
        sharded_engine = ReconEngine(backend="float", params=params)
        got = sharded_engine.reconstruct(reqs)
    out = {"n_devices": jax.device_count(),
           "match": all(
               np.allclose(r.t1_ms, g.t1_ms, rtol=1e-5, atol=1e-3)
               and np.allclose(r.t2_ms, g.t2_ms, rtol=1e-5, atol=1e-3)
               for r, g in zip(ref, got)),
           "voxels": sharded_engine.last_wave["total_voxels"]}
    print(json.dumps(out))
""")


def test_data_parallel_serving_smoke():
    proc = subprocess.run(
        [sys.executable, "-c", _DP_SUBPROC], capture_output=True, text=True,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        timeout=600)
    assert proc.returncode == 0, proc.stderr[-3000:]
    out = json.loads(proc.stdout.strip().splitlines()[-1])
    assert out["n_devices"] == 8
    assert out["match"], "sharded serving diverged from mesh-less serving"
    assert out["voxels"] == 512
