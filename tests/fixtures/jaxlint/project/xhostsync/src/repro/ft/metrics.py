"""Not a hot-loop module itself — the sync is fine here, not at its
hot-loop call sites."""


def summarize(state):
    return float(state.mean())
