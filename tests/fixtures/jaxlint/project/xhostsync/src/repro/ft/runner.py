"""Hot-loop module calling a helper that host-syncs in another module."""

from repro.ft.metrics import summarize


def run(state, steps):
    for _ in range(steps):
        state = state + 1
    return summarize(state)  # FINDING
