"""Donating step, defined here, consumed from loop.py."""

import functools

import jax


@functools.partial(jax.jit, donate_argnums=(0,))
def apply_update(state, grads):
    return jax.tree_util.tree_map(lambda p, g: p - g, state, grads)
