"""Caller: reads state after donating it to an imported step."""

import repro.models.steps as steps
from repro.models.steps import apply_update


def drive(state, grads):
    new_state = apply_update(state, grads)
    return state, new_state  # FINDING


def drive_alias(state, grads):
    out = steps.apply_update(state, grads)
    return state, out  # FINDING


def drive_rebound(state, grads):
    state = apply_update(state, grads)
    return state  # rebinding on the call line: the blessed idiom
