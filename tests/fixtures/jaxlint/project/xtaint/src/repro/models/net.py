"""Caller: traced forward hands its input to an imported helper."""

import jax

from repro.models.util import pick


@jax.jit
def forward(x):
    return pick(x)  # FINDING
