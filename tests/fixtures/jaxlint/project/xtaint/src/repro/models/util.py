"""Helper module: branches on its argument — fine unless called traced."""


def pick(v):
    if v > 0:
        return v
    return -v
