"""Kernel whose misaligned block dim hides behind an imported constant."""

from jax.experimental import pallas as pl

from repro.kernels.foo.tiles import BLOCK_N


def build_spec():
    return pl.BlockSpec((8, BLOCK_N), lambda i: (i, 0))  # FINDING
