"""Tile constants imported by kernel.py."""

BLOCK_N = 96
