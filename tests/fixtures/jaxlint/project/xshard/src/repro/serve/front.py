"""Entry point whose sharding happens one resolved call away, in another
module — a per-file false positive the project pass removes."""

from repro.serve.annotations import wrap


def serve_batch(batch):
    return wrap(batch)
