"""The delegate that actually annotates the batch axis."""

from repro.dist.sharding import shard


def wrap(x):
    return shard(x, "batch", None)
