"""A shard call exists in this module — but the entry point never reaches
it, which the per-file module-string-match provably missed."""

from repro.dist.sharding import shard


def annotate(x):
    return shard(x, "batch", None)


def infer(batch):  # FINDING
    return batch * 2
