"""RECOMPILE positives: jit re-trace hazards."""

import functools

import jax


def jit_in_loop(f, xs):
    outs = []
    for x in xs:
        outs.append(jax.jit(f)(x))  # FINDING
    return outs


def decorated_in_loop(xs):
    outs = []
    for x in xs:
        @jax.jit
        def step(v):  # FINDING
            return v * 2
        outs.append(step(x))
    return outs


def immediate_invoke(x):
    return jax.jit(lambda v: v + 1)(x)  # FINDING


@functools.partial(jax.jit, static_argnames=("width",))
def padded(v, width):
    return v


def static_name_loop_feed(xs):
    y = xs
    for width in (1, 2, 3):
        y = padded(y, width=width)  # FINDING
    return y


def run_bucket(v, size):
    return v


bucketed = jax.jit(run_bucket, static_argnums=(1,))


def static_num_loop_feed(xs):
    y = xs
    for size in (8, 16):
        y = bucketed(y, size)  # FINDING
    return y
