"""HOSTSYNC positive: five distinct host syncs on a hot-loop module path.

Linted as if it were ``src/repro/ft/runner.py`` (a hot-loop module).
"""
import jax
import numpy as np


def loop(state, metrics, xs):
    a = np.asarray(xs)                   # FINDING np.asarray pulls to host
    b = metrics["loss"].item()           # FINDING .item() blocks
    c = float(metrics["gnorm"])          # FINDING float(tracer) blocks
    jax.block_until_ready(state)         # FINDING explicit barrier
    d = jax.device_get(metrics)          # FINDING device->host transfer
    return a, b, c, d
