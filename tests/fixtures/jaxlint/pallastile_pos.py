"""PALLASTILE positive: misaligned tiles and a VMEM blowout.

Linted as if it were ``src/repro/kernels/fix/kernel.py``; under any other
path the rule is silent (the test checks both).
"""
import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu


def call(kernel, x):
    return pl.pallas_call(  # FINDING estimated VMEM ~32 MiB > 16 MiB cap
        kernel,
        grid=(4,),
        in_specs=[
            pl.BlockSpec((8, 96), lambda i: (i, 0)),   # FINDING lane 96
            pl.BlockSpec((4, 128), lambda i: (i, 0)),  # FINDING sublane 4
        ],
        out_specs=pl.BlockSpec((8, 128), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((32, 128), x.dtype),
        scratch_shapes=[pltpu.VMEM((8192, 1024), jnp.float32)],
    )(x)
