"""SCANCARRY positives: carry-out structure provably differs from carry-in."""

from functools import partial

from jax import lax


def dropped_slot(xs):
    def scan_body(carry, x):
        loss, count = carry
        return (loss + x,), x  # FINDING
    return lax.scan(scan_body, (0.0, 0), xs)


def extra_key(xs):
    init = {"w": 1.0, "b": 0.0}

    def dict_body(c, x):
        c2 = {"w": c["w"] + x, "b": c["b"], "m": x}
        return c2, x  # FINDING
    return lax.scan(dict_body, init, xs)


def while_arity(limit):
    def wcond(c):
        return c[0] < limit

    def wbody(c):
        i, total = c
        return (i + 1, total + i, i)  # FINDING
    return lax.while_loop(wcond, wbody, (0, 0))


def fori_renamed_key(n):
    def fbody(i, c):
        return {"sum": c["sum"] + i, "max": c["mx"]}  # FINDING
    return lax.fori_loop(0, n, fbody, {"sum": 0, "mx": 0})


def lambda_shrink(xs):
    return lax.scan(lambda c, x: ((c[0],), x), (0.0, 1.0), xs)  # FINDING


def partial_bound_mismatch(xs, scale):
    def pbody(scale_, carry, x):
        a, b = carry
        return (a * scale_,), x  # FINDING
    return lax.scan(partial(pbody, scale), (1.0, 0.0), xs)
