"""TRACERBRANCH negative: static args, shape branches, untraced helpers,
and subscript stores that must not taint their index."""
import functools

import jax


@functools.partial(jax.jit, static_argnames=("mode",))
def step(x, mode):
    if mode == "fast":  # static argument: branching is fine
        x = x + 1
    b = x.shape[0]
    if b > 1:           # shapes are static under tracing: fine
        x = x * 2
    acc = {}
    i = 0
    for i in range(b):
        acc[i] = x      # storing at acc[i] must not taint the index i
    if i >= 0:          # i is a Python int: fine
        x = x + 0
    return x, acc


def helper(x):
    if x > 0:  # not traced anywhere in this module: fine
        return 1
    return 0
