"""RECOMPILE negatives: bind-once jits and static literals stay silent."""

import functools

import jax


@jax.jit
def step(v):
    return v * 2


def hot_loop(xs):
    outs = []
    for x in xs:
        outs.append(step(x))  # calling a prebuilt jit is fine
    return outs


def make_step(scale):
    # the blessed factory idiom: the jit is built once and returned
    @functools.partial(jax.jit, static_argnames=("width",))
    def padded(v, width):
        return v * scale
    return padded


@functools.partial(jax.jit, static_argnames=("width",))
def pad_to(v, width):
    return v


def static_literal_under_loop(xs):
    y = xs
    for x in xs:
        y = pad_to(x, width=16)  # literal static: one trace total
    return y


def static_from_outer_scope(xs, width):
    y = xs
    for x in xs:
        y = pad_to(x, width=width)  # not a loop variable
    return y
