"""SHARD positive: batch-bearing entry points, no shard call anywhere.

Linted as if it lived under ``src/repro/serve/`` — the same source under a
non-serve/train path produces no findings (the test checks both).
"""


def make_step(fns):
    def step(params, batch):  # FINDING entry point nested in a factory
        return fns.apply(params, batch)

    return step


def serve(tokens):  # FINDING top-level batch-bearing entry point
    return tokens + 1
