"""SHARD negative: the module routes its batch through dist.shard."""
from repro.dist.sharding import shard


def make_step(fns):
    def step(params, batch):
        batch = shard(batch, "batch", None)
        return fns.apply(params, batch)

    return step


def _helper(batch):  # private: never an entry point
    return batch
