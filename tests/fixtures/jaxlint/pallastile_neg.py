"""PALLASTILE negative: aligned tiles inside the VMEM budget.

Dims resolve through a module constant (TILE) and the enclosing function's
int parameter default (block_m) — both sanctioned static sources.
"""
import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

TILE = 128


def call(kernel, x, block_m: int = 8):
    return pl.pallas_call(
        kernel,
        grid=(4,),
        in_specs=[pl.BlockSpec((block_m, TILE), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((8, 128), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((32, TILE), x.dtype),
        scratch_shapes=[pltpu.VMEM((8, TILE), jnp.float32)],
    )(x)
