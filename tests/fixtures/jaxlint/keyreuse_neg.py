"""KEYREUSE negatives: the blessed split/fold_in idioms stay silent."""

import jax
import jax.random as jr
import numpy as np


def split_idiom(key):
    k1, k2 = jax.random.split(key)
    a = jax.random.normal(k1, (8,))
    b = jax.random.uniform(k2, (8,))
    return a + b


def fold_in_loop(key, n):
    out = []
    for i in range(n):
        k = jax.random.fold_in(key, i)  # derivation, not consumption
        out.append(jax.random.normal(k, (2,)))
    return out


def carry_split_loop(key, n):
    out = []
    for _i in range(n):
        key, sub = jr.split(key)  # key is rebound every iteration
        out.append(jr.normal(sub, (2,)))
    return out


def exclusive_branches(key, flag):
    if flag:
        return jax.random.normal(key, (2,))
    return jax.random.uniform(key, (2,))


def numpy_is_stateful(n):
    a = np.random.normal(0.0, 1.0, n)  # np.random reuse is not a hazard
    b = np.random.normal(0.0, 1.0, n)
    return a, b
