"""DONATE positive: reading a buffer after the call that donated it."""
import jax


def fit(step, state, batches, log):
    step_d = jax.jit(step, donate_argnums=(0,))
    for batch in batches:
        new_state, metrics = step_d(state, batch)
        log(state.step, metrics)  # FINDING `state` was donated above
        state = new_state
    return state
