"""Pragma suppression: a reasoned pragma silences its line's finding.

Linted as if it were ``src/repro/ft/runner.py``; expected: zero findings.
"""
import jax


def loop(state):
    jax.block_until_ready(state)  # jaxlint: disable=HOSTSYNC -- fixture: sanctioned final sync
    return state
