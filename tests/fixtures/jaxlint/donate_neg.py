"""DONATE negative: the ``state = f(state)`` rebinding idiom is safe."""
import jax


def fit(step, state, batches):
    step_d = jax.jit(step, donate_argnums=(0,))
    metrics = None
    for batch in batches:
        state, metrics = step_d(state, batch)  # rebinds on the call line
    return state, metrics
