"""KEYREUSE positives: same key, same bits."""

import jax
import jax.random as jr
from jax import random
from jax.random import normal as sample_normal


def pair_reuse(key):
    a = jax.random.normal(key, (8,))
    b = jax.random.uniform(key, (8,))  # FINDING
    return a + b


def split_then_reuse(key):
    k1, k2 = jr.split(key)
    noise = jr.normal(key, (4,))  # FINDING
    return k1, k2, noise


def loop_reuse(key, n):
    out = []
    for _i in range(n):
        out.append(random.normal(key, (2,)))  # FINDING
    return out


def comp_reuse(key, n):
    return [sample_normal(key, (2,)) for _ in range(n)]  # FINDING


def keyword_spelling(key):
    a = jax.random.bernoulli(key=key, p=0.5)
    b = jax.random.bernoulli(key=key, p=0.5)  # FINDING
    return a, b
