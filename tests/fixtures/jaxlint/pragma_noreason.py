"""Reasonless pragma: inert (HOSTSYNC still reported) AND itself a finding.

Linted as if it were ``src/repro/ft/runner.py``; expected: one HOSTSYNC
finding plus one PRAGMA finding, both on the pragma line.
"""
import jax


def loop(state):
    jax.block_until_ready(state)  # jaxlint: disable=HOSTSYNC
    return state
