"""TRACERBRANCH positive: Python control flow on jit-traced values."""
import jax


@jax.jit
def step(x, y):
    if x > 0:             # FINDING Python `if` on a traced value
        y = y + 1
    while y:              # FINDING Python `while` on a traced value
        y = y - 1
    n = len(x)            # FINDING len() goes through __len__ on a tracer
    return x, y, n
