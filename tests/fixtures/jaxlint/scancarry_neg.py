"""SCANCARRY negatives: threaded carries and unknown structures stay silent."""

from functools import partial

from jax import lax


def threaded(xs):
    def scan_body(carry, x):
        loss, count = carry
        return (loss + x, count + 1), x
    return lax.scan(scan_body, (0.0, 0), xs)


def dict_state(xs):
    def dict_body(c, x):
        return {"w": c["w"] + x, "b": c["b"]}, x
    return lax.scan(dict_body, {"w": 0.0, "b": 0.0}, xs)


def partial_bound(xs, scale):
    def pbody(scale_, carry, x):
        a, b = carry
        return (a * scale_, b + x), x
    return lax.scan(pbody_bound(scale), (1.0, 0.0), xs)


def pbody_bound(scale):
    return partial(lambda s, c, x: ((c[0] * s, c[1] + x), x), scale)


def partial_inline(xs, scale):
    def ibody(scale_, carry, x):
        a, b = carry
        return (a * scale_, b + x), x
    return lax.scan(partial(ibody, scale), (1.0, 0.0), xs)


def unknown_stays_silent(xs, init):
    def ubody(c, x):
        return c, x  # carry structure unknown: no claim, no finding
    return lax.scan(ubody, init, xs)


def while_ok(limit):
    def wcond(c):
        return c[0] < limit

    def wbody(c):
        i, total = c
        return (i + 1, total + i)
    return lax.while_loop(wcond, wbody, (0, 0))


def fori_ok(n):
    def fbody(i, c):
        return {"sum": c["sum"] + i, "mx": c["mx"]}
    return lax.fori_loop(0, n, fbody, {"sum": 0, "mx": 0})
