"""HOSTSYNC negative: sanctioned sync points and static floats only.

Linted as if it were ``src/repro/serve/executor.py``, whose sync_allowlist
blesses ``InflightWave.wait*`` — and ``float(<literal>)`` is never a sync.
"""
import jax
import numpy as np


class InflightWave:
    def wait(self):
        jax.block_until_ready(self.out)  # allowlisted qualname
        return np.asarray(self.out)      # allowlisted qualname

    def wait_tiles(self, tiles):
        return [np.asarray(t) for t in tiles]  # allowlisted qualname


def schedule(waves):
    worst = float("-inf")  # float of a literal is not a device fetch
    return worst, waves
