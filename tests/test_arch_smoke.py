"""Per-architecture smoke tests (assignment requirement): a REDUCED config of
the same family runs one forward/train step on CPU — output shapes + no NaNs —
plus prefill->decode consistency against the full forward."""

import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_smoke, lm_archs
from repro.models import registry
from repro.models.encdec import enc_len_for

jax.config.update("jax_platform_name", "cpu")

B, S = 2, 24


def _batch(cfg, key, tokens):
    batch = {"tokens": tokens, "labels": tokens}
    if cfg.family == "vlm":
        batch["prefix_embeds"] = 0.02 * jax.random.normal(
            key, (tokens.shape[0], cfg.n_prefix_embeds, cfg.d_model))
    if cfg.family == "encdec":
        batch["frames"] = 0.02 * jax.random.normal(
            key, (tokens.shape[0], enc_len_for(tokens.shape[1]), cfg.d_model))
    return batch


# The MRF reconstruction nets register in ARCHS too, but have no LM
# train/prefill/decode surface; their engine coverage is
# tests/test_train_engine.py.
@pytest.fixture(scope="module", params=lm_archs())
def arch(request):
    cfg = get_smoke(request.param)
    fns = registry.build(cfg, tp=1)
    key = jax.random.PRNGKey(0)
    params = fns.init(key)
    return cfg, fns, params, key


def test_train_step(arch):
    """One full train step: loss + grads finite, params update."""
    cfg, fns, params, key = arch
    tokens = jax.random.randint(key, (B, S), 0, cfg.vocab_size, jnp.int32)
    batch = _batch(cfg, key, tokens)
    loss, grads = jax.value_and_grad(fns.loss)(params, batch)
    assert jnp.isfinite(loss), cfg.name
    for g in jax.tree.leaves(grads):
        assert jnp.all(jnp.isfinite(g)), cfg.name
    new = jax.tree.map(lambda p, g: p - 1e-3 * g, params, grads)
    loss2 = fns.loss(new, batch)
    assert jnp.isfinite(loss2)


def test_forward_shapes(arch):
    cfg, fns, params, key = arch
    tokens = jax.random.randint(key, (B, S), 0, cfg.vocab_size, jnp.int32)
    cache, logits = fns.prefill(params, _batch(cfg, key, tokens))
    assert logits.shape == (B, cfg.padded_vocab(1))
    assert jnp.all(jnp.isfinite(logits.astype(jnp.float32)))


def test_prefill_decode_consistency(arch):
    """decode(prefill(S tokens), token S) == prefill(S+1 tokens) last logits."""
    cfg, fns, params, key = arch
    tokens = jax.random.randint(key, (B, S + 1), 0, cfg.vocab_size, jnp.int32)
    _, full = fns.prefill(params, _batch(cfg, key, tokens))
    cache, _ = fns.prefill(params, _batch(cfg, key, tokens[:, :S]))
    dec, new_cache = fns.decode(params, cache, tokens[:, S], jnp.int32(S))
    assert dec.shape == full.shape
    d = jnp.max(jnp.abs(full.astype(jnp.float32) - dec.astype(jnp.float32)))
    assert d < 0.06, f"{cfg.name}: decode/full divergence {float(d)}"
    # cache must actually change (the new token was written)
    leaves_old = jax.tree.leaves(cache)
    leaves_new = jax.tree.leaves(new_cache)
    assert any(not jnp.array_equal(a, b) for a, b in zip(leaves_old, leaves_new))


def test_decode_steps_chain(arch):
    """A few chained decode steps stay finite (cache plumbing is consistent)."""
    cfg, fns, params, key = arch
    tokens = jax.random.randint(key, (B, S), 0, cfg.vocab_size, jnp.int32)
    cache, logits = fns.prefill(params, _batch(cfg, key, tokens))
    for i in range(3):
        nxt = jnp.argmax(logits, -1).astype(jnp.int32) % cfg.vocab_size
        logits, cache = fns.decode(params, cache, nxt, jnp.int32(S + i))
        assert jnp.all(jnp.isfinite(logits.astype(jnp.float32))), cfg.name
