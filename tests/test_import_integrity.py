"""Tier-1 guard: every ``repro.*`` import target exists on disk.

This is the check that would have caught the seed regression where ten
modules imported ``repro.dist.sharding`` but ``src/repro/dist/`` was never
committed, failing collection of the whole suite.
"""

import pathlib
import subprocess
import textwrap

import pytest

from repro.tools.import_integrity import find_missing_imports

REPO_ROOT = pathlib.Path(__file__).resolve().parents[1]


def test_all_repro_imports_resolve():
    assert find_missing_imports(REPO_ROOT) == []


def test_no_tracked_bytecode():
    """Compiled bytecode must never be committed: it bloats diffs, goes
    stale silently, and once slipped a whole ``__pycache__`` tree into a PR.
    ``.gitignore`` keeps new ones out; this guards the index itself."""
    try:
        res = subprocess.run(["git", "ls-files"], cwd=REPO_ROOT,
                             capture_output=True, text=True, timeout=60)
    except (OSError, subprocess.TimeoutExpired):
        pytest.skip("git unavailable")
    if res.returncode != 0:
        pytest.skip("not a git checkout")
    tracked = res.stdout.splitlines()
    offenders = [f for f in tracked
                 if f.endswith(".pyc") or "__pycache__" in f.split("/")]
    assert offenders == [], (
        f"tracked bytecode files (git rm --cached them): {offenders[:10]}")
    gitignore = REPO_ROOT / ".gitignore"
    assert gitignore.exists() and ".gitignore" in tracked
    rules = gitignore.read_text().splitlines()
    for required in ("__pycache__/", "*.pyc", ".jaxlint-cache.json"):
        assert required in rules, f".gitignore is missing {required!r}"


def test_checker_flags_missing_module(tmp_path):
    pkg = tmp_path / "src" / "repro"
    pkg.mkdir(parents=True)
    (pkg / "__init__.py").write_text("")
    (pkg / "consumer.py").write_text(textwrap.dedent("""
        import repro
        from repro.ghost.sharding import shard
    """))
    missing = find_missing_imports(tmp_path)
    assert len(missing) == 1
    assert "repro.ghost.sharding" in missing[0]
    assert "consumer.py" in missing[0]


def test_checker_accepts_attribute_imports(tmp_path):
    pkg = tmp_path / "src" / "repro"
    pkg.mkdir(parents=True)
    (pkg / "__init__.py").write_text("")
    (pkg / "util.py").write_text("helper = 1\n")
    (pkg / "consumer.py").write_text("from repro.util import helper\n")
    assert find_missing_imports(tmp_path) == []
