"""Tier-1 guard: every ``repro.*`` import target exists on disk.

This is the check that would have caught the seed regression where ten
modules imported ``repro.dist.sharding`` but ``src/repro/dist/`` was never
committed, failing collection of the whole suite.
"""

import pathlib
import textwrap

from repro.tools.import_integrity import find_missing_imports

REPO_ROOT = pathlib.Path(__file__).resolve().parents[1]


def test_all_repro_imports_resolve():
    assert find_missing_imports(REPO_ROOT) == []


def test_checker_flags_missing_module(tmp_path):
    pkg = tmp_path / "src" / "repro"
    pkg.mkdir(parents=True)
    (pkg / "__init__.py").write_text("")
    (pkg / "consumer.py").write_text(textwrap.dedent("""
        import repro
        from repro.ghost.sharding import shard
    """))
    missing = find_missing_imports(tmp_path)
    assert len(missing) == 1
    assert "repro.ghost.sharding" in missing[0]
    assert "consumer.py" in missing[0]


def test_checker_accepts_attribute_imports(tmp_path):
    pkg = tmp_path / "src" / "repro"
    pkg.mkdir(parents=True)
    (pkg / "__init__.py").write_text("")
    (pkg / "util.py").write_text("helper = 1\n")
    (pkg / "consumer.py").write_text("from repro.util import helper\n")
    assert find_missing_imports(tmp_path) == []
