"""Direct unit coverage for the analysis layer (hlo_cost + roofline) and
the measured-autotune scoring logic it feeds.

``analyze_hlo`` is checked against a *real* compiled module (flops of a
jitted matmul are known in closed form, and an int8 dot must land in
``flops_int8``) plus a synthetic while-loop module for trip-count
multiplication.  ``roofline_terms`` is checked as arithmetic.  The
autotune pieces (candidate generation / trace scoring / bucket selection /
VMEM block model) are pure given an injected timing function, so they are
tested without timing anything.
"""

import sys

import jax
import jax.numpy as jnp
import pytest

from repro.analysis.hlo_cost import analyze_hlo
from repro.analysis.roofline import (TPU_V5E, model_flops_decode,
                                     model_flops_train, roofline_terms)

jax.config.update("jax_platform_name", "cpu")

sys.path.insert(0, ".")  # benchmarks/ is repo-root level, not a package
from benchmarks import serve_autotune  # noqa: E402


# ---------------------------------------------------------------------------
# analyze_hlo on real compiled modules
# ---------------------------------------------------------------------------

def _hlo_of(fn, *args):
    return jax.jit(fn).lower(*args).compile().as_text()


def test_analyze_hlo_matmul_flops_exact():
    """A lone (M,K)@(K,N) dot costs exactly 2*M*N*K flops."""
    m, k, n = 64, 128, 32
    a = jnp.zeros((m, k), jnp.float32)
    b = jnp.zeros((k, n), jnp.float32)
    hc = analyze_hlo(_hlo_of(lambda a, b: a @ b, a, b))
    assert hc["flops"] == 2.0 * m * n * k
    assert hc["flops_int8"] == 0.0


def test_analyze_hlo_int8_dot_fraction():
    """An s8 x s8 dot's flops land in flops_int8 (the 2x MXU path).

    Synthetic module: XLA-CPU widens s8 operands to s32 before its dot (so
    a CPU-compiled module never shows an s8 dot), but TPU/Mosaic modules
    keep them s8 — the classification is exercised on HLO as the TPU
    emits it.
    """
    hlo = """
HloModule m

ENTRY %main (a: s8[16,64], b: s8[64,8]) -> s32[16,8] {
  %a = s8[16,64]{1,0} parameter(0)
  %b = s8[64,8]{1,0} parameter(1)
  ROOT %d = s32[16,8]{1,0} dot(s8[16,64]{1,0} %a, s8[64,8]{1,0} %b), lhs_contracting_dims={1}, rhs_contracting_dims={0}
}
"""
    hc = analyze_hlo(hlo)
    assert hc["flops"] == 2.0 * 16 * 8 * 64
    assert hc["flops_int8"] == hc["flops"]


def test_analyze_hlo_while_trip_count():
    """A known_trip_count while multiplies its body's dot flops."""
    hlo = """
HloModule m

%body (p: (s32[], f32[8,8])) -> (s32[], f32[8,8]) {
  %p = (s32[], f32[8,8]{1,0}) parameter(0)
  %i = s32[] get-tuple-element((s32[], f32[8,8]{1,0}) %p), index=0
  %x = f32[8,8]{1,0} get-tuple-element((s32[], f32[8,8]{1,0}) %p), index=1
  %d = f32[8,8]{1,0} dot(f32[8,8]{1,0} %x, f32[8,8]{1,0} %x), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  ROOT %t = (s32[], f32[8,8]{1,0}) tuple(s32[] %i, f32[8,8]{1,0} %d)
}

%cond (q: (s32[], f32[8,8])) -> pred[] {
  %q = (s32[], f32[8,8]{1,0}) parameter(0)
  %j = s32[] get-tuple-element((s32[], f32[8,8]{1,0}) %q), index=0
  %c = s32[] constant(5)
  ROOT %lt = pred[] compare(s32[] %j, s32[] %c), direction=LT
}

ENTRY %main (a: (s32[], f32[8,8])) -> (s32[], f32[8,8]) {
  %a = (s32[], f32[8,8]{1,0}) parameter(0)
  ROOT %w = (s32[], f32[8,8]{1,0}) while((s32[], f32[8,8]{1,0}) %a), condition=%cond, body=%body, backend_config={"known_trip_count":{"n":"5"}}
}
"""
    hc = analyze_hlo(hlo)
    assert hc["flops"] == 5 * 2.0 * 8 * 8 * 8


# ---------------------------------------------------------------------------
# roofline_terms arithmetic
# ---------------------------------------------------------------------------

def test_roofline_terms_arithmetic():
    r = roofline_terms(flops_per_device=TPU_V5E["peak_bf16_flops"],
                       bytes_per_device=0.0,
                       collective_bytes_per_device=0.0, chips=1)
    assert r["t_compute_s"] == pytest.approx(1.0)
    assert r["dominant"] == "compute"
    assert r["roofline_fraction"] == pytest.approx(1.0)


def test_roofline_int8_fraction_halves_compute():
    """Full-int8 flops run at 2x the bf16 rate, so t_compute halves."""
    f = TPU_V5E["peak_bf16_flops"]
    t_f32 = roofline_terms(flops_per_device=f, bytes_per_device=0,
                           collective_bytes_per_device=0, chips=1)
    t_i8 = roofline_terms(flops_per_device=f, bytes_per_device=0,
                          collective_bytes_per_device=0, chips=1,
                          int8_fraction=1.0)
    assert t_i8["t_compute_s"] == pytest.approx(t_f32["t_compute_s"] / 2)


def test_model_flops_formulas():
    assert model_flops_train(10, 5) == 300.0   # 6 N D
    assert model_flops_decode(10, 5) == 100.0  # 2 N D


# ---------------------------------------------------------------------------
# autotune: pure logic under an injected timing model
# ---------------------------------------------------------------------------

def test_candidate_sets_lane_aligned_and_bounded():
    sizes = [700, 1024, 333, 96, 2048, 1500, 811, 64]
    cands = serve_autotune.candidate_bucket_sets(sizes)
    assert any(list(c) == [128, 256, 512, 1024] for c in cands)  # control
    for c in cands:
        assert 1 <= len(c) <= serve_autotune.MAX_BUCKETS
        assert all(b % serve_autotune.LANE == 0 for b in c)
        assert list(c) == sorted(set(c))


def test_trace_cost_charges_planned_tiles():
    # 300 on buckets (128, 256): two tiles of 256 then... plan_tiles: one
    # full 256 tile + remainder 44 -> 128 tile
    times = {128: 1.0, 256: 1.5}
    assert serve_autotune.trace_cost([300], (128, 256), times) == 2.5
    assert serve_autotune.trace_cost([100, 100], (128, 256), times) == 2.0


def test_tune_buckets_picks_measured_argmin():
    """Under a linear cost model with a fixed per-tile launch overhead, the
    tuner must prefer buckets that pad less over the trace."""
    sizes = [700] * 8  # every request pads 1024-700 = 324 under the default

    def time_buckets(buckets):
        # launch overhead + linear voxel cost: padding is pure waste
        return {b: 1.0 + b * 0.01 for b in buckets}

    out = serve_autotune.tune_buckets(sizes, time_buckets)
    assert 768 in out["buckets"]  # 700 aligns up to 768, not 1024
    best_cost = out["predicted_trace_s"]
    default_cost = next(c["predicted_trace_s"] for c in out["candidates"]
                        if c["buckets"] == [128, 256, 512, 1024])
    assert best_cost <= default_cost
    assert out["candidates"] == sorted(out["candidates"],
                                       key=lambda c: c["predicted_trace_s"])


def test_pick_block_m_respects_vmem_budget():
    widths = (128, 128, 128)
    out = serve_autotune.pick_block_m(128, widths)
    bm = out["block_m"]
    assert out["footprint_bytes"][str(bm)] <= out["vmem_budget_bytes"]
    # a tiny budget degrades to the smallest candidate, never crashes
    tiny = serve_autotune.pick_block_m(128, widths, vmem_bytes=1024)
    assert tiny["block_m"] == 128


def test_fused_vmem_bytes_monotone_in_block_m():
    widths = (128, 128)
    vals = [serve_autotune.fused_vmem_bytes(bm, 128, widths)
            for bm in (128, 256, 512)]
    assert vals == sorted(vals)
