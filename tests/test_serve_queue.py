"""Queue/executor tests for the pipelined serving stack: wave-formation
properties (every voxel served exactly once, voxel cap, deadline from
enqueue, priority order), pipelined == sync bit-exactness for both
backends, the no-per-tile-host-sync contract of the pipelined executor,
latency-from-enqueue semantics, and failed-lifecycle admission."""

import time
import types

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_fallback import given, settings, strategies as st
from _serve_helpers import (N_FRAMES, calibrated_net as _calibrated_net,
                            features as _features)

from repro.core import mrf_net
from repro.data.pipeline import denormalize_targets
from repro.serve.executor import InflightWave, WaveExecutor, plan_tiles
from repro.serve.queue import RequestQueue, RequestState
from repro.serve.recon import ReconEngine, ReconRequest

jax.config.update("jax_platform_name", "cpu")


def _stub(n_voxels, rid=""):
    # the queue is duck-typed: it only reads n_voxels / request_id
    return types.SimpleNamespace(n_voxels=n_voxels, request_id=rid)


# --------------------------------------------------------------------------
# wave formation properties (admission layer alone, no jax)
# --------------------------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_wave_formation_schedules_every_request_exactly_once(seed):
    rng = np.random.default_rng(seed)
    counts = rng.integers(0, 3000, size=int(rng.integers(1, 25))).tolist()
    prios = rng.integers(0, 3, size=len(counts)).tolist()
    cap = int(rng.integers(64, 4096))
    q = RequestQueue(max_wave_voxels=cap)
    tickets = [q.submit(_stub(n, str(i)), priority=p)
               for i, (n, p) in enumerate(zip(counts, prios))]
    assert q.pending_voxels() == sum(counts)

    waves = []
    while q.n_pending:  # flush, exactly as the engine's drain loop does
        waves.append(q.form_wave(flush=True))
    assert q.n_pending == 0
    flat = [t for w in waves for t in w]
    # every enqueued request scheduled exactly once
    assert sorted(id(t) for t in flat) == sorted(id(t) for t in tickets)
    assert all(t.state == RequestState.SCHEDULED for t in flat)
    # voxel cap respected; only a single oversized request may exceed it
    for w in waves:
        vox = sum(t.request.n_voxels for t in w)
        assert vox <= cap or len(w) == 1
    # priority order with FIFO tiebreak, never skipping within a class
    assert flat == sorted(tickets, key=lambda t: (-t.priority, t.seq))


def test_deadline_is_measured_from_enqueue():
    now = [0.0]
    q = RequestQueue(max_wave_voxels=10 ** 9, max_wait_ms=10.0,
                     clock=lambda: now[0])
    tk = q.submit(_stub(100))
    assert not q.wave_due() and q.form_wave() == []
    now[0] = 0.009
    assert not q.wave_due()  # 9 ms < 10 ms deadline
    now[0] = 0.011
    assert q.wave_due()      # oldest pending ticket hit its deadline
    assert q.form_wave() == [tk]
    assert q.form_wave() == [] and not q.wave_due()  # queue emptied


def test_deadline_promotes_starved_ticket_over_priority():
    """A low-priority ticket past its deadline leads the next wave even
    under sustained higher-priority load — max_wait_ms really bounds every
    request's wait, not just the front-runner's."""
    now = [0.0]
    q = RequestQueue(max_wave_voxels=1024, max_wait_ms=5.0,
                     clock=lambda: now[0])
    big = q.submit(_stub(2000, "big"), priority=0)
    for i in range(4):
        q.submit(_stub(512, f"hp{i}"), priority=1)
    w1 = q.form_wave(flush=True)  # before the deadline, priority wins
    assert big not in w1 and len(w1) == 2
    now[0] = 0.010                # big's deadline expired
    w2 = q.form_wave()
    assert w2 == [big]            # promoted to the front, served alone
    assert len(q.form_wave(flush=True)) == 2  # remaining high-prio pair


def test_voxel_budget_makes_wave_due_immediately():
    q = RequestQueue(max_wave_voxels=256, max_wait_ms=10_000.0)
    q.submit(_stub(200))
    assert not q.wave_due()
    q.submit(_stub(56))
    assert q.wave_due()  # budget reached long before the deadline


def test_no_deadline_means_flush_only():
    q = RequestQueue()  # no cap, no deadline: only drain flushes
    q.submit(_stub(10 ** 6))
    assert not q.wave_due()
    assert q.form_wave() == []
    assert len(q.form_wave(flush=True)) == 1


def test_rejected_requests_never_enter_the_queue():
    q = RequestQueue(validator=lambda r: "nope" if r.n_voxels < 0 else None)
    bad = q.submit(_stub(-1))
    assert bad.state == RequestState.FAILED and bad.error == "nope"
    assert q.n_pending == 0 and q.n_rejected == 1
    ok = q.submit(_stub(5))
    assert ok.state == RequestState.PENDING and q.n_pending == 1


def test_queue_arg_validation():
    with pytest.raises(ValueError, match="max_wave_voxels"):
        RequestQueue(max_wave_voxels=0)
    with pytest.raises(ValueError, match="max_wait_ms"):
        RequestQueue(max_wait_ms=-1.0)


# --------------------------------------------------------------------------
# executor: device-side staging + the one-sync-per-wave contract
# --------------------------------------------------------------------------

def test_executor_stages_padded_pool_on_device():
    params, _, _ = _calibrated_net()
    ex = WaveExecutor(backend="float", params=params, buckets=(64, 128))
    pool, tiles, total = ex.stage([_features(100, 1), _features(30, 2)])
    assert total == 130
    assert tiles == plan_tiles(130, (64, 128))
    padded = tiles[-1][0] + tiles[-1][2]
    assert isinstance(pool, jnp.ndarray) and pool.shape == (padded, ex.in_dim)
    assert np.all(np.asarray(pool)[130:] == 0)  # pad rows are zeros

    handle = ex.dispatch([_features(100, 1), _features(30, 2)])
    assert isinstance(handle, InflightWave)
    assert handle.n_tiles == len(tiles) and handle.total == 130
    pred = handle.wait()
    assert pred.shape == (130, 2)
    # outputs come back already denormalized (ms): the rescale is fused
    # into the jitted forward so retirement never re-touches the device
    want = np.asarray(denormalize_targets(mrf_net.forward(
        params, jnp.concatenate([_features(100, 1), _features(30, 2)]))))
    np.testing.assert_allclose(pred, want, rtol=1e-6)


def test_pipelined_executor_syncs_once_per_wave(monkeypatch):
    """The pipelined path must never host-sync per tile: exactly one
    ``jax.block_until_ready`` per wave, however many tiles the wave has.
    The sync baseline, by contrast, syncs every tile."""
    params, _, _ = _calibrated_net()
    reqs = [ReconRequest(features=_features(300, seed=i), request_id=str(i))
            for i in range(3)]
    n_tiles_per_wave = len(plan_tiles(300, (64, 128, 256)))
    assert n_tiles_per_wave == 2  # 256-tile + padded 64-tile

    def counting_engine(mode):
        eng = ReconEngine(backend="float", params=params, mode=mode,
                          buckets=(64, 128, 256), max_wave_voxels=300)
        eng.reconstruct(reqs)  # warmup: trace outside the counted region
        return eng

    calls = {"n": 0}
    orig = jax.block_until_ready

    def counted(x):
        calls["n"] += 1
        return orig(x)

    for mode, expect in (("pipelined", 3), ("sync", 6)):
        engine = counting_engine(mode)
        for r in reqs:
            engine.enqueue(r)
        calls["n"] = 0
        monkeypatch.setattr(jax, "block_until_ready", counted)
        results = engine.drain()
        monkeypatch.setattr(jax, "block_until_ready", orig)
        assert engine.last_wave["n_waves"] == 3
        assert len(results) == 3
        assert calls["n"] == expect, mode  # waves, not tiles, when pipelined


# --------------------------------------------------------------------------
# engine: pipelined == sync bit-exactness, both backends
# --------------------------------------------------------------------------

@pytest.mark.parametrize("backend", ["float", "int8"])
def test_pipelined_matches_sync_bitexact(backend):
    params, _, ints = _calibrated_net()
    net_kw = ({"params": params} if backend == "float"
              else {"int_layers": ints})
    mask = np.zeros((10, 13), bool)
    mask.flat[3:80] = True
    reqs = [ReconRequest(features=_features(n, seed=n), request_id=str(n),
                         mask=(mask if n == 77 else None))
            for n in (137, 64, 333, 77, 501, 0)]

    sync = ReconEngine(backend=backend, mode="sync", **net_kw)
    pipe = ReconEngine(backend=backend, mode="pipelined",
                       max_wave_voxels=256, **net_kw)
    want = sync.reconstruct(reqs)
    got = pipe.reconstruct(reqs)
    assert pipe.last_wave["n_waves"] > 1  # the trace really was split
    for w, g in zip(want, got):
        assert w.request_id == g.request_id
        assert np.array_equal(w.t1_ms, g.t1_ms)
        assert np.array_equal(w.t2_ms, g.t2_ms)
    # wave splitting must not grow the jit cache past the bucket set
    assert pipe.compile_cache_size() <= len(pipe.buckets)


def test_priority_requests_complete_first():
    params, _, _ = _calibrated_net()
    engine = ReconEngine(backend="float", params=params, mode="pipelined",
                         max_wave_voxels=128)
    engine.reconstruct([ReconRequest(features=_features(128))])  # warmup
    low = engine.enqueue(ReconRequest(features=_features(128, 1),
                                      request_id="low"), priority=0)
    high = engine.enqueue(ReconRequest(features=_features(128, 2),
                                       request_id="high"), priority=5)
    engine.drain()
    assert low.state == high.state == RequestState.DONE
    assert high.done_t <= low.done_t  # scheduled into the earlier wave


# --------------------------------------------------------------------------
# latency: measured from enqueue, not wave start
# --------------------------------------------------------------------------

def test_latency_includes_queue_wait():
    params, _, _ = _calibrated_net()
    engine = ReconEngine(backend="float", params=params)
    engine.reconstruct([ReconRequest(features=_features(64))])  # warmup
    early = engine.enqueue(ReconRequest(features=_features(64, 1)))
    time.sleep(0.05)
    late = engine.enqueue(ReconRequest(features=_features(64, 2)))
    engine.drain()
    # same wave, so the earlier-enqueued request carries the queue wait
    assert early.result.latency_s >= 0.05
    assert early.result.latency_s > late.result.latency_s
    assert early.result.latency_s - late.result.latency_s >= 0.04
    assert early.latency_s == early.result.latency_s


# --------------------------------------------------------------------------
# failures are lifecycle states on the streaming path
# --------------------------------------------------------------------------

def test_streaming_failure_does_not_poison_the_wave():
    params, _, _ = _calibrated_net()
    engine = ReconEngine(backend="float", params=params)
    bad_dim = ReconRequest(features=jnp.zeros((4, 7)), request_id="bad-dim")
    bad_mask = ReconRequest(features=_features(4), request_id="bad-mask",
                            mask=np.ones((3, 3), bool))
    ok = ReconRequest(features=_features(50, 3), request_id="ok")

    t_bad = engine.enqueue(bad_dim)        # admission rejects, no raise
    t_mask = engine.enqueue(bad_mask)
    t_ok = engine.enqueue(ok)
    assert t_bad.state == RequestState.FAILED and "feature dim" in t_bad.error
    assert t_mask.state == RequestState.FAILED and "mask selects" in t_mask.error
    assert engine.queue.n_pending == 1     # only the valid request queued

    results = engine.drain()
    assert t_ok.state == RequestState.DONE and len(results) == 1
    assert engine.last_wave["n_requests"] == 1
    want = np.asarray(denormalize_targets(
        mrf_net.forward(params, ok.features)))
    np.testing.assert_allclose(t_ok.result.t1_ms, want[:, 0], rtol=1e-6)

    # the batch wrapper keeps all-or-nothing semantics: it raises up front,
    # before admitting anything
    with pytest.raises(ValueError, match="feature dim"):
        engine.reconstruct([ok, bad_dim])
    assert engine.queue.n_pending == 0


def test_int_mask_is_validated_on_its_bool_cast():
    """An int mask summing to n_voxels but selecting fewer cells must be
    rejected at admission — validation counts exactly what assembly
    scatters through (the bool cast)."""
    params, _, _ = _calibrated_net()
    engine = ReconEngine(backend="float", params=params)
    tricky = np.zeros((2, 2), np.int64)
    tricky[0, 0] = 2  # sums to 2, bool-selects 1 cell
    req = ReconRequest(features=_features(2), mask=tricky)
    with pytest.raises(ValueError, match="mask selects 1 voxels"):
        engine.reconstruct([req])
    assert engine.enqueue(req).state == RequestState.FAILED


def test_batch_path_raises_on_assembly_failure(monkeypatch):
    """reconstruct() must never hand back a silent None: if assembly fails
    mid-wave, the wave completes for everyone else, then it raises with
    the underlying error (the streaming path keeps the failed ticket)."""
    params, _, _ = _calibrated_net()
    engine = ReconEngine(backend="float", params=params)
    orig = ReconEngine._assemble

    def flaky(self, req, pred, latency):
        if req.request_id == "boom":
            raise RuntimeError("synthetic assembly failure")
        return orig(self, req, pred, latency)

    monkeypatch.setattr(ReconEngine, "_assemble", flaky)
    good = ReconRequest(features=_features(40, 1), request_id="good")
    boom = ReconRequest(features=_features(30, 2), request_id="boom")
    with pytest.raises(ValueError, match="synthetic assembly failure"):
        engine.reconstruct([boom, good])
    # streaming path: same failure stays a lifecycle state, wave-mates fine
    t_boom, t_good = engine.enqueue(boom), engine.enqueue(good)
    results = engine.drain()
    assert t_boom.state == RequestState.FAILED
    assert "synthetic assembly failure" in t_boom.error
    assert t_good.state == RequestState.DONE and len(results) == 1
    assert engine.last_wave["n_failed"] == 1


def test_non_array_features_and_crashing_validator_never_raise():
    """Admission absorbs even type-level garbage: a features list (no
    .shape) and a validator that itself crashes both yield failed tickets,
    not exceptions out of enqueue()."""
    params, _, _ = _calibrated_net()
    engine = ReconEngine(backend="float", params=params)
    t = engine.enqueue(ReconRequest(features=[[0.1] * 32], request_id="ls"))
    assert t.state == RequestState.FAILED and "must be an array" in t.error
    q = RequestQueue(validator=lambda r: r.no_such_attr)
    t2 = q.submit(_stub(4))
    assert t2.state == RequestState.FAILED
    assert "validator error" in t2.error and q.n_pending == 0
    # validator-less queue fed a request without usable n_voxels: same deal
    t3 = RequestQueue().submit(types.SimpleNamespace(request_id="x"))
    assert t3.state == RequestState.FAILED and "n_voxels" in t3.error


def test_malformed_rank_rejected_at_admission():
    params, _, _ = _calibrated_net()
    engine = ReconEngine(backend="float", params=params)
    bad = ReconRequest(features=jnp.zeros((4, 3, 2 * N_FRAMES)),
                       request_id="rank3")
    t = engine.enqueue(bad)
    assert t.state == RequestState.FAILED and "rank-2" in t.error
    with pytest.raises(ValueError, match="rank-2"):
        engine.reconstruct([bad])


def test_execution_failure_fails_the_wave_not_the_drain(monkeypatch):
    """A device-side error during wave *execution* (after dispatch) must
    also end as failed tickets — never an exception out of drain() leaving
    popped tickets stranded in 'scheduled'."""
    params, _, _ = _calibrated_net()
    engine = ReconEngine(backend="float", params=params, mode="pipelined")
    monkeypatch.setattr(InflightWave, "wait",
                        lambda self: (_ for _ in ()).throw(
                            RuntimeError("synthetic device failure")))
    t = engine.enqueue(ReconRequest(features=_features(10, 1)))
    results = engine.drain()
    assert results == [] and len(engine._inflight) == 0
    assert t.state == RequestState.FAILED
    assert "synthetic device failure" in t.error
    assert engine.last_wave["n_failed"] == 1


def test_dispatch_failure_fails_the_wave_not_the_drain(monkeypatch):
    """If the executor cannot stage a wave, its tickets end 'failed' with
    the error attached — drain() never raises and never strands tickets
    in 'scheduled'."""
    params, _, _ = _calibrated_net()
    engine = ReconEngine(backend="float", params=params)
    monkeypatch.setattr(engine.executor, "dispatch",
                        lambda feats, **kw: (_ for _ in ()).throw(
                            RuntimeError("synthetic stage failure")))
    t1 = engine.enqueue(ReconRequest(features=_features(10, 1)))
    t2 = engine.enqueue(ReconRequest(features=_features(20, 2)))
    results = engine.drain()
    assert results == []
    assert t1.state == t2.state == RequestState.FAILED
    assert "synthetic stage failure" in t1.error
    assert engine.last_wave["n_failed"] == 2


def test_streaming_poll_then_drain_serves_everything_once():
    params, _, _ = _calibrated_net()
    engine = ReconEngine(backend="float", params=params, mode="pipelined",
                         max_wave_voxels=256, max_wait_ms=0.0)
    engine.reconstruct([ReconRequest(features=_features(256))])  # warmup
    tickets = []
    for i, n in enumerate((100, 250, 64, 300, 0)):
        tickets.append(engine.enqueue(
            ReconRequest(features=_features(n, seed=10 + i),
                         request_id=f"s{i}")))
        engine.poll()  # deadline 0 ms: dispatch whatever is pending
    results = engine.drain()
    assert all(t.state == RequestState.DONE for t in tickets)
    assert sum(t.result.n_voxels for t in tickets) == 714
    # drain returns its own waves' results (poll-retired ones live on the
    # tickets the caller holds — never retained by the engine), but the
    # session stats must account for every served request
    ticket_results = {id(t.result) for t in tickets}
    assert results and all(id(r) in ticket_results for r in results)
    assert engine.last_wave["n_requests"] == len(tickets)
    assert engine.last_wave["total_voxels"] == 714
    solo = ReconEngine(backend="float", params=params)
    for t in tickets:
        want, = solo.reconstruct([t.request])
        assert np.array_equal(t.result.t1_ms, want.t1_ms)
        assert np.array_equal(t.result.t2_ms, want.t2_ms)
