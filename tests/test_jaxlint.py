"""Tier-1 tests for repro.tools.jaxlint.

Three layers:

* the repo gate — ``src/`` plus the extra scan dirs must lint clean
  (zero unsuppressed findings; every pragma carries a reason), same
  contract CI enforces via ``scripts/check_lints.py``, and the
  dead-exports allowlist must gate clean;
* golden fixtures — one positive and one negative snippet per rule under
  ``tests/fixtures/jaxlint/``.  Positive fixtures mark every expected
  finding line with a ``# FINDING`` comment, and the test asserts the
  analyzer reports exactly those lines (no more, no fewer) — across ALL
  rules, so a fixture written for one rule cannot silently trip another;
* project fixtures — mini-repos under ``tests/fixtures/jaxlint/project/``
  whose marked findings only exist interprocedurally: the per-file v1
  view provably misses them, ``lint_project`` catches them.  The cache
  and SARIF layers are tested on the same mini-repos.
"""

import pathlib

import pytest

from repro.tools.jaxlint import (PRAGMA_RULE, RULES, available_rules,
                                 lint_repo, lint_source, parse_pragmas)
from repro.tools.jaxlint.core import Finding, LintConfig, lint_project
from repro.tools.jaxlint.deadexports import (dead_exports,
                                             dead_exports_gate,
                                             parse_allowlist)
from repro.tools.jaxlint.sarif import sarif_report

REPO_ROOT = pathlib.Path(__file__).resolve().parents[1]
FIXTURES = REPO_ROOT / "tests" / "fixtures" / "jaxlint"
ALLOWLIST = REPO_ROOT / "scripts" / "dead_exports_allowlist.txt"

#: fixture stem -> path the snippet pretends to live at (rules key off it);
#: full-stem entries win over per-rule ones
PRETEND_PATHS = {
    "hostsync": "src/repro/ft/runner.py",
    "hostsync_neg": "src/repro/serve/executor.py",  # its allowlist home
    "tracerbranch": "src/repro/models/net.py",
    "donate": "src/repro/models/loops.py",  # outside the SHARD domain
    "shard": "src/repro/serve/steps.py",
    "pallastile": "src/repro/kernels/fix/kernel.py",
    "keyreuse": "src/repro/models/rng.py",
    "recompile": "src/repro/models/jits.py",
    "scancarry": "src/repro/models/sweeps.py",
}


def fixture_source(name: str) -> str:
    return (FIXTURES / f"{name}.py").read_text()


def marked_lines(source: str) -> list[int]:
    return [i for i, text in enumerate(source.splitlines(), start=1)
            if "# FINDING" in text]


def lint_fixture(name: str, path: str | None = None) -> list[Finding]:
    rule = name.rsplit("_", 1)[0]
    path = path or PRETEND_PATHS.get(name) \
        or PRETEND_PATHS.get(rule, "src/repro/ft/runner.py")
    return lint_source(fixture_source(name), path)


# --- the repo gate ---------------------------------------------------------

def test_src_lints_clean():
    findings = lint_repo(REPO_ROOT)
    assert findings == [], "\n".join(f.key for f in findings)


# --- golden fixtures, one pair per rule ------------------------------------

@pytest.mark.parametrize("rule", sorted(RULES))
def test_every_rule_has_fixture_pair(rule):
    stem = rule.lower()
    assert (FIXTURES / f"{stem}_pos.py").is_file()
    assert (FIXTURES / f"{stem}_neg.py").is_file()


@pytest.mark.parametrize("rule", sorted(RULES))
def test_positive_fixture_hits_marked_lines(rule):
    name = f"{rule.lower()}_pos"
    source = fixture_source(name)
    expected = marked_lines(source)
    assert expected, f"{name}.py has no # FINDING markers"
    findings = lint_fixture(name)
    assert all(f.rule == rule for f in findings), findings
    assert sorted(f.line for f in findings) == expected, findings


@pytest.mark.parametrize("rule", sorted(RULES))
def test_negative_fixture_is_clean(rule):
    assert lint_fixture(f"{rule.lower()}_neg") == []


def test_rules_are_path_scoped():
    # the same offending source is silent outside the rule's domain
    for name, other in [("hostsync_pos", "src/repro/models/net.py"),
                        ("shard_pos", "src/repro/models/net.py"),
                        ("pallastile_pos", "src/repro/serve/helpers.py")]:
        assert lint_fixture(name, other) == []


def test_pallastile_covers_multistep_kernel_files():
    """The multi-step training kernels live in multistep.py — the rule must
    audit that suffix like kernel.py/fused.py (and stay path-scoped)."""
    src = ("from jax.experimental import pallas as pl\n"
           "spec = pl.BlockSpec((8, 100), lambda i: (i, 0))\n")
    findings = lint_source(src, "src/repro/kernels/fused_train/multistep.py")
    assert [f.rule for f in findings] == ["PALLASTILE"]
    # same name outside the kernels tree stays out of the rule's domain
    assert lint_source(src, "src/repro/train/multistep.py") == []


_MOMENT_SCRATCH_CALL = """
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

L = 90  # 90*128*128*4B = 5.6 MiB per stack

def launch(kern, x):
    return pl.pallas_call(
        kern,
        grid=(4,),
        in_specs=[pl.BlockSpec((8, 128), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((8, 128), lambda i: (i, 0)),
        scratch_shapes=[
            pltpu.VMEM((L, 128, 128), jnp.float32),   # weights
{moments}        ],
    )(x)
"""


def test_pallastile_vmem_estimate_counts_moment_scratch():
    """The in-kernel Adam rides mu/nu stacks as extra VMEM scratch: the
    VMEM estimate must include them — weights alone fit the budget, weights
    + both moment stacks do not."""
    path = "src/repro/kernels/fused_train/multistep.py"
    moments = ("            pltpu.VMEM((L, 128, 128), jnp.float32),   # mu\n"
               "            pltpu.VMEM((L, 128, 128), jnp.float32),   # nu\n")
    over = lint_source(_MOMENT_SCRATCH_CALL.format(moments=moments), path)
    assert [f.rule for f in over] == ["PALLASTILE"]
    assert "VMEM footprint" in over[0].message
    assert lint_source(_MOMENT_SCRATCH_CALL.format(moments=""), path) == []


# --- pragmas ---------------------------------------------------------------

def test_reasoned_pragma_suppresses():
    assert lint_fixture("pragma_ok") == []


def test_reasonless_pragma_is_inert_and_reported():
    findings = lint_fixture("pragma_noreason")
    assert sorted(f.rule for f in findings) == ["HOSTSYNC", PRAGMA_RULE]


def test_unknown_rule_pragma_is_reported():
    src = "x = 1  # jaxlint: disable=NOSUCHRULE -- because\n"
    findings = lint_source(src, "src/repro/models/net.py")
    assert [f.rule for f in findings] == [PRAGMA_RULE]
    assert "NOSUCHRULE" in findings[0].message


def test_multi_rule_pragma():
    src = "y = f(x)  # jaxlint: disable=HOSTSYNC, SHARD -- shared reason\n"
    suppress, problems = parse_pragmas(src, "p.py")
    assert suppress == {1: {"HOSTSYNC", "SHARD"}}
    assert problems == []


def test_pragma_rule_is_not_suppressible():
    # a reasonless pragma cannot be silenced by another pragma on its line
    src = ("import jax\n\n\ndef f(state):\n"
           "    jax.block_until_ready(state)"
           "  # jaxlint: disable=HOSTSYNC, PRAGMA\n    return state\n")
    findings = lint_source(src, "src/repro/ft/runner.py")
    assert PRAGMA_RULE in {f.rule for f in findings}


# --- registry + output formats ---------------------------------------------

def test_registry_has_the_contract_rules():
    names = set(available_rules())
    assert {"HOSTSYNC", "TRACERBRANCH", "DONATE", "SHARD", "PALLASTILE",
            "KEYREUSE", "RECOMPILE", "SCANCARRY"} <= names
    assert all(n == n.upper() for n in names)


def test_github_annotation_format():
    f = Finding(path="src/repro/x.py", line=7, rule="HOSTSYNC", message="m")
    assert f.github() == ("::error file=src/repro/x.py,line=7,"
                          "title=jaxlint HOSTSYNC::m")
    assert f.key == "src/repro/x.py:7 HOSTSYNC m"


def test_syntax_error_is_a_finding():
    findings = lint_source("def broken(:\n", "src/repro/models/net.py")
    assert [f.rule for f in findings] == ["SYNTAX"]


# --- dead-exports report ---------------------------------------------------

def test_dead_exports_on_synthetic_repo(tmp_path):
    pkg = tmp_path / "src" / "repro"
    pkg.mkdir(parents=True)
    (pkg / "alpha.py").write_text(
        "def used():\n    return 1\n\n\ndef dormant():\n    return 2\n")
    (pkg / "beta.py").write_text(
        "from repro.alpha import used\n\nVALUE = used()\n")
    dead = dead_exports(tmp_path)
    names = {n for _m, n, _l in dead["symbols"]}
    assert "dormant" in names
    assert "used" not in names
    assert "VALUE" in names            # beta's constant is referenced nowhere
    assert "repro.beta" in dead["modules"]
    assert "repro.alpha" not in dead["modules"]


def test_dead_exports_smoke_on_this_repo():
    dead = dead_exports(REPO_ROOT)
    assert set(dead) == {"symbols", "modules"}
    # identifier-based usage: anything this very test references is alive
    assert all(n != "dead_exports" for _m, n, _l in dead["symbols"])


# --- the whole-fixture property: markers exact, negatives clean, ALL rules -

@pytest.mark.parametrize(
    "name", sorted(p.stem for p in FIXTURES.glob("*_pos.py")))
def test_every_pos_fixture_markers_are_exact(name):
    source = fixture_source(name)
    expected = marked_lines(source)
    assert expected, f"{name}.py has no # FINDING markers"
    findings = lint_fixture(name)
    assert sorted(f.line for f in findings) == expected, findings


@pytest.mark.parametrize(
    "name", sorted(p.stem for p in FIXTURES.glob("*_neg.py")))
def test_every_neg_fixture_is_clean_under_all_rules(name):
    assert lint_fixture(name) == []


# --- pragma extensions: multiple pragmas / mixed known-unknown -------------

def test_two_pragmas_on_one_line():
    src = ("y = g(x)  # jaxlint: disable=HOSTSYNC -- io boundary "
           "# jaxlint: disable=SHARD -- delegate\n")
    suppress, problems = parse_pragmas(src, "p.py")
    assert suppress == {1: {"HOSTSYNC", "SHARD"}}
    assert problems == []


def test_multi_rule_pragma_with_unknown_name_keeps_known():
    src = "y = g(x)  # jaxlint: disable=HOSTSYNC,BOGUS -- reason\n"
    suppress, problems = parse_pragmas(src, "p.py")
    assert suppress == {1: {"HOSTSYNC"}}
    assert [p.rule for p in problems] == [PRAGMA_RULE]
    assert "BOGUS" in problems[0].message


def test_multi_rule_pragma_suppresses_both_rules_end_to_end():
    src = ("import jax\n\n\ndef loop(f, xs):\n"
           "    key = jax.random.PRNGKey(0)\n"
           "    for x in xs:\n"
           "        y = jax.jit(f)(jax.random.normal(key, (2,)))"
           "  # jaxlint: disable=RECOMPILE,KEYREUSE -- demo code\n"
           "    return y\n")
    assert lint_source(src, "src/repro/models/demo.py") == []


# --- interprocedural project fixtures --------------------------------------

PROJECT_CASES = {
    "xtaint": "TRACERBRANCH",
    "xdonate": "DONATE",
    "xshard": "SHARD",
    "xhostsync": "HOSTSYNC",
    "xpallastile": "PALLASTILE",
}


def project_fixture(case: str) -> dict[str, str]:
    base = FIXTURES / "project" / case
    return {p.relative_to(base).as_posix(): p.read_text()
            for p in sorted(base.rglob("*.py"))}


@pytest.mark.parametrize("case,rule", sorted(PROJECT_CASES.items()))
def test_project_pass_catches_what_per_file_missed(case, rule):
    files = project_fixture(case)
    expected = {(p, i) for p, src in files.items()
                for i in marked_lines(src)}
    assert expected, f"project/{case} has no # FINDING markers"
    # v1 per-file view: every marked finding is invisible
    v1 = [f for p, src in files.items() for f in lint_source(src, p)]
    assert not ({(f.path, f.line) for f in v1} & expected), v1
    # v2 whole-program view: exactly the marked findings, right rule
    v2 = lint_project(files).findings
    assert {(f.path, f.line) for f in v2} == expected, v2
    assert all(f.rule == rule for f in v2), v2


def test_shard_project_pass_removes_per_file_false_positive():
    files = project_fixture("xshard")
    front = "src/repro/serve/front.py"
    v1 = lint_source(files[front], front)
    assert [f.rule for f in v1] == ["SHARD"]  # v1 false positive
    v2 = lint_project(files).findings
    assert all(f.path != front for f in v2)   # resolved cross-module


def test_project_findings_attributed_to_origin_files():
    # attribution discipline: the callee file carries no findings, so its
    # cached (empty) result stays valid when only callers change
    for case in PROJECT_CASES:
        files = project_fixture(case)
        marked_files = {p for p, src in files.items() if marked_lines(src)}
        for f in lint_project(files).findings:
            assert f.path in marked_files, (case, f)


# --- incremental cache ------------------------------------------------------

CACHE_FILES = {
    "src/repro/models/aa.py": "def helper(v):\n    return v\n",
    "src/repro/models/bb.py": ("from repro.models.aa import helper\n\n\n"
                               "def use(x):\n    return helper(x)\n"),
    "src/repro/models/cc.py": "Z = 1\n",
}


def test_cache_cold_then_warm(tmp_path):
    cache = tmp_path / "cache.json"
    r1 = lint_project(dict(CACHE_FILES), cache_path=cache)
    assert (r1.stats.analyzed, r1.stats.reused) == (3, 0)
    r2 = lint_project(dict(CACHE_FILES), cache_path=cache)
    assert (r2.stats.analyzed, r2.stats.reused) == (0, 3)
    assert "0/3" in r2.stats.line() and "3 from cache" in r2.stats.line()


def test_cache_edit_invalidates_importers_only(tmp_path):
    cache = tmp_path / "cache.json"
    lint_project(dict(CACHE_FILES), cache_path=cache)
    edited = dict(CACHE_FILES)
    edited["src/repro/models/aa.py"] += "\nX = 2\n"
    r = lint_project(edited, cache_path=cache)
    # aa (changed) + bb (imports aa) re-analyzed; cc untouched
    assert (r.stats.analyzed, r.stats.reused) == (2, 1)


def test_cache_preserves_cross_module_findings(tmp_path):
    cache = tmp_path / "cache.json"
    files = project_fixture("xtaint")
    cold = lint_project(files, cache_path=cache)
    warm = lint_project(files, cache_path=cache)
    assert cold.findings and warm.findings == cold.findings
    assert warm.stats.analyzed == 0


def test_cache_invalidates_on_config_change(tmp_path):
    cache = tmp_path / "cache.json"
    lint_project(dict(CACHE_FILES), cache_path=cache)
    r = lint_project(dict(CACHE_FILES), cache_path=cache,
                     config=LintConfig(max_call_depth=2))
    assert r.stats.analyzed == 3  # different fingerprint: full re-analysis


def test_parallel_jobs_match_serial():
    files = project_fixture("xtaint")
    assert lint_project(files, jobs=2).findings == \
        lint_project(files).findings


# --- SARIF ------------------------------------------------------------------

def test_sarif_schema_shape():
    doc = sarif_report([Finding("src/repro/x.py", 3, "HOSTSYNC", "m")])
    assert doc["version"] == "2.1.0"
    assert doc["$schema"].endswith("sarif-schema-2.1.0.json")
    run = doc["runs"][0]
    ids = [r["id"] for r in run["tool"]["driver"]["rules"]]
    assert ids == sorted(ids)
    assert {"HOSTSYNC", "PRAGMA", "SYNTAX", "KEYREUSE"} <= set(ids)
    (res,) = run["results"]
    assert res["ruleId"] == "HOSTSYNC" and res["level"] == "error"
    assert run["tool"]["driver"]["rules"][res["ruleIndex"]]["id"] == \
        "HOSTSYNC"
    loc = res["locations"][0]["physicalLocation"]
    assert loc["artifactLocation"]["uri"] == "src/repro/x.py"
    assert loc["region"]["startLine"] == 3


# --- dead-exports gate ------------------------------------------------------

def test_dead_exports_gate_semantics(tmp_path):
    pkg = tmp_path / "src" / "repro"
    pkg.mkdir(parents=True)
    (pkg / "mod.py").write_text("def dormant():\n    return 1\n")
    allow = tmp_path / "allow.txt"

    allow.write_text("# nothing allowlisted\n")
    lines, code = dead_exports_gate(tmp_path, allow)
    assert code == 1 and any("repro.mod.dormant" in ln for ln in lines)

    allow.write_text("repro.mod.dormant -- parked for the next PR\n"
                     "module:repro.mod -- parked for the next PR\n")
    lines, code = dead_exports_gate(tmp_path, allow)
    assert code == 0, lines

    allow.write_text("repro.mod.dormant -- parked\n"
                     "module:repro.mod -- parked\n"
                     "repro.mod.gone -- no longer exists\n")
    lines, code = dead_exports_gate(tmp_path, allow)
    assert code == 1 and any("stale" in ln for ln in lines)

    allow.write_text("repro.mod.dormant\nmodule:repro.mod -- parked\n")
    lines, code = dead_exports_gate(tmp_path, allow)
    assert code == 1 and any("no reason" in ln for ln in lines)


def test_allowlist_parser_reads_reasons(tmp_path):
    f = tmp_path / "a.txt"
    f.write_text("# comment\n\nrepro.a.b -- why it stays\n")
    entries, problems = parse_allowlist(f)
    assert entries == {"repro.a.b": "why it stays"} and problems == []


def test_dead_exports_gate_is_clean_on_this_repo():
    lines, code = dead_exports_gate(REPO_ROOT, ALLOWLIST)
    assert code == 0, "\n".join(lines)


# --- repo scan coverage -----------------------------------------------------

def test_repo_scan_covers_extra_dirs():
    from repro.tools.jaxlint.core import iter_repo_files
    tops = {p.relative_to(REPO_ROOT).parts[0]
            for p in iter_repo_files(REPO_ROOT)}
    assert {"src", "benchmarks", "examples", "scripts"} <= tops
