"""Tier-1 tests for repro.tools.jaxlint.

Two layers:

* the repo gate — ``src/`` must lint clean (zero unsuppressed findings;
  every pragma carries a reason), same contract CI enforces via
  ``scripts/check_lints.py``;
* golden fixtures — one positive and one negative snippet per rule under
  ``tests/fixtures/jaxlint/``.  Positive fixtures mark every expected
  finding line with a ``# FINDING`` comment, and the test asserts the
  analyzer reports exactly those lines (no more, no fewer).
"""

import pathlib

import pytest

from repro.tools.jaxlint import (PRAGMA_RULE, RULES, available_rules,
                                 lint_repo, lint_source, parse_pragmas)
from repro.tools.jaxlint.core import Finding
from repro.tools.jaxlint.deadexports import dead_exports

REPO_ROOT = pathlib.Path(__file__).resolve().parents[1]
FIXTURES = REPO_ROOT / "tests" / "fixtures" / "jaxlint"

#: fixture stem -> path the snippet pretends to live at (rules key off it);
#: full-stem entries win over per-rule ones
PRETEND_PATHS = {
    "hostsync": "src/repro/ft/runner.py",
    "hostsync_neg": "src/repro/serve/executor.py",  # its allowlist home
    "tracerbranch": "src/repro/models/net.py",
    "donate": "src/repro/models/loops.py",  # outside the SHARD domain
    "shard": "src/repro/serve/steps.py",
    "pallastile": "src/repro/kernels/fix/kernel.py",
}


def fixture_source(name: str) -> str:
    return (FIXTURES / f"{name}.py").read_text()


def marked_lines(source: str) -> list[int]:
    return [i for i, text in enumerate(source.splitlines(), start=1)
            if "# FINDING" in text]


def lint_fixture(name: str, path: str | None = None) -> list[Finding]:
    rule = name.rsplit("_", 1)[0]
    path = path or PRETEND_PATHS.get(name) \
        or PRETEND_PATHS.get(rule, "src/repro/ft/runner.py")
    return lint_source(fixture_source(name), path)


# --- the repo gate ---------------------------------------------------------

def test_src_lints_clean():
    findings = lint_repo(REPO_ROOT)
    assert findings == [], "\n".join(f.key for f in findings)


# --- golden fixtures, one pair per rule ------------------------------------

@pytest.mark.parametrize("rule", sorted(RULES))
def test_every_rule_has_fixture_pair(rule):
    stem = rule.lower()
    assert (FIXTURES / f"{stem}_pos.py").is_file()
    assert (FIXTURES / f"{stem}_neg.py").is_file()


@pytest.mark.parametrize("rule", sorted(RULES))
def test_positive_fixture_hits_marked_lines(rule):
    name = f"{rule.lower()}_pos"
    source = fixture_source(name)
    expected = marked_lines(source)
    assert expected, f"{name}.py has no # FINDING markers"
    findings = lint_fixture(name)
    assert all(f.rule == rule for f in findings), findings
    assert sorted(f.line for f in findings) == expected, findings


@pytest.mark.parametrize("rule", sorted(RULES))
def test_negative_fixture_is_clean(rule):
    assert lint_fixture(f"{rule.lower()}_neg") == []


def test_rules_are_path_scoped():
    # the same offending source is silent outside the rule's domain
    for name, other in [("hostsync_pos", "src/repro/models/net.py"),
                        ("shard_pos", "src/repro/models/net.py"),
                        ("pallastile_pos", "src/repro/serve/helpers.py")]:
        assert lint_fixture(name, other) == []


# --- pragmas ---------------------------------------------------------------

def test_reasoned_pragma_suppresses():
    assert lint_fixture("pragma_ok") == []


def test_reasonless_pragma_is_inert_and_reported():
    findings = lint_fixture("pragma_noreason")
    assert sorted(f.rule for f in findings) == ["HOSTSYNC", PRAGMA_RULE]


def test_unknown_rule_pragma_is_reported():
    src = "x = 1  # jaxlint: disable=NOSUCHRULE -- because\n"
    findings = lint_source(src, "src/repro/models/net.py")
    assert [f.rule for f in findings] == [PRAGMA_RULE]
    assert "NOSUCHRULE" in findings[0].message


def test_multi_rule_pragma():
    src = "y = f(x)  # jaxlint: disable=HOSTSYNC, SHARD -- shared reason\n"
    suppress, problems = parse_pragmas(src, "p.py")
    assert suppress == {1: {"HOSTSYNC", "SHARD"}}
    assert problems == []


def test_pragma_rule_is_not_suppressible():
    # a reasonless pragma cannot be silenced by another pragma on its line
    src = ("import jax\n\n\ndef f(state):\n"
           "    jax.block_until_ready(state)"
           "  # jaxlint: disable=HOSTSYNC, PRAGMA\n    return state\n")
    findings = lint_source(src, "src/repro/ft/runner.py")
    assert PRAGMA_RULE in {f.rule for f in findings}


# --- registry + output formats ---------------------------------------------

def test_registry_has_the_contract_rules():
    names = set(available_rules())
    assert {"HOSTSYNC", "TRACERBRANCH", "DONATE", "SHARD",
            "PALLASTILE"} <= names
    assert all(n == n.upper() for n in names)


def test_github_annotation_format():
    f = Finding(path="src/repro/x.py", line=7, rule="HOSTSYNC", message="m")
    assert f.github() == ("::error file=src/repro/x.py,line=7,"
                          "title=jaxlint HOSTSYNC::m")
    assert f.key == "src/repro/x.py:7 HOSTSYNC m"


def test_syntax_error_is_a_finding():
    findings = lint_source("def broken(:\n", "src/repro/models/net.py")
    assert [f.rule for f in findings] == ["SYNTAX"]


# --- dead-exports report ---------------------------------------------------

def test_dead_exports_on_synthetic_repo(tmp_path):
    pkg = tmp_path / "src" / "repro"
    pkg.mkdir(parents=True)
    (pkg / "alpha.py").write_text(
        "def used():\n    return 1\n\n\ndef dormant():\n    return 2\n")
    (pkg / "beta.py").write_text(
        "from repro.alpha import used\n\nVALUE = used()\n")
    dead = dead_exports(tmp_path)
    names = {n for _m, n, _l in dead["symbols"]}
    assert "dormant" in names
    assert "used" not in names
    assert "VALUE" in names            # beta's constant is referenced nowhere
    assert "repro.beta" in dead["modules"]
    assert "repro.alpha" not in dead["modules"]


def test_dead_exports_smoke_on_this_repo():
    dead = dead_exports(REPO_ROOT)
    assert set(dead) == {"symbols", "modules"}
    # identifier-based usage: anything this very test references is alive
    assert all(n != "dead_exports" for _m, n, _l in dead["symbols"])
