"""Correctness of the §Perf optimization levers: they must change the
schedule, never the math."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke
from repro.models import attention as A
from repro.models import registry

jax.config.update("jax_platform_name", "cpu")


# --------------------------------------------------------------------------
# banded SWA attention == masked-full attention (lever B)
# --------------------------------------------------------------------------

@pytest.mark.parametrize("window,q_chunk", [(8, 8), (12, 4), (16, 8)])
def test_banded_equals_masked_full(window, q_chunk):
    key = jax.random.PRNGKey(0)
    b, s, hq, hkv, dh = 2, 64, 4, 2, 8
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (b, s, hq, dh))
    k = jax.random.normal(ks[1], (b, s, hkv, dh))
    v = jax.random.normal(ks[2], (b, s, hkv, dh))
    banded = A._attention_banded(q, k, v, window=window, q_chunk=q_chunk)
    full = A.attention(q, k, v, causal=True, window=window, q_chunk=s)
    np.testing.assert_allclose(banded, full, rtol=1e-4, atol=1e-5)


def test_banded_dispatch_condition():
    """attention() auto-routes to the banded path only when profitable."""
    key = jax.random.PRNGKey(1)
    b, s, h, dh = 1, 4096, 2, 8
    q = jax.random.normal(key, (b, s, h, dh))
    k = jax.random.normal(key, (b, s, h, dh))
    v = jax.random.normal(key, (b, s, h, dh))
    out_band = A.attention(q, k, v, causal=True, window=64, q_chunk=128)
    out_full = A.attention(q, k, v, causal=True, window=64, q_chunk=s)
    np.testing.assert_allclose(out_band, out_full, rtol=1e-4, atol=1e-5)


# --------------------------------------------------------------------------
# parallel block / SP / remat: train step still finite + grads flow (lever A)
# --------------------------------------------------------------------------

@pytest.mark.parametrize("opts", [
    {"parallel_block": True},
    {"parallel_block": True, "quant": "int8-hlo"},
    {"remat": "save_attn"},
])
def test_lever_configs_train(opts):
    cfg = dataclasses.replace(get_smoke("tinyllama-1.1b"), **opts)
    fns = registry.build(cfg, tp=1)
    key = jax.random.PRNGKey(0)
    params = fns.init(key)
    tokens = jax.random.randint(key, (2, 16), 0, cfg.vocab_size, jnp.int32)
    loss, grads = jax.value_and_grad(fns.loss)(params, {"tokens": tokens,
                                                        "labels": tokens})
    assert jnp.isfinite(loss)
    assert all(jnp.all(jnp.isfinite(g)) for g in jax.tree.leaves(grads))


def test_int8_hlo_close_to_float():
    """int8 forward dots approximate the float forward (QAT deployment)."""
    cfg = get_smoke("tinyllama-1.1b")
    cfg8 = dataclasses.replace(cfg, quant="int8-hlo")
    fns = registry.build(cfg, tp=1)
    fns8 = registry.build(cfg8, tp=1)
    key = jax.random.PRNGKey(0)
    params = fns.init(key)
    tokens = jax.random.randint(key, (2, 16), 0, cfg.vocab_size, jnp.int32)
    batch = {"tokens": tokens, "labels": tokens}
    l_f, l_q = fns.loss(params, batch), fns8.loss(params, batch)
    assert abs(float(l_f) - float(l_q)) < 0.1 * float(l_f)


# --------------------------------------------------------------------------
# decode unroll == scanned decode (extra lever)
# --------------------------------------------------------------------------

@pytest.mark.parametrize("arch", ["tinyllama-1.1b", "deepseek-moe-16b"])
def test_decode_unroll_matches_scan(arch):
    cfg = get_smoke(arch)
    cfg_u = dataclasses.replace(cfg, decode_unroll=True)
    key = jax.random.PRNGKey(0)
    fns = registry.build(cfg, tp=1)
    fns_u = registry.build(cfg_u, tp=1)
    params = fns.init(key)  # identical params for both paths
    S = 16
    tokens = jax.random.randint(key, (2, S + 1), 0, cfg.vocab_size, jnp.int32)
    batch = {"tokens": tokens[:, :S]}
    cache, _ = fns.prefill(params, batch)
    cache_u, _ = fns_u.prefill(params, batch)
    lg, _ = fns.decode(params, cache, tokens[:, S], jnp.int32(S))
    lg_u, _ = fns_u.decode(params, cache_u, tokens[:, S], jnp.int32(S))
    np.testing.assert_allclose(lg.astype(np.float32), lg_u.astype(np.float32),
                               rtol=2e-2, atol=2e-2)
