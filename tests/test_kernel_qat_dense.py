"""int8 dense kernel vs pure-jnp oracle: BIT-EXACT on integer outputs
(the paper's FPGA-vs-Python criterion), exact fp32 on the float head.
Shapes/dtypes swept with hypothesis."""

import jax
import jax.numpy as jnp
import pytest
from _hypothesis_fallback import given, settings, strategies as st

from repro.core import mrf_net, qat
from repro.kernels.qat_dense import ops, ref

jax.config.update("jax_platform_name", "cpu")


def _rand_case(m, k, n, seed):
    kx, kw, kb, ks = jax.random.split(jax.random.PRNGKey(seed), 4)
    x = jax.random.randint(kx, (m, k), -128, 128, jnp.int8)
    w = jax.random.randint(kw, (k, n), -128, 128, jnp.int8)
    b = jax.random.randint(kb, (n,), -2048, 2048, jnp.int32)
    s = jax.random.uniform(ks, (n,), jnp.float32, 1e-4, 1e-2)
    return x, w, b, s


@pytest.mark.parametrize("mkn", [(8, 64, 32), (130, 200, 300), (1, 64, 2), (256, 256, 128)])
@pytest.mark.parametrize("relu,float_out", [(True, False), (False, False), (False, True)])
def test_bitexact_vs_oracle(mkn, relu, float_out):
    x, w, b, s = _rand_case(*mkn, seed=hash(mkn) % 100)
    got = ops.qat_dense(x, w, b, s, relu=relu, float_out=float_out)
    want = ref.ref_qat_dense(x, w, b, s, relu=relu, float_out=float_out)
    if float_out:
        assert jnp.array_equal(got, want)
    else:
        assert bool(jnp.all(got == want)), "integer outputs must be bit-exact"


@settings(max_examples=12, deadline=None)
@given(m=st.integers(1, 80), k=st.integers(1, 160), n=st.integers(1, 160),
       relu=st.booleans(), seed=st.integers(0, 2**16))
def test_property_bitexact(m, k, n, relu, seed):
    x, w, b, s = _rand_case(m, k, n, seed)
    got = ops.qat_dense(x, w, b, s, relu=relu, float_out=False, block=64)
    want = ref.ref_qat_dense(x, w, b, s, relu=relu, float_out=False)
    assert bool(jnp.all(got == want))


def test_full_integer_network_paths_agree():
    """QAT export -> software integer oracle == Pallas integer network."""
    sizes = mrf_net.layer_sizes(32)
    params = mrf_net.init_params(jax.random.PRNGKey(1), sizes)
    qs = qat.init_qat_state(len(params))
    x = jax.random.normal(jax.random.PRNGKey(2), (32, sizes[0]))
    for _ in range(5):
        _, qs = qat.forward_qat(params, qs, x)
    ints = qat.export_int8(params, qs)
    y_sw = qat.int_forward(ints, x)
    y_pl = ops.int_forward_pallas(ints, x)
    assert jnp.array_equal(y_sw, y_pl)


def test_int_node_bitexact():
    """Paper §2.2: the single-node function on the accelerator must equal the
    software implementation exactly for identical inputs/weights/bias."""
    x, w, b, s = _rand_case(16, 64, 16, seed=7)
    got = ops.qat_dense(x, w, b, s, relu=True)
    want = ref.ref_qat_dense(x, w, b, s, relu=True)
    assert bool(jnp.all(got == want))
