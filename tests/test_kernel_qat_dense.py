"""int8 dense kernel vs pure-jnp oracle: BIT-EXACT on integer outputs
(the paper's FPGA-vs-Python criterion), exact fp32 on the float head.
Shapes/dtypes swept with hypothesis."""

import jax
import jax.numpy as jnp
import pytest
from _hypothesis_fallback import given, settings, strategies as st

from repro.core import mrf_net, qat
from repro.kernels.qat_dense import ops, ref

jax.config.update("jax_platform_name", "cpu")


def _rand_case(m, k, n, seed):
    kx, kw, kb, ks = jax.random.split(jax.random.PRNGKey(seed), 4)
    x = jax.random.randint(kx, (m, k), -128, 128, jnp.int8)
    w = jax.random.randint(kw, (k, n), -128, 128, jnp.int8)
    b = jax.random.randint(kb, (n,), -2048, 2048, jnp.int32)
    s = jax.random.uniform(ks, (n,), jnp.float32, 1e-4, 1e-2)
    return x, w, b, s


@pytest.mark.parametrize("mkn", [(8, 64, 32), (130, 200, 300), (1, 64, 2), (256, 256, 128)])
@pytest.mark.parametrize("relu,float_out", [(True, False), (False, False), (False, True)])
def test_bitexact_vs_oracle(mkn, relu, float_out):
    x, w, b, s = _rand_case(*mkn, seed=hash(mkn) % 100)
    got = ops.qat_dense(x, w, b, s, relu=relu, float_out=float_out)
    want = ref.ref_qat_dense(x, w, b, s, relu=relu, float_out=float_out)
    if float_out:
        assert jnp.array_equal(got, want)
    else:
        assert bool(jnp.all(got == want)), "integer outputs must be bit-exact"


@settings(max_examples=12, deadline=None)
@given(m=st.integers(1, 80), k=st.integers(1, 160), n=st.integers(1, 160),
       relu=st.booleans(), seed=st.integers(0, 2**16))
def test_property_bitexact(m, k, n, relu, seed):
    x, w, b, s = _rand_case(m, k, n, seed)
    got = ops.qat_dense(x, w, b, s, relu=relu, float_out=False, block=64)
    want = ref.ref_qat_dense(x, w, b, s, relu=relu, float_out=False)
    assert bool(jnp.all(got == want))


def test_full_integer_network_paths_agree():
    """QAT export -> software integer oracle == Pallas integer network."""
    sizes = mrf_net.layer_sizes(32)
    params = mrf_net.init_params(jax.random.PRNGKey(1), sizes)
    qs = qat.init_qat_state(len(params))
    x = jax.random.normal(jax.random.PRNGKey(2), (32, sizes[0]))
    for _ in range(5):
        _, qs = qat.forward_qat(params, qs, x)
    ints = qat.export_int8(params, qs)
    y_sw = qat.int_forward(ints, x)
    y_pl = ops.int_forward_pallas(ints, x)
    assert jnp.array_equal(y_sw, y_pl)


def test_int_node_bitexact():
    """Paper §2.2: the single-node function on the accelerator must equal the
    software implementation exactly for identical inputs/weights/bias."""
    x, w, b, s = _rand_case(16, 64, 16, seed=7)
    got = ops.qat_dense(x, w, b, s, relu=True)
    want = ref.ref_qat_dense(x, w, b, s, relu=True)
    assert bool(jnp.all(got == want))


# ---------------------------------------------------------------------------
# Fused whole-network kernel + vectorized lax fallback (this PR's paths).
# ---------------------------------------------------------------------------

def _exported_net(seed: int = 1, n_frames: int = 32):
    sizes = mrf_net.layer_sizes(n_frames)
    params = mrf_net.init_params(jax.random.PRNGKey(seed), sizes)
    qs = qat.init_qat_state(len(params))
    x = jax.random.normal(jax.random.PRNGKey(seed + 1), (64, sizes[0]))
    for _ in range(5):
        _, qs = qat.forward_qat(params, qs, x)
    return qat.export_int8(params, qs), sizes[0]


@pytest.mark.parametrize("relu,float_out", [(True, False), (False, False),
                                            (True, True), (False, True)])
@pytest.mark.parametrize("mkn", [(1, 64, 2), (7, 33, 5), (130, 200, 300),
                                 (128, 128, 128)])
def test_qat_dense_lax_bitexact(mkn, relu, float_out):
    """The pure-lax layer primitive matches the oracle for every epilogue
    combo on ragged AND tile-aligned shapes."""
    x, w, b, s = _rand_case(*mkn, seed=hash(mkn) % 100 + 1)
    got = ops.qat_dense_lax(x, w, b, s, relu=relu, float_out=float_out)
    want = ref.ref_qat_dense(x, w, b, s, relu=relu, float_out=float_out)
    assert got.dtype == want.dtype
    assert jnp.array_equal(got, want)


def test_qat_dense_lax_int32_fallback_bitexact():
    """A bias too large for exact f32 accumulation flips the layer onto the
    int32 dot path — still bit-exact vs the oracle."""
    x, w, b, s = _rand_case(16, 64, 16, seed=3)
    b = b + jnp.int32(2 ** 24)  # k*2**14 + |b| >= 2**24: f32 not exact
    assert not ops._f32_dot_is_exact(64, b)
    got = ops.qat_dense_lax(x, w, b, s, relu=True)
    want = ref.ref_qat_dense(x, w, b, s, relu=True)
    assert jnp.array_equal(got, want)


@pytest.mark.parametrize("m", [1, 7, 96, 128, 333, 1024])
def test_all_int8_impls_bitexact_vs_oracle(m):
    """Fused kernel, lax fallback, layered chain (prepadded and legacy):
    every serving implementation equals ``qat.int_forward`` bit-for-bit on
    ragged and bucket-aligned voxel counts — the paper's FPGA-vs-Python
    criterion for the whole network."""
    ints, in_dim = _exported_net()
    x = jax.random.normal(jax.random.PRNGKey(m), (m, in_dim), jnp.float32)
    want = qat.int_forward(ints, x)
    pre = ops.prepad_int_layers(ints)
    assert jnp.array_equal(want, ops.int_forward_fused(pre, x))
    assert jnp.array_equal(want, ops.int_forward_lax(ints, x))
    assert jnp.array_equal(want, ops.int_forward_pallas(ints, x,
                                                        prepadded=pre))
    assert jnp.array_equal(want, ops.int_forward_pallas(ints, x))


def test_fused_denorm_epilogue_bitexact():
    """The in-kernel denormalize epilogue == composing denormalize_targets
    outside, bit-for-bit (it multiplies after the head scale, never folded
    into it — folding would change f32 rounding)."""
    from repro.data.pipeline import (T1_RANGE_MS, T2_RANGE_MS,
                                     denormalize_targets)

    ints, in_dim = _exported_net(seed=4)
    x = jax.random.normal(jax.random.PRNGKey(9), (75, in_dim), jnp.float32)
    pre = ops.prepad_int_layers(ints)
    dscale = jnp.array([T1_RANGE_MS[1], T2_RANGE_MS[1]], jnp.float32)
    got = ops.int_forward_fused(pre, x, denorm_scale=dscale)
    want = denormalize_targets(qat.int_forward(ints, x))
    assert jnp.array_equal(got, want)


def test_fused_accepts_raw_layer_list_and_block_m():
    """Convenience path (un-prepadded list) and a non-default voxel tile
    both reduce to the same bits."""
    ints, in_dim = _exported_net(seed=5)
    x = jax.random.normal(jax.random.PRNGKey(11), (50, in_dim), jnp.float32)
    want = qat.int_forward(ints, x)
    assert jnp.array_equal(want, ops.int_forward_fused(ints, x))
    assert jnp.array_equal(
        want, ops.int_forward_fused(ops.prepad_int_layers(ints), x,
                                    block_m=16))


def test_prepad_preserves_oracle_scale_grouping():
    """prepad must precompute (s_in * s_w) / s_out with the oracle's operand
    grouping — any re-association changes f32 bits."""
    ints, _ = _exported_net(seed=6)
    pre = ops.prepad_int_layers(ints)
    for i, layer in enumerate(ints):
        n = layer.w_q.shape[1]
        want = (layer.s_in * layer.s_w if layer.s_out is None
                else (layer.s_in * layer.s_w) / layer.s_out)
        assert jnp.array_equal(pre.packed[3 * i + 2][0, :n],
                               want.astype(jnp.float32))
    assert pre.in_dim == int(ints[0].w_q.shape[0])
    assert pre.out_dim == int(ints[-1].w_q.shape[1])
    assert all(w % 128 == 0 for w in pre.padded_widths)
