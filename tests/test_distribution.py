"""Distribution-layer tests.

The heavyweight 512-device dry-run is exercised by ``repro.launch.dryrun``
(results under experiments/dryrun/).  Here we test the machinery on small
meshes in a subprocess (device count must be set before jax init):
lower+compile for each family incl. train/prefill/decode, sharding-rule
mapping, and the HLO cost analyzer against hand-computable modules.
"""

import json
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import pytest

from repro.analysis.hlo_cost import analyze_hlo
from repro.dist.sharding import (SINGLE_POD_RULES, axes_to_spec,
                                 is_axes, with_overrides)

jax.config.update("jax_platform_name", "cpu")


# --------------------------------------------------------------------------
# axis rules (pure)
# --------------------------------------------------------------------------

def test_axes_to_spec_mapping():
    r = SINGLE_POD_RULES
    spec = axes_to_spec(("batch", "act_seq", None), r)
    assert tuple(spec) == ("data", None, None)
    spec = axes_to_spec(("layers", "fsdp", "tp"), r)
    assert tuple(spec) == (None, "data", "model")
    sp = with_overrides(r, act_seq="model")
    assert tuple(axes_to_spec(("batch", "act_seq", None), sp)) == (
        "data", "model", None)


def test_is_axes_leaf_predicate():
    from repro.models.ssm import SSMCache
    assert is_axes(("batch", None))
    assert is_axes(())
    assert not is_axes(SSMCache(("a",), ("b",), ("c",), ("d",)))  # NamedTuple
    assert not is_axes(({"k": 1},))


# --------------------------------------------------------------------------
# HLO cost analyzer (single device, hand-computable)
# --------------------------------------------------------------------------

def test_hlo_cost_counts_scan_trips():
    n = 128
    S = lambda s: jax.ShapeDtypeStruct(s, jnp.float32)

    def g(h, w):
        def body(h, _):
            return jnp.tanh(h @ w), None
        return jax.lax.scan(body, h, None, length=7)[0]

    r = analyze_hlo(jax.jit(g).lower(S((n, n)), S((n, n))).compile().as_text())
    assert abs(r["flops"] / (7 * 2 * n ** 3) - 1.0) < 1e-6


def test_hlo_cost_counts_remat_factor():
    n = 128
    S = lambda s: jax.ShapeDtypeStruct(s, jnp.float32)

    def loss(h, w):
        def body(h, _):
            return jnp.tanh(h @ w), None
        return jnp.sum(jax.lax.scan(jax.checkpoint(body), h, None,
                                    length=10)[0] ** 2)

    r = analyze_hlo(jax.jit(jax.grad(loss, argnums=1))
                    .lower(S((n, n)), S((n, n))).compile().as_text())
    assert abs(r["flops"] / (4 * 10 * 2 * n ** 3) - 1.0) < 0.01  # 4/3 * 3x


# --------------------------------------------------------------------------
# small-mesh lowering in a subprocess (needs >1 device before jax init)
# --------------------------------------------------------------------------

_SUBPROC = textwrap.dedent("""
    import os, json
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=16"
    import sys; sys.path.insert(0, "src")
    from repro.configs import get_smoke
    from repro.configs.base import ShapeCell
    from repro.dist.sharding import make_compat_mesh
    from repro.launch.dryrun import lower_cell

    mesh = make_compat_mesh((4, 4), ("data", "model"))
    out = {}
    for name in %(archs)s:
        cfg = get_smoke(name)
        for cell in [ShapeCell("t", 64, 8, "train"),
                     ShapeCell("d", 64, 8, "decode")]:
            rec = lower_cell(cfg, cell, mesh)
            out[f"{name}/{cell.name}"] = {
                "flops": rec["hlo_cost"]["flops"],
                "coll": rec["collectives"]["total"],
            }
    print(json.dumps(out))
""")


_SP_SUBPROC = textwrap.dedent("""
    import os, json, re
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=16"
    import sys; sys.path.insert(0, "src")
    import jax
    from repro.configs import get_smoke
    from repro.configs.base import ShapeCell
    from repro.dist.sharding import make_compat_mesh, use_rules
    from repro.launch import input_specs as specs_mod
    from repro.launch.mesh import rules_for
    from repro.models import registry

    mesh = make_compat_mesh((4, 4), ("data", "model"))
    cfg = get_smoke("tinyllama-1.1b")
    cell = ShapeCell("t", 16, 8, "train")
    fns = registry.build(cfg, tp=mesh.shape["model"])
    params_s = specs_mod.params_specs(cfg, mesh.shape["model"])
    batch_s = specs_mod.batch_specs(cfg, cell)

    # the (batch, seq, d_model) activation annotations in the lowered HLO:
    # shard(h, "batch", "act_seq", None) custom calls on 8x16x64 tensors
    pat = re.compile(r'@Sharding\\(%\\d+\\) \\{backend_config = "", '
                     r'mhlo.sharding = "\\{([^}]*)\\}"[^:]*'
                     r': \\(tensor<8x16x64x')

    def act_shardings(sp):
        rules = rules_for(mesh, global_batch=cell.global_batch,
                          sequence_parallel=sp)
        fresh = lambda p, b: fns.loss(p, b)  # defeat jax's trace cache:
        # ambient rules are invisible to its key, so reusing the same
        # function object would replay the other variant's trace
        with use_rules(rules):
            txt = jax.jit(fresh).lower(params_s, batch_s).as_text()
        return rules.rules["act_seq"], pat.findall(txt)

    sp_rule, sp_sh = act_shardings(True)
    base_rule, base_sh = act_shardings(False)
    print(json.dumps({"sp_rule": sp_rule, "base_rule": base_rule,
                      "sp_shardings": sp_sh, "base_shardings": base_sh}))
""")


def test_sequence_parallel_lowers_act_seq_to_model():
    """ROADMAP open item: ``rules_for(..., sequence_parallel=True)`` must
    map ``act_seq -> model`` all the way into the jitted HLO of a token
    arch — the (batch, seq, d) activations carry a devices=[4,4,1]
    sharding (seq over the model axis), which vanishes without sp."""
    proc = subprocess.run(
        [sys.executable, "-c", _SP_SUBPROC], capture_output=True, text=True,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        timeout=900)
    assert proc.returncode == 0, proc.stderr[-3000:]
    out = json.loads(proc.stdout.strip().splitlines()[-1])
    assert out["sp_rule"] == "model" and out["base_rule"] is None
    assert out["sp_shardings"], "no act_seq annotations found in the HLO"
    assert all(s.startswith("devices=[4,4,1]") for s in out["sp_shardings"])
    # without sequence_parallel the seq dim stays unsharded (replicated
    # across the model axis): 4 batch shards, trailing replication tile
    assert out["base_shardings"], "baseline act annotations vanished"
    assert all(s.startswith("devices=[4,1,1,4]")
               for s in out["base_shardings"])


@pytest.mark.parametrize("archs", [
    ["tinyllama-1.1b", "phi3.5-moe-42b-a6.6b"],
    ["mamba2-1.3b", "seamless-m4t-large-v2"],
])
def test_small_mesh_lower_compile(archs):
    code = _SUBPROC % {"archs": repr(archs)}
    proc = subprocess.run([sys.executable, "-c", code], capture_output=True,
                          text=True, cwd=os.path.dirname(os.path.dirname(
                              os.path.abspath(__file__))), timeout=900)
    assert proc.returncode == 0, proc.stderr[-3000:]
    out = json.loads(proc.stdout.strip().splitlines()[-1])
    for k, v in out.items():
        assert v["flops"] > 0, k
        assert v["coll"] > 0, k  # sharded execution must communicate
