"""Core MRF substrate tests: physics sanity of the Bloch/EPG simulator, the
paper's cycle model (exact numbers), QAT export equivalence, metrics, data
pipeline determinism, and a short end-to-end training run."""

import jax
import jax.numpy as jnp
import numpy as np
from _hypothesis_fallback import given, settings, strategies as st

from repro.core import fpga_cost_model as fcm
from repro.core import metrics, mrf_net, qat
from repro.data.epg import default_sequence, simulate_fingerprints, augment
from repro.data.lm_text import TextPipeline
from repro.data.pipeline import MRFSampleStream, sample_batch

jax.config.update("jax_platform_name", "cpu")


# --------------------------------------------------------------------------
# simulator physics
# --------------------------------------------------------------------------

def test_fingerprints_normalised_and_distinct():
    seq = default_sequence(32)
    t1 = jnp.array([500.0, 1000.0, 2000.0])
    t2 = jnp.array([50.0, 100.0, 200.0])
    sig = simulate_fingerprints(seq, t1, t2)
    norms = jnp.linalg.norm(sig, axis=-1)
    np.testing.assert_allclose(norms, 1.0, rtol=1e-5)
    # different tissue -> different fingerprint (the whole premise of MRF)
    c01 = jnp.abs(jnp.vdot(sig[0], sig[1]))
    assert float(c01) < 0.999


def test_augment_preserves_shape_and_adds_noise():
    seq = default_sequence(16)
    sig = simulate_fingerprints(seq, jnp.array([800.0]), jnp.array([80.0]))
    noisy = augment(jax.random.PRNGKey(0), sig, snr_range=(5.0, 5.0))
    assert noisy.shape == sig.shape
    assert float(jnp.linalg.norm(noisy - sig)) > 1e-3


def test_rf_rotation_matches_matrix_oracle():
    """The hand-inlined RF rotation in _bloch_step must equal R_x(a) @ m.

    With r1 = r2 = 0 the relaxation factors are exactly 1, so the carried
    magnetization after one TR is precisely the rotated vector — checked
    against an explicit rotation-matrix oracle for both RF phase signs.
    """
    from repro.data.epg import _bloch_step

    m0 = jnp.array([0.3, -0.5, 0.8], jnp.float32)
    for a, sign in ((0.7, 1.0), (1.3, -1.0), (0.0, 1.0)):
        (m_next, next_sign), sig = _bloch_step(
            (m0, jnp.float32(sign)),
            jnp.array([a, 0.012, 0.0, 0.0], jnp.float32))
        eff = a * sign
        rot = np.array([[1.0, 0.0, 0.0],
                        [0.0, np.cos(eff), np.sin(eff)],
                        [0.0, -np.sin(eff), np.cos(eff)]])
        np.testing.assert_allclose(np.asarray(m_next), rot @ np.asarray(m0),
                                   rtol=1e-6, atol=1e-7)
        # the echo signal is the rotated transverse magnetization
        np.testing.assert_allclose(
            complex(sig), complex((rot @ np.asarray(m0))[0]
                                  + 1j * (rot @ np.asarray(m0))[1]),
            rtol=1e-6, atol=1e-7)
        assert float(next_sign) == -sign  # bSSFP phase alternation


@settings(max_examples=6, deadline=None)
@given(t1=st.floats(300, 3000), t2_frac=st.floats(0.05, 0.5),
       seed=st.integers(0, 2**10))
def test_property_simulator_finite(t1, t2_frac, seed):
    seq = default_sequence(16, seed=seed % 4)
    sig = simulate_fingerprints(seq, jnp.array([t1]), jnp.array([t1 * t2_frac]))
    assert bool(jnp.all(jnp.isfinite(jnp.abs(sig))))


# --------------------------------------------------------------------------
# the paper's cycle model — exact numbers
# --------------------------------------------------------------------------

def test_cycle_model_matches_paper_exactly():
    sizes = mrf_net.layer_sizes(32)  # adapted net
    assert fcm.fwd_cycles(sizes) == 56
    assert fcm.bwd_cycles(sizes) == 104
    assert fcm.train_seconds(sizes, 250_000_000) == 200.0
    assert fcm.paper_eq3_seconds() == 200.0


def test_resource_model_within_band():
    est = fcm.resource_estimate(mrf_net.layer_sizes(32))
    paper = fcm.PAPER["resources_nn"]
    assert abs(est["LUT"] - paper["LUT"]) / paper["LUT"] < 0.25
    assert abs(est["DSP"] - paper["DSP"]) / paper["DSP"] < 0.25


def test_tpu_projection_faster_than_fpga():
    t = fcm.tpu_train_seconds(mrf_net.layer_sizes(32), 250_000_000, chips=1,
                              int8=True)
    assert t["t_total_s"] < fcm.paper_eq3_seconds()


# --------------------------------------------------------------------------
# QAT / metrics
# --------------------------------------------------------------------------

def test_qat_export_close_to_fakequant():
    sizes = mrf_net.layer_sizes(16)
    params = mrf_net.init_params(jax.random.PRNGKey(0), sizes)
    qs = qat.init_qat_state(len(params))
    x = jax.random.normal(jax.random.PRNGKey(1), (32, sizes[0]))
    for _ in range(4):
        _, qs = qat.forward_qat(params, qs, x)
    ints = qat.export_int8(params, qs)
    y_fake, _ = qat.forward_qat(params, qs, x, train=False)
    y_int = qat.int_forward(ints, x)
    np.testing.assert_allclose(y_int, y_fake, atol=1e-5)


def test_metrics_zero_for_perfect_prediction():
    y = jnp.abs(jax.random.normal(jax.random.PRNGKey(0), (100, 2))) + 1.0
    m = metrics.table1_metrics(y, y)
    for p in ("T1", "T2"):
        assert m[p]["MAPE_%"] == 0.0 and m[p]["RMSE_ms"] == 0.0


# --------------------------------------------------------------------------
# data pipelines
# --------------------------------------------------------------------------

def test_mrf_stream_deterministic():
    stream = MRFSampleStream(seq=default_sequence(16), batch_size=8)
    x1, y1 = sample_batch(stream, jax.random.PRNGKey(7))
    x2, y2 = sample_batch(stream, jax.random.PRNGKey(7))
    np.testing.assert_array_equal(x1, x2)
    assert bool(jnp.all(y1 <= 1.0)) and bool(jnp.all(y1 > 0.0))


def test_lm_pipeline_seekable_and_host_sharded():
    p = TextPipeline(seq_len=32, batch_size=8)
    a = p.batch_at(5)
    b = p.batch_at(5)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    np.testing.assert_array_equal(a["tokens"][:, 1:], a["labels"][:, :-1])
    h0 = TextPipeline(seq_len=32, batch_size=8, n_hosts=2, host=0).batch_at(5)
    h1 = TextPipeline(seq_len=32, batch_size=8, n_hosts=2, host=1).batch_at(5)
    assert h0["tokens"].shape[0] == 4
    assert not np.array_equal(h0["tokens"], h1["tokens"])


# --------------------------------------------------------------------------
# short end-to-end training (the paper's software reference)
# --------------------------------------------------------------------------

def test_training_reduces_loss():
    from repro.core.train_loop import TrainConfig, train
    cfg = TrainConfig(n_frames=16, steps=60, lr=3e-3, batch_size=64,
                      log_every=1000)
    params, _, info = train(cfg, verbose=False)
    # loss after training must beat the first-step loss significantly
    first = info["history"][0][1]
    last = info["history"][-1][1]
    assert last < 0.5 * first
