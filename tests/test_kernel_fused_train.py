"""Fused training kernel vs the jax.grad oracle (kernels/fused_train/ref.py).

The paper's correctness criterion is exact agreement between the accelerator
and the Python reference at node granularity; here the entire fused
fwd+bwd+SGD step is checked against autodiff to fp32 tolerance, across batch
tiles, stream (per-sample) mode, and the QAT fake-quant forward.
"""

import jax
import jax.numpy as jnp
import pytest
from _hypothesis_fallback import given, settings, strategies as st

from repro.core import mrf_net
from repro.kernels.fused_train import ops, ref

jax.config.update("jax_platform_name", "cpu")


def _setup(n_frames=32, batch=32, seed=0, hidden=mrf_net.ADAPTED_HIDDEN):
    sizes = mrf_net.layer_sizes(n_frames, hidden)
    params = mrf_net.init_params(jax.random.PRNGKey(seed), sizes)
    x = jax.random.normal(jax.random.PRNGKey(seed + 1), (batch, sizes[0]))
    y = jax.random.uniform(jax.random.PRNGKey(seed + 2), (batch, 2))
    return params, x, y


def _assert_params_close(a, b, atol=1e-5):
    for la, lb in zip(a, b):
        assert jnp.allclose(la["w"], lb["w"], atol=atol), float(jnp.max(jnp.abs(la["w"] - lb["w"])))
        assert jnp.allclose(la["b"], lb["b"], atol=atol)


@pytest.mark.parametrize("tile_batch", [1, 8, 32])
def test_matches_autodiff_oracle(tile_batch):
    params, x, y = _setup()
    new_k, loss_k = ops.fused_train_step(params, x, y, lr=1e-2, tile_batch=tile_batch)
    new_r, loss_r = ref.ref_train(params, x, y, lr=1e-2, tile_batch=tile_batch)
    assert jnp.allclose(loss_k, loss_r, atol=1e-5)
    _assert_params_close(new_k, new_r)


def test_stream_mode_is_paper_sgd():
    """tile_batch=1 must equal a hand-rolled per-sample SGD loop."""
    params, x, y = _setup(batch=8)
    new_k, _ = ops.fused_train_step(params, x, y, lr=5e-3, tile_batch=1)
    p = params
    for i in range(x.shape[0]):
        g = jax.grad(mrf_net.mse_loss)(p, x[i:i + 1], y[i:i + 1])
        p = jax.tree.map(lambda a, b: a - 5e-3 * b, p, g)
    _assert_params_close(new_k, p)


def test_qat_forward_mode():
    params, x, y = _setup()
    new_k, loss_k = ops.fused_train_step(params, x, y, lr=1e-2, tile_batch=16, qat=True)
    new_r, loss_r = ref.ref_train(params, x, y, lr=1e-2, tile_batch=16, qat=True)
    assert jnp.allclose(loss_k, loss_r, atol=1e-5)
    _assert_params_close(new_k, new_r)


def test_padding_is_inert():
    """Padded lanes must stay exactly zero after a training pass."""
    params, x, y = _setup()
    w_pad, b_pad = ops.pad_params(params)
    from repro.kernels.fused_train.kernel import fused_train_call, PAD
    x_pad = jnp.zeros((32, PAD)).at[:, :x.shape[1]].set(x)
    y_pad = jnp.zeros((32, PAD)).at[:, :2].set(y)
    w_new, b_new, _ = fused_train_call(x_pad, y_pad, w_pad, b_pad,
                                       n_layers=len(params), out_dim=2,
                                       lr=1e-2, tile_batch=8)
    sizes = [p["w"].shape for p in params]
    for l, (i, o) in enumerate(sizes):
        assert jnp.all(w_new[l, i:, :] == 0.0)
        assert jnp.all(w_new[l, :, o:] == 0.0)
        assert jnp.all(b_new[l, o:] == 0.0)


def test_loss_decreases_over_tiles():
    """Sequential SGD across tiles should reduce loss on average."""
    params, x, y = _setup(batch=512, seed=3)
    _, losses = ops.fused_train_step(params, x, y, lr=1e-1, tile_batch=32)
    first, last = float(losses[0]), float(losses[-1])
    assert last < first


@settings(max_examples=8, deadline=None)
@given(
    n_frames=st.sampled_from([8, 16, 32, 64]),
    batch=st.sampled_from([4, 16, 32]),
    tile=st.sampled_from([1, 2, 4]),
    seed=st.integers(0, 2**16),
)
def test_property_kernel_equals_oracle(n_frames, batch, tile, seed):
    if batch % tile:
        tile = 1
    hidden = (32, 16, 16)
    params, x, y = _setup(n_frames=n_frames, batch=batch, seed=seed, hidden=hidden)
    new_k, loss_k = ops.fused_train_step(params, x, y, lr=1e-2, tile_batch=tile)
    new_r, loss_r = ref.ref_train(params, x, y, lr=1e-2, tile_batch=tile)
    assert jnp.allclose(loss_k, loss_r, atol=1e-4)
    _assert_params_close(new_k, new_r, atol=1e-4)
