"""Fused training kernel vs the jax.grad oracle (kernels/fused_train/ref.py).

The paper's correctness criterion is exact agreement between the accelerator
and the Python reference at node granularity; here the entire fused
fwd+bwd+SGD step is checked against autodiff to fp32 tolerance, across batch
tiles, stream (per-sample) mode, and the QAT fake-quant forward.
"""

import jax
import jax.numpy as jnp
import pytest
from _hypothesis_fallback import given, settings, strategies as st

from repro.core import mrf_net
from repro.kernels.fused_train import ops, ref

jax.config.update("jax_platform_name", "cpu")


def _setup(n_frames=32, batch=32, seed=0, hidden=mrf_net.ADAPTED_HIDDEN):
    sizes = mrf_net.layer_sizes(n_frames, hidden)
    params = mrf_net.init_params(jax.random.PRNGKey(seed), sizes)
    x = jax.random.normal(jax.random.PRNGKey(seed + 1), (batch, sizes[0]))
    y = jax.random.uniform(jax.random.PRNGKey(seed + 2), (batch, 2))
    return params, x, y


def _assert_params_close(a, b, atol=1e-5):
    for la, lb in zip(a, b):
        assert jnp.allclose(la["w"], lb["w"], atol=atol), float(jnp.max(jnp.abs(la["w"] - lb["w"])))
        assert jnp.allclose(la["b"], lb["b"], atol=atol)


@pytest.mark.parametrize("tile_batch", [1, 8, 32])
def test_matches_autodiff_oracle(tile_batch):
    params, x, y = _setup()
    new_k, loss_k = ops.fused_train_step(params, x, y, lr=1e-2, tile_batch=tile_batch)
    new_r, loss_r = ref.ref_train(params, x, y, lr=1e-2, tile_batch=tile_batch)
    assert jnp.allclose(loss_k, loss_r, atol=1e-5)
    _assert_params_close(new_k, new_r)


def test_stream_mode_is_paper_sgd():
    """tile_batch=1 must equal a hand-rolled per-sample SGD loop."""
    params, x, y = _setup(batch=8)
    new_k, _ = ops.fused_train_step(params, x, y, lr=5e-3, tile_batch=1)
    p = params
    for i in range(x.shape[0]):
        g = jax.grad(mrf_net.mse_loss)(p, x[i:i + 1], y[i:i + 1])
        p = jax.tree.map(lambda a, b: a - 5e-3 * b, p, g)
    _assert_params_close(new_k, p)


def test_qat_forward_mode():
    params, x, y = _setup()
    new_k, loss_k = ops.fused_train_step(params, x, y, lr=1e-2, tile_batch=16, qat=True)
    new_r, loss_r = ref.ref_train(params, x, y, lr=1e-2, tile_batch=16, qat=True)
    assert jnp.allclose(loss_k, loss_r, atol=1e-5)
    _assert_params_close(new_k, new_r)


def test_padding_is_inert():
    """Padded lanes must stay exactly zero after a training pass."""
    params, x, y = _setup()
    w_pad, b_pad = ops.pad_params(params)
    from repro.kernels.fused_train.kernel import fused_train_call, PAD
    x_pad = jnp.zeros((32, PAD)).at[:, :x.shape[1]].set(x)
    y_pad = jnp.zeros((32, PAD)).at[:, :2].set(y)
    w_new, b_new, _ = fused_train_call(x_pad, y_pad, w_pad, b_pad,
                                       n_layers=len(params), out_dim=2,
                                       lr=1e-2, tile_batch=8)
    sizes = [p["w"].shape for p in params]
    for l, (i, o) in enumerate(sizes):
        assert jnp.all(w_new[l, i:, :] == 0.0)
        assert jnp.all(w_new[l, :, o:] == 0.0)
        assert jnp.all(b_new[l, o:] == 0.0)


def test_loss_decreases_over_tiles():
    """Sequential SGD across tiles should reduce loss on average."""
    params, x, y = _setup(batch=512, seed=3)
    _, losses = ops.fused_train_step(params, x, y, lr=1e-1, tile_batch=32)
    first, last = float(losses[0]), float(losses[-1])
    assert last < first


@settings(max_examples=8, deadline=None)
@given(
    n_frames=st.sampled_from([8, 16, 32, 64]),
    batch=st.sampled_from([4, 16, 32]),
    tile=st.sampled_from([1, 2, 4]),
    seed=st.integers(0, 2**16),
)
def test_property_kernel_equals_oracle(n_frames, batch, tile, seed):
    if batch % tile:
        tile = 1
    hidden = (32, 16, 16)
    params, x, y = _setup(n_frames=n_frames, batch=batch, seed=seed, hidden=hidden)
    new_k, loss_k = ops.fused_train_step(params, x, y, lr=1e-2, tile_batch=tile)
    new_r, loss_r = ref.ref_train(params, x, y, lr=1e-2, tile_batch=tile)
    assert jnp.allclose(loss_k, loss_r, atol=1e-4)
    _assert_params_close(new_k, new_r, atol=1e-4)


# --------------------------------------------------------------------------
# multi-step launches (multistep.py): K steps per kernel call must be
# BIT-identical to K single-step calls — params, opt state, per-step losses
# --------------------------------------------------------------------------

def _params_bitequal(a, b):
    for la, lb in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        assert jnp.array_equal(la, lb), float(jnp.max(jnp.abs(la - lb)))


def _multi_setup(K=4, batch=24, seed=0):
    params, x, y = _setup(n_frames=16, batch=K * batch, seed=seed,
                          hidden=(32, 16))
    return params, x, y, K, batch


@pytest.mark.parametrize("qat", [False, True])
def test_multistep_sgd_bitmatches_sequential_calls(qat):
    """One K-step launch == K sequential fused_train_step calls, bit for
    bit: final params AND the per-step loss trace (the weights never leave
    VMEM mid-launch, but the grid sequencing makes that unobservable)."""
    params, x, y, K, B = _multi_setup()
    p_multi, _, trace = ops.fused_train_multistep(
        params, None, x, y, n_steps=K, lr=1e-2, optimizer="sgd",
        tile_batch=8, qat=qat)
    p_seq, rows = params, []
    for k in range(K):
        p_seq, losses = ops.fused_train_step(
            p_seq, x[k * B:(k + 1) * B], y[k * B:(k + 1) * B], lr=1e-2,
            tile_batch=8, qat=qat)
        rows.append(losses)
    assert trace.shape == (K, B // 8)
    assert jnp.array_equal(trace, jnp.stack(rows))
    _params_bitequal(p_multi, p_seq)


@pytest.mark.parametrize("qat", [False, True])
def test_multistep_adam_bitmatches_sequential_launches(qat):
    """In-kernel Adam: one K-step launch == K single-step (n_steps=1)
    launches — params, moment stacks, step counter, loss trace.  The moments
    roundtrip through HBM between sequential launches; resident-in-VMEM must
    be unobservable."""
    from repro.optim.optimizers import adam
    params, x, y, K, B = _multi_setup(seed=2)
    opt = adam(2e-3)
    p_multi, st_multi, trace = ops.fused_train_multistep(
        params, opt.init(params), x, y, n_steps=K, lr=2e-3,
        optimizer="adam", tile_batch=8, qat=qat)
    p_seq, st_seq, rows = params, opt.init(params), []
    for k in range(K):
        p_seq, st_seq, tl = ops.fused_train_multistep(
            p_seq, st_seq, x[k * B:(k + 1) * B], y[k * B:(k + 1) * B],
            n_steps=1, lr=2e-3, optimizer="adam", tile_batch=8, qat=qat)
        rows.append(tl[0])
    assert jnp.array_equal(trace, jnp.stack(rows))
    _params_bitequal(p_multi, p_seq)
    _params_bitequal(st_multi.mu, st_seq.mu)
    _params_bitequal(st_multi.nu, st_seq.nu)
    assert int(st_multi.step) == int(st_seq.step) == K * (B // 8)


@pytest.mark.parametrize("optimizer", ["sgd", "adam"])
def test_multistep_ragged_chunk_composition(optimizer):
    """Chunk clipping (ft.runner semantics): 4+4+2 multi-step launches must
    bit-match one 10-step launch — a restart landing on any chunk boundary
    resumes the exact trajectory."""
    from repro.optim.optimizers import adam
    B = 16
    params, x, y = _setup(n_frames=16, batch=10 * B, seed=5, hidden=(32, 16))
    st0 = adam(1e-3).init(params) if optimizer == "adam" else None
    p_full, st_full, trace_full = ops.fused_train_multistep(
        params, st0, x, y, n_steps=10, lr=1e-3, optimizer=optimizer,
        tile_batch=8)
    p, st, rows = params, st0, []
    for lo, hi in ((0, 4), (4, 8), (8, 10)):
        p, st, tl = ops.fused_train_multistep(
            p, st, x[lo * B:hi * B], y[lo * B:hi * B], n_steps=hi - lo,
            lr=1e-3, optimizer=optimizer, tile_batch=8)
        rows.append(tl)
    assert jnp.array_equal(trace_full, jnp.concatenate(rows))
    _params_bitequal(p_full, p)
    if optimizer == "adam":
        _params_bitequal(st_full.mu, st.mu)
        _params_bitequal(st_full.nu, st.nu)
        assert int(st_full.step) == int(st.step)


class _ListRefs:
    """List-backed stand-in for the kernel's VMEM scratch refs, so
    ``train_tile`` can run as plain traced JAX for oracle tests."""

    def __init__(self, arrs):
        self.a = [jnp.asarray(v) for v in arrs]

    def __getitem__(self, l):
        return self.a[l]

    def __setitem__(self, l, v):
        self.a[l] = v


def test_adam_kernel_matches_software_adam_on_padded_math():
    """The in-kernel Adam against ``optim.optimizers.adam`` applied to the
    padded stacks, with gradients extracted from the *same* ``train_tile``
    body.  The first update is checked bit-for-bit on the loss and both
    moment stacks (same ops, same order); the parameter subtraction crosses
    two separately-compiled XLA programs where FMA contraction may differ,
    so params — and everything downstream of them over the K-step
    trajectory — are held to float32-ulp tolerance instead."""
    from repro.kernels.fused_train.kernel import PAD, train_tile
    from repro.optim.optimizers import adam
    K, B, tile, out_dim = 3, 16, 8, 2
    params, x, y = _setup(n_frames=16, batch=K * B, seed=7, hidden=(32, 16))
    n_layers = len(params)
    opt = adam(2e-3)
    p_k, st_k, trace = ops.fused_train_multistep(
        params, opt.init(params), x, y, n_steps=K, lr=2e-3,
        optimizer="adam", tile_batch=tile)

    w_pad, b_pad = ops.pad_params(params)
    x_pad = jnp.zeros((K * B, PAD)).at[:, :x.shape[1]].set(x)
    y_pad = jnp.zeros((K * B, PAD)).at[:, :out_dim].set(y)

    @jax.jit
    def software_adam(w_pad, b_pad, x_pad, y_pad):
        stacks = {"w": w_pad, "b": b_pad}
        st = opt.init(stacks)
        losses = []
        for t in range(K * B // tile):
            xs = x_pad[t * tile:(t + 1) * tile]
            ys = y_pad[t * tile:(t + 1) * tile]
            w_s = _ListRefs([stacks["w"][l] for l in range(n_layers)])
            b_s = _ListRefs([stacks["b"][l] for l in range(n_layers)])
            h_s = _ListRefs([jnp.zeros((tile, PAD))] * max(n_layers - 1, 1))
            grads = {"w": [None] * n_layers, "b": [None] * n_layers}

            def grab(l, dw, db):
                grads["w"][l] = dw
                grads["b"][l] = db
            losses.append(train_tile(xs, ys, w_s, b_s, h_s, grab,
                                     n_layers=n_layers, out_dim=out_dim,
                                     qat=False))
            grads = {"w": jnp.stack(grads["w"]), "b": jnp.stack(grads["b"])}
            stacks, st = opt.update(grads, st, stacks)
        return stacks, st, jnp.stack(losses)

    stacks_r, st_r, losses_r = software_adam(w_pad, b_pad, x_pad, y_pad)

    # --- first update: gradient path and moment math are bit-identical -----
    @jax.jit
    def software_first_update(w_pad, b_pad, x_pad, y_pad):
        st = opt.init({"w": w_pad, "b": b_pad})
        w_s = _ListRefs([w_pad[l] for l in range(n_layers)])
        b_s = _ListRefs([b_pad[l] for l in range(n_layers)])
        h_s = _ListRefs([jnp.zeros((tile, PAD))] * max(n_layers - 1, 1))
        grads = {"w": [None] * n_layers, "b": [None] * n_layers}

        def grab(l, dw, db):
            grads["w"][l] = dw
            grads["b"][l] = db
        loss = train_tile(x_pad[:tile], y_pad[:tile], w_s, b_s, h_s, grab,
                          n_layers=n_layers, out_dim=out_dim, qat=False)
        grads = {"w": jnp.stack(grads["w"]), "b": jnp.stack(grads["b"])}
        _, st = opt.update(grads, st, {"w": w_pad, "b": b_pad})
        return loss, st

    loss_1r, st_1r = software_first_update(w_pad, b_pad, x_pad, y_pad)
    _, st1, trace1 = ops.fused_train_multistep(
        params, opt.init(params), x[:tile], y[:tile], n_steps=1, lr=2e-3,
        optimizer="adam", tile_batch=tile)
    assert jnp.array_equal(trace1[0, 0], loss_1r)
    mw1, mb1 = ops.pad_params(st1.mu)
    vw1, vb1 = ops.pad_params(st1.nu)
    assert jnp.array_equal(st_1r.mu["w"], mw1)
    assert jnp.array_equal(st_1r.mu["b"], mb1)
    assert jnp.array_equal(st_1r.nu["w"], vw1)
    assert jnp.array_equal(st_1r.nu["b"], vb1)

    # --- K-step trajectory: float32-ulp agreement --------------------------
    assert jnp.allclose(trace, losses_r.reshape(K, -1), atol=0.0, rtol=1e-5)
    mw_k, mb_k = ops.pad_params(st_k.mu)
    vw_k, vb_k = ops.pad_params(st_k.nu)
    for got, want in ((mw_k, st_r.mu["w"]), (mb_k, st_r.mu["b"]),
                      (vw_k, st_r.nu["w"]), (vb_k, st_r.nu["b"])):
        assert jnp.allclose(got, want, atol=1e-6, rtol=1e-5)
    w_k, b_k = ops.pad_params(p_k)
    assert jnp.allclose(stacks_r["w"], w_k, atol=1e-6, rtol=1e-5)
    assert jnp.allclose(stacks_r["b"], b_k, atol=1e-6, rtol=1e-5)
