"""``hypothesis`` if installed, else a deterministic single-example stand-in.

The kernel property tests sweep shapes with hypothesis, but the training
container doesn't ship it (and the repo policy is to gate missing deps, not
install them).  Importing ``given/settings/st`` from here keeps the test
modules collectable everywhere: with hypothesis present you get the real
sweep; without it each ``@given`` test runs once with the *first* value of
every strategy — a smoke check, not a property check (CI installs the real
thing).
"""

try:
    from hypothesis import given, settings, strategies  # noqa: F401
    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False

    class _Strategy:
        def __init__(self, example):
            self.example = example

    class _Strategies:
        @staticmethod
        def integers(min_value, max_value=None):
            return _Strategy(min_value)

        @staticmethod
        def sampled_from(elements):
            return _Strategy(elements[0])

        @staticmethod
        def floats(min_value, max_value=None):
            return _Strategy(min_value)

        @staticmethod
        def booleans():
            return _Strategy(False)

    strategies = _Strategies()

    def settings(**_kwargs):
        return lambda fn: fn

    def given(**strategies):
        def deco(fn):
            # zero-arg wrapper, deliberately NOT functools.wraps: pytest must
            # see an empty signature, or it would treat the strategy kwargs
            # as fixtures to inject
            def run_single_example():
                return fn(**{k: s.example for k, s in strategies.items()})

            run_single_example.__name__ = fn.__name__
            run_single_example.__doc__ = fn.__doc__
            return run_single_example

        return deco
