"""Shared fixtures for the serving test suites (test_serve_recon /
test_serve_queue): one smoke-sized calibrated net and feature factory, so
the recipe can't drift between the files.  benchmarks/mrf_serve_bench.py
keeps its own cfg-driven variant (full-size topology from the arch config,
not this fixed smoke net)."""

import jax
import jax.numpy as jnp

from repro.core import mrf_net, qat

N_FRAMES = 16  # smoke-sized net: (32, 64, 64, 32, 16, 16, 16, 2)


def calibrated_net(seed=0):
    """(params, qat_state, int8_export) for the smoke net — random weights
    plus observer calibration passes; serving needs no trained net."""
    sizes = mrf_net.layer_sizes(N_FRAMES)
    params = mrf_net.init_params(jax.random.PRNGKey(seed), sizes)
    qs = qat.init_qat_state(len(params))
    x = jax.random.normal(jax.random.PRNGKey(seed + 1), (64, sizes[0]))
    for _ in range(3):
        _, qs = qat.forward_qat(params, qs, x)
    return params, qs, qat.export_int8(params, qs)


def features(n, seed=0):
    return jax.random.normal(jax.random.PRNGKey(seed), (n, 2 * N_FRAMES),
                             jnp.float32)
