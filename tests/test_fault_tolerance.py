"""Fault-tolerance tests: checkpoint roundtrip, keep-K GC, crash-restart
equivalence (injected fault resumes to the same final state), straggler
watchdog policy, gradient compression error feedback."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke
from repro.data.lm_text import TextPipeline
from repro.ft.checkpoint import CheckpointManager, restore_state, save_state
from repro.ft.runner import RunnerConfig, run
from repro.ft.straggler import StragglerMonitor
from repro.models import registry
from repro.optim import adam
from repro.optim.grad_compression import error_feedback_compress
from repro.train.step import init_train_state, make_train_step

jax.config.update("jax_platform_name", "cpu")


def _tree_allclose(a, b, atol=0.0):
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y), atol=atol)


def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(12.0).reshape(3, 4),
            "b": [jnp.ones((5,), jnp.int32), jnp.float32(3.5)],
            "c": {"d": jax.random.normal(jax.random.PRNGKey(0), (64, 64))}}
    wait = save_state(tree, tmp_path, step=7, async_io=True)
    wait()
    like = jax.tree.map(lambda x: jax.ShapeDtypeStruct(jnp.shape(x), x.dtype), tree)
    got = restore_state(like, tmp_path, 7)
    _tree_allclose(tree, got)


def test_checkpoint_manager_keeps_k(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=2, every=1)
    tree = {"w": jnp.zeros((4,))}
    for s in range(1, 6):
        mgr.maybe_save(jax.tree.map(lambda x: x + s, tree), s)
    mgr.wait()
    steps = sorted(int(p.name.split("_")[1]) for p in tmp_path.glob("step_*"))
    assert steps == [4, 5]
    got, step = mgr.restore_latest(tree)
    assert step == 5
    np.testing.assert_allclose(got["w"], 5.0)


def _setup_train(tmp_path, inject=None, steps=12):
    cfg = get_smoke("tinyllama-1.1b")
    fns = registry.build(cfg, tp=1)
    opt = adam(1e-3)
    params = fns.init(jax.random.PRNGKey(0))
    state = init_train_state(params, opt)
    step_fn = jax.jit(make_train_step(fns.loss, opt))
    pipe = TextPipeline(seq_len=32, batch_size=4, vocab_size=cfg.vocab_size)
    rcfg = RunnerConfig(total_steps=steps, ckpt_dir=str(tmp_path),
                        ckpt_every=4, inject_fault_at=inject)
    return step_fn, state, pipe.batch_at, rcfg


def test_crash_restart_equals_uninterrupted(tmp_path):
    """A run that crashes at step 6 and restarts from the step-4 checkpoint
    must reach the same final state as an uninterrupted run."""
    step_fn, state, batches, rcfg = _setup_train(tmp_path / "a")
    final_a, _ = run(step_fn, state, batches, rcfg)

    step_fn, state, batches, rcfg = _setup_train(tmp_path / "b", inject=6)
    final_b, _ = run(step_fn, state, batches, rcfg)
    _tree_allclose(final_a.params, final_b.params, atol=1e-6)
    assert int(final_a.step) == int(final_b.step)


def _setup_qat_engine(tmp_path, inject=None, steps=10):
    """The MRF net through the unified engine with the qat-int8 backend: the
    QAT observer state rides in TrainState.aux and must checkpoint/restore."""
    from repro.configs import get_smoke
    from repro.data.epg import default_sequence
    from repro.data.pipeline import MRFSampleStream, make_batch_factory
    from repro.models import registry
    from repro.train import engine

    cfg = get_smoke("mrf-fpga")
    fns = registry.build(cfg)
    step_fn, init_state = engine.build(fns, engine.EngineConfig(
        backend="qat-int8", lr=1e-3, max_grad_norm=None))
    stream = MRFSampleStream(seq=default_sequence(cfg.mrf_n_frames),
                             batch_size=16)
    batches = make_batch_factory(stream, jax.random.PRNGKey(3))
    rcfg = RunnerConfig(total_steps=steps, ckpt_dir=str(tmp_path),
                        ckpt_every=4, inject_fault_at=inject)
    return step_fn, init_state(jax.random.PRNGKey(0)), batches, rcfg


def test_qat_crash_restart_bitmatches_uninterrupted(tmp_path):
    """A QAT run crashed mid-run must restart from checkpoint — params AND
    the aux observer state — and bit-match an uninterrupted run."""
    step_fn, state, batches, rcfg = _setup_qat_engine(tmp_path / "a")
    final_a, _ = run(step_fn, state, batches, rcfg)

    step_fn, state, batches, rcfg = _setup_qat_engine(tmp_path / "b", inject=6)
    final_b, _ = run(step_fn, state, batches, rcfg)

    _tree_allclose(final_a.params, final_b.params, atol=0.0)
    np.testing.assert_array_equal(
        np.asarray(final_a.aux["act_absmax"]),
        np.asarray(final_b.aux["act_absmax"]))
    _tree_allclose(final_a.opt_state, final_b.opt_state, atol=0.0)
    assert int(final_a.step) == int(final_b.step)


def test_straggler_monitor_fires_after_strikes():
    mon = StragglerMonitor(threshold=1.5, strikes=3, warmup=2)
    actions = [mon.update(0.1) for _ in range(6)]
    assert all(a is None for a in actions)
    actions = [mon.update(0.5) for _ in range(3)]
    assert actions[-1] == "checkpoint_and_evict"
    # counter resets after mitigation
    assert mon.update(0.5) is None


def test_grad_compression_error_feedback_unbiased():
    """Error feedback: sum of decompressed grads converges to sum of true
    grads (residual carries the quantization error)."""
    g = jax.random.normal(jax.random.PRNGKey(1), (256,)) * 0.01
    res = None
    total = jnp.zeros_like(g)
    for i in range(20):
        deq, res = error_feedback_compress({"g": g}, res)
        total = total + deq["g"]
    err = jnp.linalg.norm(total - 20 * g) / jnp.linalg.norm(20 * g)
    assert float(err) < 0.02


def test_elastic_restore_respects_target_structure(tmp_path):
    """Restore onto a different (trivial) sharding layout still reassembles
    the same global values — the elasticity contract."""
    tree = {"w": jax.random.normal(jax.random.PRNGKey(2), (128, 8))}
    save_state(tree, tmp_path, 1, async_io=False)
    like = {"w": jax.ShapeDtypeStruct((128, 8), jnp.float32)}
    got = restore_state(like, tmp_path, 1)
    _tree_allclose(tree, got)
