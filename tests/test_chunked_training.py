"""Chunked-dispatch training tests: ``chunk_steps > 1`` must be a pure
performance change — bit-identical final TrainState and per-step loss trace
vs the stepwise loop for every backend, through ragged final chunks,
checkpoint-boundary clipping, and mid-run crash/restart.  Plus the runner's
no-callback sync elision and the elastic ``survivor_mesh`` builder."""

import json
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke
from repro.data.epg import default_sequence
from repro.data.pipeline import (MRFSampleStream, batch_at,
                                 make_batch_factory)
from repro.ft.runner import RunnerConfig, run
from repro.models import registry
from repro.train import engine

jax.config.update("jax_platform_name", "cpu")

# "fused-pallas-adam" is the fused backend with the in-kernel Adam rule —
# a distinct bit-exactness surface (moment stacks + traced-step bias
# correction ride through the multi-step kernel and the ckpt/restart path)
BACKENDS = ("float", "qat-int8", "fused-pallas", "fused-pallas-adam")


def _tree_equal(a, b):
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def _engine_cfg(backend, chunk_steps):
    if backend == "fused-pallas-adam":
        backend, optimizer = "fused-pallas", "adam"
    else:
        optimizer = "sgd" if backend == "fused-pallas" else "adam"
    return engine.EngineConfig(
        backend=backend, lr=1e-3, max_grad_norm=None, optimizer=optimizer,
        chunk_steps=chunk_steps)


def _train(fns, backend, chunk_steps, ckpt_dir, *, total=10, ckpt_every=4,
           inject=None, batch=32, on_metrics="collect"):
    losses = []
    cb = (lambda s, m, dt: losses.append((s, float(m["loss"])))) \
        if on_metrics == "collect" else on_metrics
    rcfg = RunnerConfig(total_steps=total, ckpt_dir=str(ckpt_dir),
                        ckpt_every=ckpt_every, inject_fault_at=inject)
    stream = engine.default_stream(fns.cfg, batch)
    state, step, info = engine.train(
        fns, _engine_cfg(backend, chunk_steps), rcfg, stream=stream,
        data_key=jax.random.PRNGKey(1), batch_size=batch, on_metrics=cb)
    return state, step, losses, info


@pytest.fixture(scope="module")
def fns():
    return registry.build(get_smoke("mrf-fpga"))


# --------------------------------------------------------------------------
# bit-identity: chunked == stepwise, all backends, ragged final chunk
# --------------------------------------------------------------------------

@pytest.mark.parametrize("backend", BACKENDS)
def test_chunked_bitmatches_stepwise(backend, fns, tmp_path):
    """total=10 with chunk_steps=4 exercises chunks 4+4+2 (ragged tail):
    final state AND the full per-step loss trace must be bit-identical."""
    s1, st1, l1, _ = _train(fns, backend, 1, tmp_path / "stepwise")
    s4, st4, l4, _ = _train(fns, backend, 4, tmp_path / "chunked")
    assert st1 == st4 == 10
    assert [s for s, _ in l4] == list(range(1, 11))
    assert l1 == l4  # per-step losses, exact float equality
    _tree_equal(s1, s4)


def test_oversized_chunk_is_one_ragged_chunk(fns, tmp_path):
    """chunk_steps beyond total_steps degrades to a single shorter chunk."""
    s1, _, l1, _ = _train(fns, "float", 1, tmp_path / "a", total=5,
                          ckpt_every=99)
    s8, _, l8, _ = _train(fns, "float", 8, tmp_path / "b", total=5,
                          ckpt_every=99)
    assert l1 == l8 and len(l8) == 5
    _tree_equal(s1, s8)


def test_chunk_clips_to_checkpoint_boundaries(fns, tmp_path):
    """ckpt_every not a multiple of chunk_steps: chunks clip so checkpoints
    land exactly where stepwise puts them, and results still bit-match."""
    s1, _, l1, _ = _train(fns, "float", 1, tmp_path / "a", total=12,
                          ckpt_every=5)
    s4, _, l4, _ = _train(fns, "float", 4, tmp_path / "b", total=12,
                          ckpt_every=5)  # chunks 4,1,4,1,2
    assert l1 == l4
    _tree_equal(s1, s4)
    for d in ("a", "b"):
        assert (tmp_path / d / "step_5").exists() or \
               (tmp_path / d / "step_10").exists()


# --------------------------------------------------------------------------
# crash/restart: resume lands on a chunk boundary and still bit-matches
# --------------------------------------------------------------------------

@pytest.mark.parametrize("backend", BACKENDS)
def test_chunked_crash_restart_bitmatches(backend, fns, tmp_path):
    """Fault at step 6 (mid-chunk for chunk_steps=4): the chunk clips at 6,
    the restart resumes from the step-4 checkpoint — a chunk boundary — and
    the final state bit-matches both an uninterrupted chunked run and the
    stepwise loop."""
    s_plain, _, l_plain, _ = _train(fns, backend, 4, tmp_path / "plain")
    s_crash, st, l_crash, _ = _train(fns, backend, 4, tmp_path / "crash",
                                     inject=6)
    assert st == 10
    _tree_equal(s_plain, s_crash)
    s_step, _, _, _ = _train(fns, backend, 1, tmp_path / "stepwise")
    _tree_equal(s_plain, s_step)
    # the re-executed steps 5..6 appear twice in the crash run's trace; the
    # steps themselves must carry identical losses (seekable replay)
    assert dict(l_crash) == dict(l_plain)


# --------------------------------------------------------------------------
# the shared sampler + stepwise sync elision
# --------------------------------------------------------------------------

def test_batch_at_is_the_factory(fns):
    """make_batch_factory must route through batch_at: same key chain, same
    bits — the contract that makes in-scan synthesis safe."""
    stream = MRFSampleStream(seq=default_sequence(fns.cfg.mrf_n_frames),
                             batch_size=16)
    key = jax.random.PRNGKey(3)
    factory = make_batch_factory(stream, key)
    for step in (0, 7):
        a = factory(step)
        b = batch_at(stream, key, jnp.int32(step))  # traced-style index
        _tree_equal(a, b)


def test_stepwise_no_callback_skips_per_step_sync(fns, tmp_path):
    """No on_metrics: the runner must not block per step (loss never fetched)
    and still reach the identical final state."""
    calls = {"n": 0}
    orig = jax.block_until_ready

    def counting(x):
        calls["n"] += 1
        return orig(x)

    s_cb, _, _, _ = _train(fns, "float", 1, tmp_path / "cb", total=6,
                           ckpt_every=99)
    jax.block_until_ready = counting
    try:
        s_q, _, _, info = _train(fns, "float", 1, tmp_path / "quiet",
                                 total=6, ckpt_every=99, on_metrics=None)
    finally:
        jax.block_until_ready = orig
    assert calls["n"] == 1  # the loop-exit sync only, not one per step
    assert info["steps_executed"] == 6
    _tree_equal(s_cb, s_q)


def test_chunked_requires_stream_not_factory(fns, tmp_path):
    stream = engine.default_stream(fns.cfg, 8)
    rcfg = RunnerConfig(total_steps=4, ckpt_dir=str(tmp_path))
    with pytest.raises(ValueError, match="on-device"):
        engine.train(fns, _engine_cfg("float", 4), rcfg,
                     batches=make_batch_factory(stream, jax.random.PRNGKey(1)))
    with pytest.raises(ValueError, match="chunk_fn"):
        run(lambda s, b: (s, {}), None, lambda s: None, rcfg, chunk_steps=4)


# --------------------------------------------------------------------------
# elastic: survivor-mesh construction from the live device set
# --------------------------------------------------------------------------

_SURVIVOR_SUBPROC = textwrap.dedent("""
    import os, json
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import sys; sys.path.insert(0, "src")
    import jax
    from repro.dist.sharding import make_compat_mesh, MULTI_POD_RULES, AxisRules
    from repro.ft.elastic import survivor_mesh
    from repro.launch.mesh import rules_for

    out = {}
    devs = jax.devices()

    # single-pod (data=4, model=2): evict one data shard (2 devices)
    mesh = make_compat_mesh((4, 2), ("data", "model"), devices=devs)
    rules = rules_for(mesh, global_batch=64)
    live = devs[:6]
    new = survivor_mesh(live, rules)
    out["single"] = {"shape": dict(new.mesh.shape),
                     "batch": new.rules["batch"],
                     "fsdp": new.rules["fsdp"], "tp": new.rules["tp"],
                     "n_dev": new.mesh.size}

    # multi-pod (pod=2, data=2, model=2): lose a whole pod -> batch axes
    # (pod, data) collapse into one 'data' axis over the 4 survivors / 2 tp
    mesh2 = make_compat_mesh((2, 2, 2), ("pod", "data", "model"), devices=devs)
    rules2 = AxisRules(rules=dict(MULTI_POD_RULES.rules), mesh=mesh2)
    new2 = survivor_mesh(devs[4:], rules2)
    out["multi"] = {"shape": dict(new2.mesh.shape),
                    "batch": new2.rules["batch"]}

    # misaligned eviction: 5 survivors don't tile model=2
    try:
        survivor_mesh(devs[:5], rules)
        out["misaligned"] = "no error"
    except ValueError as e:
        out["misaligned"] = "ValueError"
    try:
        survivor_mesh(devs[:4], AxisRules(rules=dict(rules.rules), mesh=None))
        out["unbound"] = "no error"
    except ValueError:
        out["unbound"] = "ValueError"
    print(json.dumps(out))
""")


def test_survivor_mesh_from_live_devices():
    res = subprocess.run([sys.executable, "-c", _SURVIVOR_SUBPROC],
                         capture_output=True, text=True, timeout=300)
    assert res.returncode == 0, res.stderr[-2000:]
    out = json.loads(res.stdout.strip().splitlines()[-1])
    assert out["single"] == {"shape": {"data": 3, "model": 2},
                             "batch": "data", "fsdp": "data", "tp": "model",
                             "n_dev": 6}
    assert out["multi"] == {"shape": {"data": 2, "model": 2},
                            "batch": "data"}
    assert out["misaligned"] == "ValueError"
    assert out["unbound"] == "ValueError"
