"""Unit tests for the repro.dist.sharding contract itself.

test_distribution.py validates the layer end-to-end (16-device subprocess
lowering with sharded collectives); here we pin the pure semantics: rule
mapping, ambient-scope nesting, leaf predicate edges, single-device degrade,
and the elastic downsize policy helper.
"""

import types

import jax
import jax.numpy as jnp
import pytest

from repro.dist.sharding import (MULTI_POD_RULES, SINGLE_POD_RULES, AxisRules,
                                 axes_to_spec, current_rules, is_axes,
                                 make_compat_mesh, param_shardings, shard,
                                 use_rules, with_overrides)
from repro.ft.elastic import downsize_batch_rules

jax.config.update("jax_platform_name", "cpu")


# --------------------------------------------------------------------------
# rule mapping
# --------------------------------------------------------------------------

def test_multi_pod_batch_shards_over_pod_and_data():
    spec = axes_to_spec(("batch", "fsdp", "tp"), MULTI_POD_RULES)
    assert tuple(spec) == (("pod", "data"), "data", "model")


def test_unknown_logical_axis_is_replicated():
    # "cache_seq" is deliberately absent from the rule dicts: model code may
    # annotate axes that only some topologies shard
    spec = axes_to_spec(("batch", "cache_seq", "no_such_axis"),
                        SINGLE_POD_RULES)
    assert tuple(spec) == ("data", None, None)


def test_with_overrides_does_not_mutate_input():
    base = SINGLE_POD_RULES
    before = dict(base.rules)
    derived = with_overrides(base, batch=None, act_seq="model")
    assert dict(base.rules) == before
    assert derived.rules["batch"] is None
    assert derived.rules["act_seq"] == "model"
    assert derived.rules["tp"] == "model"  # untouched keys inherited
    assert derived.mesh is base.mesh


# --------------------------------------------------------------------------
# is_axes leaf predicate
# --------------------------------------------------------------------------

def test_is_axes_accepts_plain_axes_tuples():
    assert is_axes(())
    assert is_axes((None,))
    assert is_axes(("batch", None, "tp"))


def test_is_axes_rejects_non_axes():
    class NT(types.SimpleNamespace):
        pass

    from repro.models.ssm import SSMCache
    assert not is_axes(SSMCache(("a",), ("b",), ("c",), ("d",)))  # NamedTuple
    assert not is_axes(("batch", 3))          # non-str member
    assert not is_axes((("batch",),))         # nested tuple
    assert not is_axes(({"k": 1},))           # dict member
    assert not is_axes(["batch"])             # list, not tuple
    assert not is_axes("batch")               # bare string
    assert not is_axes(NT())


# --------------------------------------------------------------------------
# ambient rules: nesting / re-entrancy
# --------------------------------------------------------------------------

def test_use_rules_nesting_restores_outer():
    assert current_rules() is None
    outer = SINGLE_POD_RULES
    inner = with_overrides(outer, batch=None)
    with use_rules(outer):
        assert current_rules() is outer
        with use_rules(inner):
            assert current_rules() is inner
        assert current_rules() is outer
    assert current_rules() is None


def test_use_rules_restores_on_exception():
    with pytest.raises(RuntimeError):
        with use_rules(SINGLE_POD_RULES):
            raise RuntimeError("boom")
    assert current_rules() is None


def test_use_rules_instance_is_reusable():
    # launch/train.py constructs the context eagerly and enters it later;
    # sequential re-entry of the same instance must also work
    ctx = use_rules(SINGLE_POD_RULES)
    for _ in range(2):
        with ctx:
            assert current_rules() is SINGLE_POD_RULES
        assert current_rules() is None


# --------------------------------------------------------------------------
# shard: single-device degrade
# --------------------------------------------------------------------------

def test_shard_identity_outside_any_scope():
    x = jnp.ones((4, 4))
    assert shard(x, "batch", "tp") is x


def test_shard_identity_with_meshless_rules():
    x = jnp.ones((4, 4))
    with use_rules(SINGLE_POD_RULES):  # mesh=None constant
        assert shard(x, "batch", "tp") is x


def test_shard_identity_on_one_device_mesh():
    mesh = make_compat_mesh((1, 1), ("data", "model"),
                            devices=jax.devices("cpu")[:1])
    rules = AxisRules(rules=dict(SINGLE_POD_RULES.rules), mesh=mesh)
    x = jnp.ones((4, 4))
    with use_rules(rules):
        assert shard(x, "batch", "tp") is x


# --------------------------------------------------------------------------
# param_shardings
# --------------------------------------------------------------------------

def test_param_shardings_requires_mesh():
    with pytest.raises(ValueError, match="mesh-bound"):
        param_shardings({"w": ("fsdp", "tp")}, SINGLE_POD_RULES)


def test_param_shardings_maps_leaves_through_containers():
    from repro.models.ssm import SSMCache
    mesh = make_compat_mesh((1, 1), ("data", "model"),
                            devices=jax.devices("cpu")[:1])
    rules = AxisRules(rules=dict(SINGLE_POD_RULES.rules), mesh=mesh)
    tree = {
        "w": ("fsdp", "tp"),
        "scalar": (),
        "cache": SSMCache(("batch", "tp"), ("batch", None), (None,), ()),
    }
    out = param_shardings(tree, rules)
    assert tuple(out["w"].spec) == ("data", "model")
    assert tuple(out["scalar"].spec) == ()
    assert isinstance(out["cache"], SSMCache)  # container preserved
    assert tuple(out["cache"].state.spec) == ("data", "model")
    assert all(s.mesh is mesh for s in jax.tree.leaves(out))


# --------------------------------------------------------------------------
# elastic downsize policy
# --------------------------------------------------------------------------

def _mesh_stub(data=8, pod=None):
    # downsize_batch_rules only reads mesh.shape; a stub keeps the test off
    # the (process-global, single-device) jax backend
    shape = {"data": data, "model": 16}
    if pod is not None:
        shape["pod"] = pod
    return types.SimpleNamespace(shape=shape)


def test_downsize_valid_eviction_detaches_mesh():
    rules = AxisRules(rules=dict(SINGLE_POD_RULES.rules), mesh=_mesh_stub(8))
    out = downsize_batch_rules(rules, lost_hosts=4, hosts_per_data_shard=2)
    assert out.mesh is None
    assert dict(out.rules) == dict(SINGLE_POD_RULES.rules)
    assert rules.mesh is not None  # input untouched


def test_downsize_rejects_misaligned_eviction():
    rules = AxisRules(rules=dict(SINGLE_POD_RULES.rules), mesh=_mesh_stub(8))
    with pytest.raises(ValueError, match="shard-aligned"):
        downsize_batch_rules(rules, lost_hosts=3, hosts_per_data_shard=2)


def test_downsize_rejects_emptying_batch_pool():
    rules = AxisRules(rules=dict(SINGLE_POD_RULES.rules), mesh=_mesh_stub(4))
    with pytest.raises(ValueError, match="empties the batch-shard pool"):
        downsize_batch_rules(rules, lost_hosts=4)


def test_downsize_multi_pod_counts_full_batch_pool():
    # pod=2 x data=16 = 32 batch shards: losing a whole pod's 16 shards is
    # a valid downsize, not an axis-emptying one
    rules = AxisRules(rules=dict(MULTI_POD_RULES.rules),
                      mesh=_mesh_stub(data=16, pod=2))
    out = downsize_batch_rules(rules, lost_hosts=16)
    assert out.mesh is None
    with pytest.raises(ValueError, match="empties the batch-shard pool"):
        downsize_batch_rules(rules, lost_hosts=32)


def test_downsize_rejects_nonpositive_and_meshless():
    rules = AxisRules(rules=dict(SINGLE_POD_RULES.rules), mesh=_mesh_stub(4))
    with pytest.raises(ValueError, match="positive"):
        downsize_batch_rules(rules, lost_hosts=0)
    with pytest.raises(ValueError, match="bound to the pre-eviction mesh"):
        downsize_batch_rules(SINGLE_POD_RULES, lost_hosts=1)
