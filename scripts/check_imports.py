#!/usr/bin/env python
"""CI entry point for the import-integrity check (no jax needed).

Usage: ``python scripts/check_imports.py`` from anywhere; exits non-zero if
any ``repro.*`` import names a module that does not exist under ``src/``.
"""

import pathlib
import sys

REPO_ROOT = pathlib.Path(__file__).resolve().parents[1]
sys.path.insert(0, str(REPO_ROOT / "src"))

from repro.tools.import_integrity import main  # noqa: E402

if __name__ == "__main__":
    raise SystemExit(main(REPO_ROOT))
