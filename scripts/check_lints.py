#!/usr/bin/env python
"""CI entry point for jaxlint (stdlib-only, no jax needed).

Usage, from anywhere in the repo:

    python scripts/check_lints.py                  # lint src/ + benchmarks/
                                                   # examples/ scripts/, exit 1
                                                   # on unsuppressed findings
    python scripts/check_lints.py --github         # ::error annotations
    python scripts/check_lints.py --format sarif   # SARIF 2.1.0 on stdout
    python scripts/check_lints.py --cache .jaxlint-cache.json
                                                   # incremental: re-analyze
                                                   # only changed files + their
                                                   # reverse-import closure
    python scripts/check_lints.py --jobs 4         # parse/per-file in parallel
    python scripts/check_lints.py --report dead-exports \
        --allowlist scripts/dead_exports_allowlist.txt
                                                   # CI gate: fail on dead
                                                   # exports not allowlisted
                                                   # AND on stale entries
    python scripts/check_lints.py --report dead-exports   # informational
    python scripts/check_lints.py --list-rules
"""

import pathlib
import sys

REPO_ROOT = pathlib.Path(__file__).resolve().parents[1]
sys.path.insert(0, str(REPO_ROOT / "src"))

from repro.tools.jaxlint import main  # noqa: E402

if __name__ == "__main__":
    raise SystemExit(main(repo_root=REPO_ROOT))
